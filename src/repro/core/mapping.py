"""Intra-device KV mapping (paper §6.1).

Within one PIM device, KV tokens are interleaved across B parallel bank
groups; the device's latency is the *max* over bank groups (T_intra =
max_bg T_bg), so the mapper balances the **activation frequency** (tracked
over a 10-step window) across bank groups, then aligns tokens to identical
rows across banks for lockstep activation.

TPU adaptation: "bank group" maps to a kernel grid lane / sublane partition
of the per-device KV shard. The balanced assignment determines the gather
order used when compacting the hot set into the dense kernel layout, so
each grid block of the Pallas decode kernel receives an equal share of
frequently-activated tokens.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("num_groups",))
def greedy_balanced_assign(freq: jax.Array, valid: jax.Array,
                           num_groups: int) -> jax.Array:
    """Greedy longest-processing-time assignment of tokens to bank groups.

    Tokens are taken in decreasing activation frequency; each goes to the
    currently lightest group (paper: "greedily allocated to the bank group
    with the lowest activation frequency"). Returns (tokens,) int32 group id.

    Implemented as a sorted round-robin refinement: after sorting by
    frequency, position p goes to group p % G when loads are equal, which is
    exactly LPT for the uniform case; a scan fixes the general case.
    """
    n = freq.shape[0]
    f = jnp.where(valid, freq.astype(jnp.float32), -1.0)
    order = jnp.argsort(-f)  # decreasing frequency, invalid last

    def body(loads, tok):
        g = jnp.argmin(loads)
        loads = loads.at[g].add(jnp.maximum(f[tok], 0.0))
        return loads, g

    _, groups_sorted = jax.lax.scan(body, jnp.zeros((num_groups,)), order)
    # scatter back to token order
    assign = jnp.zeros((n,), jnp.int32).at[order].set(
        groups_sorted.astype(jnp.int32))
    return assign


def group_loads(freq: jax.Array, assign: jax.Array, valid: jax.Array,
                num_groups: int) -> jax.Array:
    """Per-group total activation frequency (T_bg proxy)."""
    w = jnp.where(valid, freq.astype(jnp.float32), 0.0)
    return jax.ops.segment_sum(w, assign, num_segments=num_groups)


def imbalance(freq: jax.Array, assign: jax.Array, valid: jax.Array,
              num_groups: int) -> jax.Array:
    """max/mean group load — 1.0 is perfect balance (T_intra metric)."""
    loads = group_loads(freq, assign, valid, num_groups)
    return jnp.max(loads) / jnp.maximum(jnp.mean(loads), 1e-9)


@partial(jax.jit, static_argnames=("window",))
def update_activation_freq(freq_window: jax.Array, activated: jax.Array,
                           step: jax.Array, window: int = 10) -> jax.Array:
    """Ring-buffer activation tracking over the paper's 10-step window.

    freq_window: (window, tokens) uint8 activation history;
    activated: (tokens,) bool for this step. Returns updated window.
    """
    slot = step % window
    return freq_window.at[slot].set(activated.astype(freq_window.dtype))


def windowed_frequency(freq_window: jax.Array) -> jax.Array:
    """(tokens,) activation count over the window."""
    return jnp.sum(freq_window.astype(jnp.int32), axis=0)
