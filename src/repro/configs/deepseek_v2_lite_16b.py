"""deepseek-v2-lite-16b [arXiv:2405.04434; hf] — MLA (kv_lora=512) + MoE
64 routed top-6 + 2 shared experts."""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    rope_theta=1e4,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_expert=1408),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
))
