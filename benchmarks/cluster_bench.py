"""Multi-device cluster benchmark (paper §4.3): one bursty Poisson
request stream served by a single device vs a heterogeneous 3-device
cluster (1x HBM-class + 2x CXL-class) with online KV balancing.

Reports aggregate tok/s, per-device utilization, migrations per 1k
router ticks and SLO attainment — the PR-4 bench trajectory point
(``benchmarks/run.py --section cluster --out BENCH_pr4.json``).
"""

from __future__ import annotations

from typing import Optional

import numpy as np


def bursty_trace(n: int, vocab: int, *, seed: int = 1, burst: int = 16,
                 gap_in_burst: float = 0.0005, gap_between: float = 0.05,
                 prompt_len: int = 16, max_new: int = 16):
    """Bursty Poisson arrivals: exponential gaps with a short mean inside
    a burst and a long mean between bursts (paper's heavy-traffic online
    setting)."""
    from repro.serving import Request
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    for i in range(n):
        mean = gap_in_burst if (i % burst) else gap_between
        t += float(rng.exponential(mean))
        reqs.append(Request(id=i,
                            prompt=rng.integers(0, vocab, prompt_len),
                            max_new_tokens=max_new, arrival=t))
    return reqs


def _run_cluster(cfg, params, classes, scfg, trace, balanced: bool,
                 slo_s: float):
    from repro.cluster import BalancerConfig, ClusterSpec, KVBalancer
    bal = (KVBalancer(BalancerConfig(rebalance_interval=4, hysteresis=1.2,
                                     cooldown_ticks=8))
           if balanced else None)
    router = ClusterSpec.of(cfg, classes,
                            serving=scfg).build(params, balancer=bal)
    for req in trace:
        router.submit(req)
    summary = router.run()
    summary["slo_attainment"] = router.slo_attainment(slo_s)
    summary["slo_s"] = slo_s
    summary["migrations_per_1k_ticks"] = (
        1000.0 * summary["balancer_migrations"]
        / max(summary["ticks"], 1))
    return summary


def bench_cluster(n_requests: int = 96, slo_s: float = 0.05,
                  seed: int = 1) -> dict:
    """1-device vs heterogeneous 3-device under the same bursty trace.

    Returns the machine-readable comparison: the heterogeneous cluster
    must beat the best single device on aggregate tok/s with balancer
    migrations > 0 (the PR-4 acceptance point)."""
    import jax
    from repro.models import transformer as tf
    from repro.models.config import get_config, reduced
    from repro.perfmodel.devices import CXL_CLASS, HBM_CLASS
    from repro.serving import PAMManagerConfig, ServingConfig

    cfg = reduced(get_config("pam-llama-7b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    pam = PAMManagerConfig(max_tokens=64, hot_capacity=4, warm_capacity=8,
                           compression=4, recency_window=2,
                           schedule_interval=2)
    scfg = ServingConfig(max_batch=4, max_len=64, pam=pam, block_size=8)
    trace = lambda: bursty_trace(n_requests, cfg.vocab, seed=seed)

    out = {
        "config": {
            "model": cfg.name, "n_requests": n_requests,
            "prompt_len": 16, "max_new_tokens": 16,
            "burst": 16, "block_size": 8, "max_len": 64,
            "devices_single_fast": "hbm:1",
            "devices_single_slow": "cxl:1",
            "devices_cluster": "hbm:1,cxl:2",
            "balancer": {"rebalance_interval": 4, "hysteresis": 1.2,
                         "cooldown_ticks": 8},
            "seed": seed,
        },
        "single_hbm": _run_cluster(cfg, params, [HBM_CLASS], scfg,
                                   trace(), balanced=False, slo_s=slo_s),
        "single_cxl": _run_cluster(cfg, params, [CXL_CLASS], scfg,
                                   trace(), balanced=False, slo_s=slo_s),
        "cluster_3dev": _run_cluster(
            cfg, params, [HBM_CLASS, CXL_CLASS, CXL_CLASS], scfg,
            trace(), balanced=True, slo_s=slo_s),
    }
    best_single = max(out["single_hbm"]["throughput_tok_s"],
                      out["single_cxl"]["throughput_tok_s"])
    out["best_single_tok_s"] = best_single
    out["cluster_tok_s"] = out["cluster_3dev"]["throughput_tok_s"]
    out["cluster_speedup_vs_best_single"] = (
        out["cluster_tok_s"] / max(best_single, 1e-9))
    out["migrations"] = out["cluster_3dev"]["balancer_migrations"]
    return out


def cluster_rows(result: Optional[dict] = None) -> tuple[dict, list]:
    """CSV rows for the harness (+ the computed result)."""
    res = result if result is not None else bench_cluster()
    rows = []
    for name in ("single_hbm", "single_cxl", "cluster_3dev"):
        s = res[name]
        util = " ".join(f"{d}={v['utilization']:.2f}"
                        for d, v in s["devices"].items())
        rows.append((f"cluster/{name}", s["makespan_s"] * 1e6,
                     f"tok_s={s['throughput_tok_s']:.1f} "
                     f"migrations={s['balancer_migrations']} "
                     f"slo={s['slo_attainment']:.3f} util[{util}]"))
    rows.append(("cluster/speedup_vs_best_single", 0.0,
                 f"{res['cluster_speedup_vs_best_single']:.2f}x "
                 f"migrations_per_1k="
                 f"{res['cluster_3dev']['migrations_per_1k_ticks']:.1f}"))
    return res, rows
