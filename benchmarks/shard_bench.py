"""Sharded single-dispatch engine bench (PR 10) -> BENCH_pr10.json.

Measures, at shard 1/2/4 on fake CPU devices (subprocess — the
8-device XLA flag must be set before jax imports, and the parent bench
session must keep seeing 1 device):

  * decode tokens/s of the sharded fused step (wall clock)
  * dispatches per decode step — the 1-dispatch invariant under
    ``shard_map``
  * param bytes per device — a shard-N engine holds ~1/N of a copy
  * token exactness vs the unsharded engine (streams must be
    bit-identical; ``tokens_lost`` counts any divergence)
  * the Alg. 1 merge's collective bytes/step vs context length — the
    ``pmax``/``psum`` of the (O, m, l) triple is H x (d + 2) fp32 per
    layer per row, FLAT in context, against a gather baseline whose
    bytes grow linearly (the paper's flat-communication claim)
  * replica-group economics: one 2-way group's summed param bytes vs
    two full per-device copies
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

_CTXS = (64, 256, 1024, 4096)


def _worker_main() -> None:
    import time

    import jax
    import numpy as np

    jax.config.update("jax_platform_name", "cpu")

    from repro.distributed.pam_shard import merge_collective_bytes
    from repro.models import transformer as tf
    from repro.models.config import get_config, reduced
    from repro.serving.engine import Request, ServingConfig
    from repro.serving.pam_manager import PAMManagerConfig
    from repro.serving.spec import EngineSpec

    cfg = reduced(get_config("qwen3-0.6b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    pam = PAMManagerConfig(max_tokens=64, hot_capacity=8,
                           warm_capacity=16, compression=4,
                           recency_window=4, schedule_interval=2)
    scfg = ServingConfig(pam=pam, max_batch=2, max_len=64, block_size=8,
                         pool_blocks=23, hot_window=16)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab, 20) for _ in range(4)]

    def run(shard):
        eng = EngineSpec(model=cfg, serving=scfg, shard=shard,
                         name=f"s{shard}").build(params)
        for i, p in enumerate(prompts):
            eng.submit(Request(id=i, prompt=p, max_new_tokens=12))
        t0 = time.perf_counter()
        summary = eng.run()
        wall = time.perf_counter() - t0
        streams = {rid: rs.outputs for rid, rs in eng.requests.items()}
        return {
            "decode_tok_s": summary["total_tokens"] / wall,
            "dispatches_per_step": (eng.decode_dispatches
                                    / max(eng.decode_device_steps, 1)),
            "param_bytes_per_device": eng.params_bytes_per_device(),
        }, streams

    points, streams = {}, {}
    for shard in (1, 2, 4):
        points[str(shard)], streams[shard] = run(shard)
    lost = sum(
        sum(a != b for a, b in zip(streams[1][rid], streams[s][rid]))
        + abs(len(streams[1][rid]) - len(streams[s][rid]))
        for s in (2, 4) for rid in streams[1])

    # analytic collective bytes/step: the exact (O, m, l) merge vs a
    # gather baseline that ships the remote KV instead (batch of 2)
    B = scfg.max_batch
    merge_by_ctx, gather_by_ctx = {}, {}
    for ctx in _CTXS:
        merge, _ = merge_collective_bytes(cfg.n_layers, cfg.n_heads,
                                          cfg.head_dim, B)
        merge_by_ctx[str(ctx)] = merge
        gather_by_ctx[str(ctx)] = (2 * cfg.n_layers * B * cfg.n_kv_heads
                                   * cfg.head_dim * ctx * 4)
    full = points["1"]["param_bytes_per_device"]
    grp2 = 2 * points["2"]["param_bytes_per_device"]
    out = {
        "points": points,
        "tokens_lost_total": int(lost),
        "merge_bytes_by_context": merge_by_ctx,
        "gather_bytes_by_context": gather_by_ctx,
        "merge_bytes_flat": len(set(merge_by_ctx.values())) == 1,
        "merge_bytes_per_step": merge_by_ctx[str(_CTXS[0])],
        "dispatches_per_step_max": max(
            p["dispatches_per_step"] for p in points.values()),
        "replica_group_2way": {
            "group_total_bytes": grp2,
            "per_device_copies_bytes": 2 * full,
            "bytes_ratio_vs_copies": grp2 / (2 * full),
        },
    }
    print("SHARD_BENCH_JSON " + json.dumps(out))


def shard_rows() -> tuple[dict, list[tuple]]:
    """Run the sharded bench in an 8-fake-device subprocess; returns
    (summary dict for BENCH_pr10.json, CSV rows)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", ""))
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--worker"],
        env=env, capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"shard bench worker failed:\n{out.stdout}"
                           f"\n{out.stderr}")
    line = next(ln for ln in out.stdout.splitlines()
                if ln.startswith("SHARD_BENCH_JSON "))
    d = json.loads(line[len("SHARD_BENCH_JSON "):])

    rows: list[tuple] = []
    for shard, p in sorted(d["points"].items(), key=lambda kv: int(kv[0])):
        rows.append((f"shard{shard}_decode", 0.0,
                     f"{p['decode_tok_s']:.0f} tok/s, "
                     f"{p['dispatches_per_step']:.2f} dispatches/step, "
                     f"{p['param_bytes_per_device']} param B/dev"))
    for ctx in _CTXS:
        rows.append((f"shard_collectives_ctx{ctx}", 0.0,
                     f"merge {d['merge_bytes_by_context'][str(ctx)]} B "
                     f"vs gather {d['gather_bytes_by_context'][str(ctx)]}"
                     f" B"))
    rg = d["replica_group_2way"]
    rows.append(("shard_replica_group_2way", 0.0,
                 f"{rg['group_total_bytes']} B shared vs "
                 f"{rg['per_device_copies_bytes']} B as copies "
                 f"({rg['bytes_ratio_vs_copies']:.2f}x)"))
    rows.append(("shard_tokens_lost", 0.0, str(d["tokens_lost_total"])))
    return d, rows


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker_main()
    else:
        summary, rows = shard_rows()
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
