"""KV-centric serving engine (paper §4): request pool, continuous batching
with prefill priority, paged + tiered KV management, PAM decode loop."""

from repro.serving.paged_kv import (BlockAllocator, OutOfBlocks,
                                    PagedKVPool, PrefixTrie)
from repro.serving.pam_manager import PAMManager, PAMManagerConfig
from repro.serving.engine import (PAMEngine, Request, RequestState,
                                  ServingConfig, ServingEngine)

__all__ = ["BlockAllocator", "OutOfBlocks", "PagedKVPool", "PAMEngine",
           "PAMManager", "PAMManagerConfig", "PrefixTrie", "Request",
           "RequestState", "ServingConfig", "ServingEngine"]
