"""Unified telemetry layer (PR 9): metrics registry + request tracing.

Two host-side surfaces, both OFF by default and zero-allocation when
disabled, shared by the engine (``repro.serving``), the cluster
(``repro.cluster``) and the front end (``repro.frontend``):

- ``repro.obs.metrics`` — a process-wide registry of labeled Counters /
  Gauges / Histograms (fixed log-bucket latency histograms), with
  ``snapshot()`` for structured export and ``render()`` for
  Prometheus-style text exposition;
- ``repro.obs.trace`` — per-request lifecycle spans (queued →
  chunked-prefill slices → decode → suspend/migrate → finish/shed) and
  engine-step / cluster-tick events on the existing sim-clocks,
  recorded into a bounded ring and exported as Chrome trace-event JSON
  loadable in Perfetto.

Enable both for a run with::

    from repro import obs
    reg = obs.metrics.install(obs.metrics.MetricsRegistry())
    coll = obs.trace.install(obs.trace.TraceCollector())
    ...build engines / routers / servers, run...
    print(reg.render())          # Prometheus text
    coll.write("trace.json")     # load in https://ui.perfetto.dev

Instrumentation points bind to whatever registry/collector is installed
at CONSTRUCTION time (engines) or look the collector up per hook
(cheap module-global read), so installing before building the serving
stack is all that is needed. The fused-dispatch and donation
invariants are unaffected: every hook is host-side bookkeeping around
the existing per-step readbacks.
"""

from repro.obs import metrics, trace
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import TraceCollector

__all__ = ["metrics", "trace", "MetricsRegistry", "TraceCollector"]
