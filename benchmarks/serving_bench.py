"""Serving-under-load benchmark (PR 8): the front end scored on tails.

Serves seeded arrival traces (Poisson + bursty Gamma/ON-OFF) through
``repro.frontend.AsyncServer`` — SLO admission attached — on a
single-device engine and on the paper's ``hbm:1,cxl:2`` heterogeneous
cluster, recording TTFT/TPOT p50/p95/p99, SLO attainment, shed /
forced-preemption counts, and the zero-lost/zero-duplicated streamed
token check per scenario.

Two extra points pin the PR 8 mechanisms:

* **chunked vs unchunked prefill** on a long-prompt trace at equal
  offered load: a monolithic prefill stalls every co-running decode for
  one big step (the TPOT tail), while pow-2 slices bound the stall —
  chunked p99 TPOT must come out LOWER at matched throughput.
* **generator scale**: ``cluster_bench`` serves 96 requests; the load
  generator here is exercised at 100x that (9600-request trace,
  generation + arrival-stat checks only — the SCORED scenarios serve
  CI-sized traces so the committed bench stays reproducible in
  minutes).
"""

from __future__ import annotations

import asyncio
from typing import Optional

import numpy as np

# single-device scenarios run the reduced model's own latency model;
# the cluster runs hardware-scale device models (context_scale), so its
# time base — and therefore its sustainable rate and SLO — is ~100x
# coarser (cluster_bench's regime: ~3 req/s, 50 ms-class token gaps).
SLO_TTFT_S = 0.25
SLO_TPOT_S = 0.05
CLUSTER_SLO_TTFT_S = 2.0
CLUSTER_SLO_TPOT_S = 0.1
CLUSTER_RATE_RPS = 3.0


def _score_keys(sc: dict, adm) -> dict:
    out = dict(sc)
    out["admission"] = adm.summary()
    return out


def serving_sweep(n_requests: int = 256, rate_rps: float = 300.0,
                  seed: int = 11) -> dict:
    import jax
    from repro.cluster import (BalancerConfig, ClusterSpec, KVBalancer,
                               RecoveryConfig)
    from repro.frontend.admission import SLOAdmission, SLOSpec
    from repro.frontend.loadgen import TraceConfig, make_trace, score
    from repro.frontend.server import AsyncServer
    from repro.models import transformer as tf
    from repro.models.config import get_config, reduced
    from repro.perfmodel import make_latency_model
    from repro.perfmodel.model import PAM_LLAMA_7B, make_system

    cfg = reduced(get_config("qwen3-0.6b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    lat = make_latency_model(make_system("pam"), PAM_LLAMA_7B)
    slo = SLOSpec(ttft_s=SLO_TTFT_S, tpot_s=SLO_TPOT_S)
    cluster_slo = SLOSpec(ttft_s=CLUSTER_SLO_TTFT_S,
                          tpot_s=CLUSTER_SLO_TPOT_S)

    from repro.serving import EngineSpec, PAMManagerConfig, ServingConfig

    def scfg(max_len=128, chunk=0):
        pam = PAMManagerConfig(max_tokens=max_len,
                               hot_capacity=max_len // 8,
                               warm_capacity=max_len // 4, compression=4,
                               recency_window=8, schedule_interval=2)
        return ServingConfig(max_batch=4, max_len=max_len, pam=pam,
                             block_size=8, prefill_chunk=chunk)

    def engine(**kw):
        return EngineSpec(model=cfg, serving=scfg(**kw)).build(
            params, latency_model=lat)

    def cluster():
        return ClusterSpec.from_cli(
            "hbm:1,cxl:2", model=cfg, serving=scfg(),
            recovery=RecoveryConfig()).build(
            params, balancer=KVBalancer(BalancerConfig()))

    def trace(kind, tseed, **kw):
        base = dict(kind=kind, n_requests=n_requests, rate_rps=rate_rps,
                    prompt_len=(8, 48), max_new=(4, 16), vocab=cfg.vocab,
                    seed=tseed)
        base.update(kw)
        return make_trace(TraceConfig(**base))

    def serve(backend, reqs, spec):
        adm = SLOAdmission(spec)
        srv = AsyncServer(backend, admission=adm)
        records = asyncio.run(srv.serve_trace(reqs))
        sc = score(records.values(), ttft_slo_s=spec.ttft_s,
                   tpot_slo_s=spec.tpot_s)
        back = srv.router.summary()
        sc["throughput_tok_s"] = back["throughput_tok_s"]
        sc["makespan_s"] = back["makespan_s"]
        return _score_keys(sc, adm)

    n_cluster = max(n_requests // 2, 32)
    scenarios = {}
    scenarios["single_poisson"] = serve(engine(), trace("poisson", seed),
                                        slo)
    scenarios["single_gamma"] = serve(engine(), trace("gamma", seed + 1),
                                      slo)
    scenarios["single_onoff"] = serve(engine(), trace("onoff", seed + 2),
                                      slo)
    scenarios["cluster_poisson"] = serve(
        cluster(), trace("poisson", seed + 3, rate_rps=CLUSTER_RATE_RPS,
                         n_requests=n_cluster), cluster_slo)
    scenarios["cluster_onoff"] = serve(
        cluster(), trace("onoff", seed + 4, rate_rps=CLUSTER_RATE_RPS,
                         n_requests=n_cluster, period_s=20.0),
        cluster_slo)

    # ---- chunked vs unchunked prefill, long-prompt trace, equal load.
    # TPOT here is the pooled per-token gap distribution (itl_s): the
    # mechanism under test is ONE monolithic long prefill stalling the
    # co-running decode step, a single-gap spike that per-request means
    # average away but the pooled p99 pins.
    long_kw = dict(prompt_len=(112, 160), max_new=(8, 16), rate_rps=150.0,
                   n_requests=max(n_requests // 4, 32))
    chunk_cmp = {}
    for label, chunk in (("unchunked", 0), ("chunked", 16)):
        reqs = trace("poisson", seed + 9, **long_kw)
        sc = serve(engine(max_len=192, chunk=chunk), reqs, slo)
        chunk_cmp[label] = sc
    chunk_cmp["chunk_budget"] = 16
    chunk_cmp["p99_tpot_ratio"] = (
        chunk_cmp["chunked"]["itl_s"]["p99"]
        / max(chunk_cmp["unchunked"]["itl_s"]["p99"], 1e-12))

    # ---- generator at 100x cluster_bench scale (generation only)
    big = TraceConfig(kind="gamma", n_requests=9600, rate_rps=2000.0,
                      vocab=cfg.vocab, seed=seed + 5)
    arr = np.array([r.arrival for r in make_trace(big)])
    gaps = np.diff(arr)
    scale = {
        "n_requests": big.n_requests,
        "monotone": bool((gaps >= 0).all()),
        "mean_rate_rps": float((big.n_requests - 1) / (arr[-1] - arr[0])),
        "gap_cv2": float(np.var(gaps) / np.mean(gaps) ** 2),
    }

    lost = sum(s["lost_tokens"] + s["dup_tokens"]
               for s in scenarios.values())
    lost += sum(chunk_cmp[k]["lost_tokens"] + chunk_cmp[k]["dup_tokens"]
                for k in ("unchunked", "chunked"))
    return {
        "scenarios": scenarios,
        "chunked_prefill": chunk_cmp,
        "scale_trace": scale,
        "slo": {"ttft_s": slo.ttft_s, "tpot_s": slo.tpot_s,
                "cluster_ttft_s": cluster_slo.ttft_s,
                "cluster_tpot_s": cluster_slo.tpot_s,
                "cluster_rate_rps": CLUSTER_RATE_RPS},
        "n_requests_per_scenario": n_requests,
        "smoke_slo_attainment": scenarios["single_poisson"][
            "slo_attainment"],
        "p99_ttft_s_worst": max(s["ttft_s"]["p99"]
                                for s in scenarios.values()),
        "tokens_lost_total": int(lost),
    }


def serving_rows(result: Optional[dict] = None) -> tuple[dict, list]:
    if result is None:
        result = serving_sweep()
    rows = []
    for name in sorted(result["scenarios"]):
        s = result["scenarios"][name]
        rows.append((
            f"serving/{name}", 0.0,
            f"ttft_p99={s['ttft_s']['p99']:.4f}s "
            f"tpot_p99={s['tpot_s']['p99']:.4f}s "
            f"slo={s['slo_attainment']:.3f} "
            f"shed={s['admission']['shed']} "
            f"lost={s['lost_tokens']} dup={s['dup_tokens']}"))
    cc = result["chunked_prefill"]
    rows.append(("serving/chunked_vs_unchunked", 0.0,
                 f"p99_tpot chunked={cc['chunked']['itl_s']['p99']:.4f}s "
                 f"unchunked={cc['unchunked']['itl_s']['p99']:.4f}s "
                 f"ratio={cc['p99_tpot_ratio']:.3f} "
                 f"tok_s {cc['chunked']['throughput_tok_s']:.0f}"
                 f"/{cc['unchunked']['throughput_tok_s']:.0f}"))
    sc = result["scale_trace"]
    rows.append(("serving/loadgen_scale", 0.0,
                 f"n={sc['n_requests']} monotone={sc['monotone']} "
                 f"rate={sc['mean_rate_rps']:.0f}rps "
                 f"cv2={sc['gap_cv2']:.2f}"))
    return result, rows


if __name__ == "__main__":
    _, rows = serving_rows()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
