"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train-grad step + (where applicable) decode steps on CPU.
Asserts output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tf
from repro.models.config import all_configs, get_config, reduced

jax.config.update("jax_platform_name", "cpu")

ARCHS = ["qwen3-14b", "deepseek-67b", "qwen3-0.6b", "minicpm-2b",
         "internvl2-1b", "deepseek-v2-lite-16b", "qwen3-moe-235b-a22b",
         "zamba2-7b", "hubert-xlarge", "mamba2-780m"]


def _batch(cfg, B=2, S=16, key=None):
    key = key or jax.random.PRNGKey(0)
    batch = {}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.frontend_dim))
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
        if cfg.family == "vlm":
            batch["patches"] = jax.random.normal(
                key, (B, cfg.num_patches, cfg.frontend_dim))
    return batch


def test_all_assigned_archs_registered():
    cfgs = all_configs()
    for a in ARCHS:
        assert a in cfgs, f"missing config {a}"
        full = cfgs[a]
        assert full.param_count() > 1e8, (a, full.param_count())


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg)
    logits, aux = tf.forward(cfg, params, batch)
    B, S = batch["labels"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, grads = jax.value_and_grad(
        lambda p: tf.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    # at least one grad is nonzero
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves)


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "hubert-xlarge"])
def test_decode_steps(arch):
    cfg = reduced(get_config(arch))
    params = tf.init_params(cfg, jax.random.PRNGKey(2))
    B, max_len = 2, 12
    cache = tf.init_decode_cache(cfg, B, max_len)
    tok = jnp.array([1, 2], jnp.int32)
    for step in range(3):
        logits, cache, scores = tf.decode_step(cfg, params, tok, cache)
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        if cfg.family != "ssm":
            assert scores is not None and scores.shape == (B, max_len)
            # participating tokens' mass sums to live count (head-mean x N)
            assert bool(jnp.all(scores >= 0))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(cache.lengths), [3, 3])


def test_encoder_only_has_no_decode():
    cfg = reduced(get_config("hubert-xlarge"))
    assert not cfg.has_decode
    with pytest.raises(ValueError):
        tf.init_decode_cache(cfg, 1, 4)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-780m"])
def test_decode_matches_prefill_logits(arch):
    """Teacher-forced decode must reproduce the train-forward logits
    (the KV-cache / recurrent-state path is consistent with the parallel
    path) — run in fp32 reduced config."""
    cfg = reduced(get_config(arch))
    params = tf.init_params(cfg, jax.random.PRNGKey(3))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    logits_par, _ = tf.forward(cfg, params, batch)

    cache = tf.init_decode_cache(cfg, B, S + 1)
    logits_seq = []
    for t in range(S):
        lg, cache, _ = tf.decode_step(cfg, params, toks[:, t], cache)
        logits_seq.append(lg)
    logits_seq = jnp.stack(logits_seq, axis=1)
    np.testing.assert_allclose(np.asarray(logits_seq),
                               np.asarray(logits_par), rtol=2e-3, atol=2e-3)


def test_moe_dispatch_close_to_dense_oracle():
    """Capacity-based dispatch ~= dense oracle when capacity is ample."""
    import dataclasses
    from repro.models import moe as moe_mod
    cfg = reduced(get_config("qwen3-moe-235b-a22b"))
    mcfg = dataclasses.replace(cfg.moe, capacity_factor=4.0)
    p = moe_mod.init_moe(jax.random.PRNGKey(5), cfg.d_model, mcfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 16, cfg.d_model))
    y, aux = moe_mod.moe_forward(p, x, mcfg)
    y_ref = moe_mod.moe_forward_dense_oracle(p, x, mcfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) >= 0.0


def test_param_counts_in_expected_range():
    """Sanity: analytic param counts are within the advertised scale."""
    expect = {
        "qwen3-14b": (10e9, 20e9),
        "deepseek-67b": (55e9, 75e9),
        "qwen3-0.6b": (0.3e9, 1.0e9),
        "minicpm-2b": (2e9, 3.5e9),
        "internvl2-1b": (0.3e9, 1.2e9),
        "deepseek-v2-lite-16b": (12e9, 20e9),
        "qwen3-moe-235b-a22b": (180e9, 260e9),
        "zamba2-7b": (5e9, 9e9),
        "hubert-xlarge": (0.7e9, 1.4e9),
        "mamba2-780m": (0.5e9, 1.1e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_much_smaller():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()
