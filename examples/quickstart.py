"""Quickstart: PAM's core machinery in ~100 lines.

Runs on CPU in seconds:
  1. exact tier-partitioned attention (PAMattention, Alg. 1)
  2. importance tracking (eq. 7) + online scheduling (Alg. 2)
  3. a few serving-engine steps on a tiny model
  4. the paged warm/cold tiers: block-table reads, identical tokens,
     a fraction of the KV pages touched
  5. a heterogeneous 2-device cluster: router + online KV balancer
     migrating a running request between device classes, exactly

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PAMAttentionConfig, ScheduleConfig,
                        pam_attention_step, reference_attention,
                        schedule_kv)
from repro.core.tiers import initial_placement

key = jax.random.PRNGKey(0)
S, H, Hkv, d = 128, 8, 4, 32

# ---- 1. PAMattention == monolithic attention, for ANY tier placement ----
q = jax.random.normal(jax.random.fold_in(key, 0), (H, d))
k = jax.random.normal(jax.random.fold_in(key, 1), (S, Hkv, d))
v = jax.random.normal(jax.random.fold_in(key, 2), (S, Hkv, d))

state = initial_placement(num_tokens=S, max_tokens=S,
                          tier_capacity_tokens=[16, 48, 1000])
out = pam_attention_step(q, k, v, state.tier_of_token, state.valid,
                         state.importance,
                         PAMAttentionConfig(use_sparsity=False))
ref = reference_attention(
    q, jnp.moveaxis(jnp.repeat(k, H // Hkv, 1), 0, 1),
    jnp.moveaxis(jnp.repeat(v, H // Hkv, 1), 0, 1))
np.testing.assert_allclose(np.asarray(out.out), np.asarray(ref),
                           rtol=2e-5, atol=2e-5)
print("1. PAMattention across 3 tiers == dense attention  [exact]")

# ---- 2. importance EMA + Algorithm 2 scheduling -------------------------
imp = out.new_importance
new_tier, total = state.tier_of_token, 0
for _ in range(8):                      # bounded swaps/step -> iterate
    new_tier, moved, swaps = schedule_kv(
        imp, new_tier, state.valid, ScheduleConfig(x=8.0, y=3.0,
                                                   max_swaps=16))
    total += int(swaps)
    if int(swaps) == 0:
        break
hot_imp = float(jnp.sum(jnp.where(new_tier == 0, imp, 0))
                / jnp.maximum(jnp.sum(new_tier == 0), 1))
cold_imp = float(jnp.sum(jnp.where(new_tier == 2, imp, 0))
                 / jnp.maximum(jnp.sum(new_tier == 2), 1))
print(f"2. Alg.2 converged after {total} swaps; hot-tier mean importance "
      f"{hot_imp:.4f} vs cold {cold_imp:.4f}")
assert hot_imp > cold_imp

# ---- 3. the serving engine on a tiny qwen3 ------------------------------
from repro.models import transformer as tfm
from repro.models.config import get_config, reduced
from repro.serving import (EngineSpec, PAMManagerConfig, Request,
                           ServingConfig)

cfg = reduced(get_config("qwen3-0.6b"))
params = tfm.init_params(cfg, jax.random.PRNGKey(1))
eng = EngineSpec(model=cfg, serving=ServingConfig(
    max_batch=2, max_len=64,
    pam=PAMManagerConfig(max_tokens=64, hot_capacity=8, warm_capacity=16,
                         compression=4,
                         recency_window=4))).build(params)
rng = np.random.default_rng(0)
for i in range(3):
    eng.submit(Request(id=i, prompt=rng.integers(0, cfg.vocab, 8),
                       max_new_tokens=6))
summary = eng.run()
print(f"3. engine served {summary['finished']} requests, "
      f"{summary['total_tokens']} tokens in {summary['steps']} steps")

# ---- 4. paged warm/cold tiers: table-gathered reads, same tokens --------
# Long prompts + a small hot tier force real warm-tier (paged) reads.
pam4 = PAMManagerConfig(max_tokens=64, hot_capacity=4, warm_capacity=16,
                        compression=4, recency_window=2)
engines = []
for block_size in (0, 8):                # dense twin vs paged
    e = EngineSpec(model=cfg, serving=ServingConfig(
        max_batch=2, max_len=64, pam=pam4,
        block_size=block_size)).build(params)
    rng = np.random.default_rng(1)
    for i in range(2):
        e.submit(Request(id=i, prompt=rng.integers(0, cfg.vocab, 28),
                         max_new_tokens=8))
    engines.append((e, e.run()))
(e_dense, _), (e_paged, sp) = engines
for rid in e_dense.requests:             # storage layout, not math
    assert e_dense.requests[rid].outputs == e_paged.requests[rid].outputs
print(f"4. paged engine: identical tokens, "
      f"{sp['blocks_touched_per_step']:.1f}/{sp['blocks_window_per_step']:.1f} "
      f"KV pages touched per step, "
      f"peak pool occupancy {sp['pool_occupancy_peak']:.0%}")

# ---- 5. heterogeneous cluster: router + inter-device KV migration -------
# One fast HBM-class device + one slow CXL-class device serve a shared
# stream; the balancer migrates running requests off the overloaded slow
# device THROUGH the block table, token streams staying exact.
from repro.cluster import BalancerConfig, ClusterSpec, KVBalancer
from repro.perfmodel.devices import CXL_CLASS, HBM_CLASS

scfg5 = ServingConfig(max_batch=2, max_len=64, pam=pam4, block_size=8)
router = ClusterSpec.of(
    cfg, [HBM_CLASS, CXL_CLASS], serving=scfg5).build(
    params,
    balancer=KVBalancer(BalancerConfig(rebalance_interval=2,
                                       hysteresis=1.1, cooldown_ticks=4,
                                       min_remaining=2)))
rng = np.random.default_rng(2)
reqs = [Request(id=10 + i, prompt=rng.integers(0, cfg.vocab, 16),
                max_new_tokens=10, arrival=0.0) for i in range(4)]
for r in reqs[:2]:                       # pre-load the SLOW device
    router.submit_to(r, "cxl0")
for r in reqs[2:]:
    router.submit(r)
cs = router.run()
twin5 = EngineSpec(model=cfg, serving=scfg5).build(params)
for r in reqs:
    twin5.submit(Request(id=r.id, prompt=r.prompt,
                         max_new_tokens=r.max_new_tokens))
twin5.run()
assert all(rs.outputs == twin5.requests[rid].outputs
           for rid, rs in router.finished.items())
print(f"5. cluster served {cs['finished']} requests on "
      f"{len(cs['devices'])} device classes, {cs['balancer_migrations']} "
      f"migrations, streams exact; aggregate "
      f"{cs['throughput_tok_s']:.0f} tok/s")
print("quickstart OK")
