"""Multi-device distributed checks — executed by test_distributed.py in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set
BEFORE jax import, which is why this is a standalone script).

Checks:
  1. sequence-sharded PAMattention (shard_map psum merge) == dense oracle
  2. gather-based baseline == dense oracle (and is the comm-heavy variant)
  3. sharded train_step runs on a (2 dp, 4 tp) mesh and matches the
     single-device loss
  4. pipeline-parallel forward == sequential stage application
  5. elastic restore: checkpoint saved from mesh A restores onto mesh B
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

from repro import compat  # noqa: E402,F401  (backfills jax.set_mesh on 0.4)

from repro.distributed.pam_shard import (  # noqa: E402
    make_gather_based_decode_attn, make_sequence_sharded_decode_attn)
from repro.distributed.pipeline import (pipeline_apply,  # noqa: E402
                                        stages_from_layers)
from repro.distributed import sharding as shd  # noqa: E402
from repro.models.attention import dense_decode_attn  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.models.config import get_config, reduced  # noqa: E402
from repro.checkpoint import save_pytree, restore_pytree  # noqa: E402

assert jax.device_count() == 8, jax.device_count()


def check_pam_shard_map():
    mesh = jax.make_mesh((8,), ("model",))
    key = jax.random.PRNGKey(0)
    B, H, Hkv, S, dh = 2, 8, 4, 64, 16
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, dh))
    lens = jnp.array([50, 17], jnp.int32)

    want_out, want_mass = dense_decode_attn(q, k, v, lens)

    with jax.set_mesh(mesh):
        seq_fn = make_sequence_sharded_decode_attn(mesh)
        out, mass = jax.jit(seq_fn)(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_out),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(mass), np.asarray(want_mass),
                               rtol=2e-4, atol=2e-5)

    with jax.set_mesh(mesh):
        gat_fn = make_gather_based_decode_attn(mesh)
        out2, _ = jax.jit(gat_fn)(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(want_out),
                               rtol=2e-5, atol=2e-5)

    # collective-bytes claim: the sequence-sharded form must move less
    with jax.set_mesh(mesh):
        _seq_hlo = jax.jit(seq_fn).lower(q, k, v, lens).compile().as_text()
        gat_hlo = jax.jit(gat_fn).lower(q, k, v, lens).compile().as_text()
    assert gat_hlo.count("all-gather") > 0
    print("  pam shard_map OK")


def check_fused_update_decode():
    """§Perf pam_shard_decode path: masked local cache write + psum merge
    == unsharded scatter + dense attention."""
    from repro.distributed.pam_shard import fused_update_decode
    mesh = jax.make_mesh((8,), ("model",))
    key = jax.random.PRNGKey(4)
    B, H, Hkv, S, dh = 2, 8, 4, 64, 16
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, dh))
    kn = jax.random.normal(jax.random.fold_in(key, 3), (B, Hkv, dh))
    vn = jax.random.normal(jax.random.fold_in(key, 4), (B, Hkv, dh))
    lens = jnp.array([37, 5], jnp.int32)   # different shards own the write

    bidx = jnp.arange(B)
    k_ref = k.at[bidx, :, lens].set(kn)
    v_ref = v.at[bidx, :, lens].set(vn)
    want_out, want_mass = dense_decode_attn(q, k_ref, v_ref, lens + 1)

    with jax.set_mesh(mesh):
        out, mass, kc, vc = jax.jit(
            lambda *a: fused_update_decode(*a))(q, k, v, kn, vn, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_out),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(kc), np.asarray(k_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(mass), np.asarray(want_mass),
                               rtol=2e-4, atol=2e-5)
    print("  fused update+decode OK")


def check_sharded_train_step():
    from repro.training.train_step import TrainConfig, build_train_step, \
        init_train_state
    from repro.training import optim
    cfg = reduced(get_config("qwen3-0.6b"))
    tcfg = TrainConfig(adamw=optim.AdamWConfig(lr=1e-3))
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab),
             "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab)}
    step = build_train_step(cfg, tcfg)
    _, m_ref = jax.jit(step)(state, batch)

    pspecs = shd.param_specs(cfg, mesh)
    ospecs = shd.opt_state_specs(cfg, mesh)
    bspecs = shd.batch_specs(cfg, 4, mesh)
    from repro.training.train_step import TrainState
    from repro.training.optim import AdamWState
    _state_specs = TrainState(   # spec pytree must CONSTRUCT
        params=pspecs,
        opt=AdamWState(step=P(), mu=ospecs, nu=ospecs),
        error_feedback=None)

    def put(tree, specs):
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            tree, specs, is_leaf=lambda x: isinstance(x, P))

    with jax.set_mesh(mesh):
        state_s = TrainState(
            params=put(state.params, pspecs),
            opt=AdamWState(step=state.opt.step,
                           mu=put(state.opt.mu, ospecs),
                           nu=put(state.opt.nu, ospecs)),
            error_feedback=None)
        batch_s = {k2: jax.device_put(v, NamedSharding(mesh, bspecs[k2]))
                   for k2, v in batch.items()}
        sharded_step = jax.jit(step)
        new_state, m = sharded_step(state_s, batch_s)
    np.testing.assert_allclose(float(m["loss"]), float(m_ref["loss"]),
                               rtol=1e-4)
    # params stayed sharded
    wq = new_state.params["layers"]["attn"]["wq"]
    assert not isinstance(wq.sharding, jax.sharding.SingleDeviceSharding)
    print("  sharded train_step OK")


def check_pipeline():
    mesh = jax.make_mesh((8,), ("stage",))
    L, d = 8, 16
    key = jax.random.PRNGKey(3)
    ws = jax.random.normal(key, (L, d, d)) * 0.3
    layer_params = {"w": ws}

    def stage_fn(params, x):   # applies my group of layers
        def body(h, w):
            return jnp.tanh(h @ w), None
        out, _ = jax.lax.scan(body, x, params["w"])
        return out

    M, mb = 4, 2
    xs = jax.random.normal(jax.random.fold_in(key, 1), (M, mb, d))

    # sequential oracle
    def seq(x):
        for i in range(L):
            x = jnp.tanh(x @ ws[i])
        return x
    want = jax.vmap(seq)(xs.reshape(M * mb, d)).reshape(M, mb, d)

    stacked = stages_from_layers(layer_params, 8)
    with jax.set_mesh(mesh):
        run = pipeline_apply(mesh, stage_fn, 8)
        got = run(stacked, xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("  pipeline OK")


def check_elastic_restore(tmpdir="/tmp/elastic_ck"):
    cfg = reduced(get_config("qwen3-0.6b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(7))
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    specs = shd.param_specs(cfg, mesh_a)
    params_a = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh_a, s)),
        params, specs, is_leaf=lambda x: isinstance(x, P))
    save_pytree(params_a, tmpdir)

    # "failure": restart on a smaller mesh (1 dp x 4 tp = 4 devices)
    from repro.distributed.elastic import plan_recovery
    kept, info = plan_recovery(jax.devices(), failed_hosts={1},
                               model_parallel=4, devices_per_host=4)
    assert info["new_dp"] == 1 and len(kept) == 4
    mesh_b = Mesh(np.asarray(kept).reshape(1, 4), ("data", "model"))
    specs_b = shd.param_specs(cfg, mesh_b)
    restored = restore_pytree(
        params, tmpdir,
        shardings=jax.tree.map(lambda s: NamedSharding(mesh_b, s), specs_b,
                               is_leaf=lambda x: isinstance(x, P)))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    print("  elastic restore OK")


if __name__ == "__main__":
    check_pam_shard_map()
    check_fused_update_decode()
    check_sharded_train_step()
    check_pipeline()
    check_elastic_restore()
    print("ALL DISTRIBUTED CHECKS PASSED")
