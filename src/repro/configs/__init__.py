"""Assigned architecture configs (public-literature sources inline).

Importing this package registers every config; select with
``repro.models.config.get_config(name)`` or ``--arch <id>`` on launchers.
"""

from repro.configs import (qwen3_14b, deepseek_67b, qwen3_0_6b, minicpm_2b,
                           internvl2_1b, deepseek_v2_lite_16b,
                           qwen3_moe_235b_a22b, zamba2_7b, hubert_xlarge,
                           mamba2_780m, pam_llama_7b)  # noqa: F401

ASSIGNED = [
    "qwen3-14b", "deepseek-67b", "qwen3-0.6b", "minicpm-2b", "internvl2-1b",
    "deepseek-v2-lite-16b", "qwen3-moe-235b-a22b", "zamba2-7b",
    "hubert-xlarge", "mamba2-780m",
]
