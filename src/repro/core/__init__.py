"""PAM core: the paper's primary contribution as composable JAX modules."""

from repro.core.online_softmax import (AttnPartial, attention_from_partitions,
                                       empty_partial, finalize,
                                       local_attention, merge_many,
                                       merge_partials, reference_attention,
                                       tree_merge)
from repro.core.importance import (DEFAULT_LAMBDA, context_locality_hit_rate,
                                   step_score_from_attn_weights,
                                   tier_importance_score, topk_hot_set,
                                   update_importance)
from repro.core.tiers import (COLD, DEFAULT_TIERS, HOT, WARM, TierSpec,
                              TieredKVState, initial_placement)
from repro.core.scheduling import ScheduleConfig, ratio_error, schedule_kv
from repro.core.pam_attention import (PAMAttentionConfig, PAMAttentionOutput,
                                      pam_attention_step)

__all__ = [
    "AttnPartial", "attention_from_partitions", "empty_partial", "finalize",
    "local_attention", "merge_many", "merge_partials", "reference_attention",
    "tree_merge", "DEFAULT_LAMBDA", "context_locality_hit_rate",
    "step_score_from_attn_weights", "tier_importance_score", "topk_hot_set",
    "update_importance", "COLD", "DEFAULT_TIERS", "HOT", "WARM", "TierSpec",
    "TieredKVState", "initial_placement", "ScheduleConfig", "ratio_error",
    "schedule_kv", "PAMAttentionConfig", "PAMAttentionOutput",
    "pam_attention_step",
]
