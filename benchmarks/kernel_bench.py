"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python
semantics — not a performance signal), so wall-clock here times the jnp
reference paths under jit (real XLA:CPU numbers) and reports the kernels'
MODELED TPU time from their roofline terms (bytes/bw vs flops/peak on v5e:
197 TFLOP/s bf16, 819 GB/s HBM), which is what §Perf iterates on.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

V5E_FLOPS = 197e12
V5E_HBM = 819e9


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters


def _roofline_us(flops: float, bytes_moved: float) -> float:
    return max(flops / V5E_FLOPS, bytes_moved / V5E_HBM) * 1e6


def bench_kernels() -> list[tuple]:
    from repro.kernels import ref
    rows = []
    key = jax.random.PRNGKey(0)

    # --- decode attention (PAMattention local stage) -----------------------
    B, H, Hkv, S, d = 8, 32, 8, 4096, 128
    q = jax.random.normal(key, (B, H, d), jnp.bfloat16)
    k = jax.random.normal(key, (B, Hkv, S, d), jnp.bfloat16)
    v = jax.random.normal(key, (B, Hkv, S, d), jnp.bfloat16)
    fn = jax.jit(lambda q, k, v: ref.flash_decode_ref(q, k, v))
    cpu_s = _time(fn, q, k, v)
    flops = 4.0 * B * H * S * d
    bytes_m = 2 * B * Hkv * S * d * 2.0
    rows.append(("kernel/flash_decode/B8_S4096", cpu_s * 1e6,
                 f"tpu_roofline_us={_roofline_us(flops, bytes_m):.1f} "
                 f"(bandwidth-bound)"))

    # --- prefill attention --------------------------------------------------
    B, H, Hkv, S, d = 1, 16, 8, 2048, 128
    q4 = jax.random.normal(key, (B, H, S, d), jnp.bfloat16)
    k4 = jax.random.normal(key, (B, Hkv, S, d), jnp.bfloat16)
    v4 = jax.random.normal(key, (B, Hkv, S, d), jnp.bfloat16)
    fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    cpu_s = _time(fn, q4, k4, v4)
    flops = 4.0 * B * H * S * S * d * 0.5
    bytes_m = (B * H * S * d + 2 * B * Hkv * S * d) * 2.0
    rows.append(("kernel/flash_attention/S2048", cpu_s * 1e6,
                 f"tpu_roofline_us={_roofline_us(flops, bytes_m):.1f} "
                 f"(compute-bound)"))

    # --- SSD scan ------------------------------------------------------------
    B, L, Hs, G, N, P = 2, 1024, 24, 1, 64, 64
    x = jax.random.normal(key, (B, L, Hs, P))
    dt = jax.nn.softplus(jax.random.normal(key, (B, L, Hs)))
    a = -jnp.exp(jax.random.normal(key, (Hs,)) * 0.3)
    bm = jax.random.normal(key, (B, L, G, N)) / 8
    cm = jax.random.normal(key, (B, L, G, N)) / 8
    dsk = jnp.ones((Hs,))
    fn = jax.jit(lambda *t: ref.ssd_scan_ref(*t))
    cpu_s = _time(fn, x, dt, a, bm, cm, dsk)
    Q = 128
    flops = B * Hs * (L * Q * N + L * Q * P + L * N * P) * 2.0 * 2
    bytes_m = (x.size + bm.size + cm.size) * 4.0
    rows.append(("kernel/ssd_scan/L1024", cpu_s * 1e6,
                 f"tpu_roofline_us={_roofline_us(flops, bytes_m):.1f}"))

    # --- online-softmax merge (RU stage) -----------------------------------
    from repro.core import online_softmax as osm
    T = 16
    o = jax.random.normal(key, (T, B, H, d))
    m = jax.random.normal(key, (T, B, H))
    l = jax.random.uniform(key, (T, B, H)) + 0.5
    fn = jax.jit(lambda o, m, l: osm.finalize(
        osm.merge_many(osm.AttnPartial(o, m, l))))
    cpu_s = _time(fn, o, m, l)
    bytes_m = o.size * 4.0 * 2
    rows.append(("kernel/ru_merge/T16", cpu_s * 1e6,
                 f"tpu_roofline_us={_roofline_us(0, bytes_m):.2f} "
                 f"(<2%-of-attention check)"))
    return rows
