"""Data pipeline: deterministic synthetic token streams + file-backed
corpora, sharded per data-parallel rank."""

from repro.data.pipeline import (SyntheticLM, FileCorpus, make_batch_specs,
                                 shard_for_rank)

__all__ = ["SyntheticLM", "FileCorpus", "make_batch_specs", "shard_for_rank"]
