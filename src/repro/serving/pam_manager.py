"""PAM KV-centric management for the serving engine (paper §6 end-to-end).

Holds, per running sequence: per-token importance (eq. 7 EMA), per-token
tier residency (HBM/DDR/SSD), and the retrieval-sparsity participation
mask. Each decode step:

  1. ``participation()``      -> which tokens are loaded (top-S/c + recency)
  2. model decode step        -> attention out + per-token mass S_i(j)
  3. ``observe(scores)``      -> importance EMA update, append new token
     (new tokens enter the hot tier; overflow demotes the least-important
     hot token — capacity cascade), activation-window tracking (§6.1)
  4. every ``schedule_interval`` steps: Algorithm 2 swaps (vmapped over the
     batch) + migration stats for the perf model (§6.2 interface traffic)

The attention itself runs through ``make_masked_decode_attn`` — exact
masked softmax over participating tokens, which the core/kernels property
tests certify equals the per-tier-partition + hierarchical-merge form of
Alg. 1. Tier residency feeds the latency/energy model (per-tier token
counts = per-tier bytes read).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import importance as imp_mod
from repro.core import scheduling
from repro.core.tiers import COLD, HOT, WARM


@dataclasses.dataclass(frozen=True)
class PAMManagerConfig:
    max_tokens: int
    hot_capacity: int                # tokens per sequence on HBM
    warm_capacity: int               # tokens per sequence on DDR
    compression: int = 8             # retrieval sparsity (paper: 8x)
    recency_window: int = 32
    lam: float = imp_mod.DEFAULT_LAMBDA
    schedule_interval: int = 4       # decode steps between Alg. 2 runs
    schedule: scheduling.ScheduleConfig = scheduling.ScheduleConfig()
    use_sparsity: bool = True
    use_tiering: bool = True


class PAMState(NamedTuple):
    """Per-batch device-side PAM bookkeeping, donated through the fused
    decode dispatch every step.

    ``block_table`` is the paged-KV mapping of the serving fast path:
    physical pool block per (sequence, logical block), written once at
    admission from the host ``BlockAllocator`` and read by the in-kernel
    gather each step. It is size-0 when the engine runs dense-only.
    Since the pool is shared across tiers, Alg. 2 migrations edit only
    ``tier`` — the table itself never changes during decode.
    """
    importance: jax.Array    # (B, Smax) fp32 — eq. 7 EMA
    tier: jax.Array          # (B, Smax) int32 — HOT/WARM/COLD residency
    step: jax.Array          # scalar int32
    moved_tokens: jax.Array  # scalar int32 — cumulative Alg.2 migrations
    last_hot: jax.Array      # (B, Smax) bool — previous participation set
    block_table: jax.Array   # (B, Smax//bs) int32 physical ids, or (0,)


def init_pam_state(batch: int, max_tokens: int, num_blocks: int = 0,
                   sentinel: int = 0) -> PAMState:
    """Zero state. ``num_blocks`` > 0 sizes the per-sequence block table
    (all entries pointing at the pool's ``sentinel`` trash block)."""
    if num_blocks:
        table = jnp.full((batch, num_blocks), sentinel, jnp.int32)
    else:
        table = jnp.zeros((0,), jnp.int32)
    return PAMState(
        importance=jnp.zeros((batch, max_tokens), jnp.float32),
        tier=jnp.full((batch, max_tokens), COLD, jnp.int32),
        step=jnp.zeros((), jnp.int32),
        moved_tokens=jnp.zeros((), jnp.int32),
        last_hot=jnp.zeros((batch, max_tokens), bool),
        block_table=table,
    )


# --------------------------------------------------------------- attention
def make_masked_decode_attn(participate: jax.Array):
    """Decode-attn factory: masks non-participating tokens (sparsity +
    tier-partition union). participate: (B, Smax) traced array.

    Delegates to the repeat-free grouped GQA path (``ops.
    masked_decode_attention``): Pallas ``flash_decode`` + merge on TPU, a
    single grouped einsum elsewhere — no ``jnp.repeat`` of the KV cache."""
    def d_fn(q, k_cache, v_cache, kv_lens):
        from repro.kernels import ops as kops
        return kops.masked_decode_attention(q, k_cache, v_cache,
                                            participate, kv_lens)

    return d_fn


def make_paged_decode_attn(hot_mask: jax.Array, paged_mask: jax.Array,
                           block_table: jax.Array, block_live: jax.Array):
    """Paged decode-attn factory for the block-table fast path.

    ``hot_mask``/``paged_mask``: (B, Smax) — the participation set split
    by tier residency (hot reads stay on the dense kernel-ready cache;
    warm/cold reads gather the shared pool through ``block_table``).
    ``block_table``: (B, nb) physical ids with dead logical blocks
    already remapped onto the sentinel; ``block_live``: (B, nb) which
    blocks hold at least one participating warm/cold token — the pages
    the gather actually touches.

    The produced function matches the paged ``decode_attn_fn`` contract
    of ``attention_decode``: ``d_fn(q, kc, vc, pk, pv, kv_lens)`` ->
    (out, mass).
    """
    def d_fn(q, k_cache, v_cache, pk, pv, kv_lens):
        from repro.kernels import ops as kops
        return kops.paged_masked_decode_attention(
            q, k_cache, v_cache, pk, pv, block_table, hot_mask,
            paged_mask, kv_lens, block_live=block_live)

    return d_fn


def paged_participation_split(participate: jax.Array, tier: jax.Array,
                              lengths: jax.Array, block_size: int,
                              hot_window: int = 0
                              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Split one step's participation set by storage tier.

    Returns (hot_mask, paged_mask, block_live): hot tokens read the dense
    hot-tier buffer, warm/cold tokens read the paged pool, and
    ``block_live`` ((B, nb) bool) marks the logical blocks the paged
    gather must touch — ``block_live.sum()`` is the step's pages-read,
    the sparse-read win the benchmarks record.

    ``hot_window`` > 0 is the hot ring's slot count: only positions
    inside the ring window (``>= lengths - hot_window``) have hot-tier
    storage, so hot-tagged tokens outside it fall through to the paged
    side — every participating token is read from exactly one storage.
    0 keeps the legacy full-window split (hot tier sized ``Smax``).
    """
    from repro.serving.paged_kv import token_block_mask
    B, Smax = participate.shape
    pos = jnp.arange(Smax)[None, :]
    valid = pos < lengths[:, None]
    live = participate & valid
    if hot_window:
        in_window = pos >= (lengths[:, None] - hot_window)
        hot_mask = live & (tier == HOT) & in_window
        paged_mask = live & ~((tier == HOT) & in_window)
    else:
        hot_mask = live & (tier == HOT)
        paged_mask = live & (tier != HOT)
    return hot_mask, paged_mask, token_block_mask(paged_mask, block_size)


def make_masked_latent_attn(participate: jax.Array):
    """MLA flavor: masks latent tokens. Signature matches
    ``mla_latent_decode_attn``."""
    def l_fn(q_eff, kv_latent, k_rope, kv_lens, *, scale):
        B, Smax = kv_latent.shape[0], kv_latent.shape[1]
        live = (jnp.arange(Smax)[None, :] < kv_lens[:, None]) & participate
        k_eff = jnp.concatenate([kv_latent, k_rope], axis=-1)
        s = jnp.einsum("bhd,bsd->bhs", q_eff.astype(jnp.float32),
                       k_eff.astype(jnp.float32)) * scale
        s = jnp.where(live[:, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p)
        out = jnp.einsum("bhs,bsr->bhr", p, kv_latent.astype(jnp.float32))
        n_live = jnp.sum(live, axis=-1, keepdims=True).astype(jnp.float32)
        mass = jnp.mean(p, axis=1) * n_live
        return out.astype(q_eff.dtype), mass

    return l_fn


# ------------------------------------------------------- pure state updates
# Module-level pure functions so the serving engine can inline the whole
# per-step PAM pipeline (participation -> decode -> observe -> stats) into
# ONE fused, donated jit. ``PAMManager`` methods below are thin jit'd
# wrappers around these for standalone use.

def participation_mask(cfg: PAMManagerConfig, importance: jax.Array,
                       lengths: jax.Array) -> jax.Array:
    """(B, Smax) bool. Top-(len/c) by importance + recency pins."""
    B, Smax = importance.shape
    valid = jnp.arange(Smax)[None, :] < lengths[:, None]
    if not cfg.use_sparsity:
        return valid
    budget = jnp.maximum(lengths // cfg.compression, 1)     # (B,)
    pos = jnp.arange(Smax)[None, :]
    recent = (pos >= (lengths - cfg.recency_window)[:, None]) & valid
    score = jnp.where(valid, importance, -jnp.inf)
    score = jnp.where(recent, jnp.inf, score)
    ranks = jnp.argsort(jnp.argsort(-score, axis=-1), axis=-1)
    sel = (ranks < budget[:, None]) & valid
    return sel | recent


def observe_update(cfg: PAMManagerConfig, state: PAMState,
                   scores: jax.Array, lengths: jax.Array,
                   participate: jax.Array) -> PAMState:
    """After a decode step: EMA update + hot append + capacity cascade
    + (every interval) Algorithm 2."""
    B, Smax = state.importance.shape
    valid = jnp.arange(Smax)[None, :] < lengths[:, None]

    imp = imp_mod.update_importance(state.importance,
                                    jnp.where(valid, scores, 0.0),
                                    lam=cfg.lam)
    # new token (at index lengths-1 after the model appended) -> HOT,
    # seeded with the current max importance (recency prior).
    bidx = jnp.arange(B)
    new_pos = jnp.maximum(lengths - 1, 0)
    tier = state.tier.at[bidx, new_pos].set(HOT)
    imp = imp.at[bidx, new_pos].set(
        jnp.maximum(imp[bidx, new_pos], jnp.max(imp, axis=-1)))

    if cfg.use_tiering:
        # capacity cascade: demote least-important over-capacity tokens
        tier = _enforce_capacity(imp, tier, valid, HOT,
                                 cfg.hot_capacity, WARM)
        tier = _enforce_capacity(imp, tier, valid, WARM,
                                 cfg.warm_capacity, COLD)

        def run_sched(im, ti, va):
            new_t, moved, _ = scheduling.schedule_kv(im, ti, va,
                                                     cfg.schedule)
            return new_t, jnp.sum(moved)

        def maybe_schedule(ti):
            new_t, moved = jax.vmap(run_sched)(imp, ti, valid)
            return new_t, jnp.sum(moved)

        do = (state.step + 1) % cfg.schedule_interval == 0
        tier, moved = jax.lax.cond(
            do, maybe_schedule,
            lambda ti: (ti, jnp.zeros((), jnp.int32)), tier)
    else:
        moved = jnp.zeros((), jnp.int32)

    return PAMState(importance=imp, tier=tier, step=state.step + 1,
                    moved_tokens=state.moved_tokens + moved,
                    last_hot=participate,
                    block_table=state.block_table)


def place_prefill_state(cfg: PAMManagerConfig, state: PAMState,
                        slot: jax.Array, length: jax.Array,
                        table_row: jax.Array | None = None) -> PAMState:
    """Initial placement for one admitted sequence (recency fill-down,
    §4.3): tail -> HOT, middle -> DDR, head -> SSD. ``table_row``
    ((nb,) physical block ids from the host allocator, sentinel-padded)
    installs the sequence's paged-KV block table in the same dispatch."""
    Smax = state.importance.shape[1]
    idx = jnp.arange(Smax)
    valid = idx < length
    dist = jnp.maximum(length - 1 - idx, 0)
    tier = jnp.where(dist < cfg.hot_capacity, HOT,
                     jnp.where(dist < cfg.hot_capacity
                               + cfg.warm_capacity, WARM, COLD))
    imp = jnp.where(valid, 1.0 / (1.0 + dist.astype(jnp.float32)), 0.0)
    state = state._replace(
        importance=state.importance.at[slot].set(imp),
        tier=state.tier.at[slot].set(tier.astype(jnp.int32)),
        last_hot=state.last_hot.at[slot].set(False),
    )
    if table_row is not None:
        state = state._replace(
            block_table=state.block_table.at[slot].set(table_row))
    return state


def extract_slot_state(state: PAMState, slot) -> tuple[jax.Array, ...]:
    """One sequence's migratable PAM state: (importance, tier, last_hot)
    rows. The block table row is deliberately excluded — physical block
    ids are device-local and rebuilt by the importing engine's own
    allocator (see ``repro.cluster.migration``)."""
    return (state.importance[slot], state.tier[slot], state.last_hot[slot])


def insert_slot_state(state: PAMState, slot, importance: jax.Array,
                      tier: jax.Array, last_hot: jax.Array,
                      table_row: jax.Array | None = None) -> PAMState:
    """Install one migrated sequence's PAM rows at ``slot`` (the inverse
    of ``extract_slot_state``). ``table_row`` — the *importing* engine's
    freshly-allocated physical block ids — is written when the target
    runs the paged KV path."""
    state = state._replace(
        importance=state.importance.at[slot].set(importance),
        tier=state.tier.at[slot].set(tier),
        last_hot=state.last_hot.at[slot].set(last_hot),
    )
    if table_row is not None:
        state = state._replace(
            block_table=state.block_table.at[slot].set(table_row))
    return state


def tier_read_counts_of(tier: jax.Array, participate: jax.Array
                        ) -> jax.Array:
    """(3,) tokens read per tier this step — bytes = counts x token
    bytes; drives the per-tier roofline in the perf model."""
    return jnp.stack([jnp.sum(participate & (tier == t))
                      for t in (HOT, WARM, COLD)])


def hit_rate_of(last_hot: jax.Array, participate: jax.Array) -> jax.Array:
    """Context locality: fraction of this step's working set that was
    also in the previous step's (paper §3.2)."""
    inter = jnp.sum(last_hot & participate, axis=-1)
    denom = jnp.maximum(jnp.sum(participate, axis=-1), 1)
    return jnp.mean(inter / denom)


# ------------------------------------------------------------------ manager
class PAMManager:
    """Stateless-jit wrapper around PAMState transitions."""

    def __init__(self, cfg: PAMManagerConfig):
        self.cfg = cfg

    # -- step 1: which tokens participate this step -----------------------
    @partial(jax.jit, static_argnames=("self",))
    def participation(self, state: PAMState, lengths: jax.Array
                      ) -> jax.Array:
        return participation_mask(self.cfg, state.importance, lengths)

    # -- steps 3+4: importance update, append, schedule --------------------
    @partial(jax.jit, static_argnames=("self",))
    def observe(self, state: PAMState, scores: jax.Array,
                lengths: jax.Array, participate: jax.Array) -> PAMState:
        return observe_update(self.cfg, state, scores, lengths, participate)

    # -- prefill placement --------------------------------------------------
    @partial(jax.jit, static_argnames=("self",))
    def place_prefill(self, state: PAMState, slot: jax.Array,
                      length: jax.Array) -> PAMState:
        return place_prefill_state(self.cfg, state, slot, length)

    # -- stats for the latency/energy model ---------------------------------
    @partial(jax.jit, static_argnames=("self",))
    def tier_read_counts(self, state: PAMState, participate: jax.Array
                         ) -> jax.Array:
        return tier_read_counts_of(state.tier, participate)

    def hit_rate(self, state: PAMState, participate: jax.Array) -> jax.Array:
        return hit_rate_of(state.last_hot, participate)


def _enforce_capacity(imp, tier, valid, t_from: int, cap: int, t_to: int):
    """Demote lowest-importance tokens of tier ``t_from`` past ``cap``."""
    on = (tier == t_from) & valid                       # (B, S)
    count = jnp.sum(on, axis=-1, keepdims=True)
    score = jnp.where(on, imp, jnp.inf)
    ranks = jnp.argsort(jnp.argsort(score, axis=-1), axis=-1)  # asc
    overflow = jnp.maximum(count - cap, 0)
    demote = on & (ranks < overflow)
    return jnp.where(demote, t_to, tier)
