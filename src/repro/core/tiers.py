"""Memory-tier abstraction for the PAM hierarchy (paper §4.1, Table 1).

A ``TierSpec`` captures the physical properties the paper's simulator uses:
capacity, read bandwidth available to attention (aggregate PIM bandwidth),
near-memory compute throughput, and the inter-tier link bandwidth used for
KV migration. ``TieredKVState`` tracks per-token tier residency + importance
for one sequence; it is a pytree so schedulers can be jit'd.

Default tier constants follow Table 1 / §7.1 of the paper:
  HBM-PIM : 640 GB cap, internal bw ~ 5.2 Gbps * 1024 bus ... aggregated
            near-bank bandwidth taken as 6.4 TB/s per stack-group,
            compute 1.6 TFLOPS/device
  DDR-PIM : 1280 GB cap, aggregate near-bank bw 1.6 TB/s, 204 GFLOPS/device
  SSD-PIM : 8 TB cap, controller bw 100 GB/s (paper: "<100 GB/s"),
            18 GFLOPS/device
Values are configurable — "PAM's architecture is orthogonal to specific
configurations" (§7.1).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

HOT, WARM, COLD = 0, 1, 2
TIER_NAMES = ("hbm", "ddr", "ssd")


@dataclasses.dataclass(frozen=True)
class TierSpec:
    name: str
    capacity_bytes: float          # KV capacity of this tier
    read_bw: float                 # aggregate near-memory read bandwidth B/s
    compute_flops: float           # near-memory compute throughput FLOP/s
    link_bw: float                 # migration bandwidth to adjacent tier B/s
    energy_pj_per_byte: float      # access energy (for Fig. 11 benchmark)

    def attention_time(self, bytes_read: float, flops: float) -> float:
        """Roofline time for a local-attention pass on this tier."""
        return max(bytes_read / self.read_bw, flops / self.compute_flops)

    @property
    def effective_bw(self) -> float:
        """Attention-effective bandwidth: decode attention does ~1 flop per
        KV byte, so the tier runs at min(read bw, PU flops)."""
        return min(self.read_bw, self.compute_flops)


# Paper Table-1-derived NODE-level defaults (40xHBM, 40xDDR, 64ch SSD).
# read_bw = aggregate near-bank/controller bandwidth (AttAcc-style 9x over
# a DGX's 16 TB/s for HBM-PIM); compute = power-capped PU throughput
# (1.6T/204G/18G FLOPS per device, §7.1) — decode attention at ~1 flop/byte
# is COMPUTE-capped on HBM-PIM and bandwidth-capped on SSD-PIM.
HBM_PIM = TierSpec("hbm", capacity_bytes=640e9, read_bw=144e12,
                   compute_flops=40 * 1.6e12, link_bw=64e9,
                   energy_pj_per_byte=3.5)
DDR_PIM = TierSpec("ddr", capacity_bytes=1280e9, read_bw=8.2e12,
                   compute_flops=40 * 204e9, link_bw=32e9,
                   energy_pj_per_byte=15.0)
SSD_PIM = TierSpec("ssd", capacity_bytes=8e12, read_bw=100e9,
                   compute_flops=64 * 18e9, link_bw=8e9,
                   energy_pj_per_byte=60.0)

DEFAULT_TIERS: tuple[TierSpec, ...] = (HBM_PIM, DDR_PIM, SSD_PIM)


@jax.tree_util.register_pytree_node_class
class TieredKVState:
    """Per-sequence token->tier residency + importance (device arrays).

    tier_of_token: (max_tokens,) int32 in {HOT, WARM, COLD}
    importance:    (max_tokens,) float32, eq. (7) EMA
    valid:         (max_tokens,) bool — token exists
    """

    def __init__(self, tier_of_token: jax.Array, importance: jax.Array,
                 valid: jax.Array):
        self.tier_of_token = tier_of_token
        self.importance = importance
        self.valid = valid

    @classmethod
    def create(cls, max_tokens: int) -> "TieredKVState":
        return cls(
            tier_of_token=jnp.zeros((max_tokens,), jnp.int32),
            importance=jnp.zeros((max_tokens,), jnp.float32),
            valid=jnp.zeros((max_tokens,), bool),
        )

    # -- pytree plumbing -------------------------------------------------
    def tree_flatten(self):
        return (self.tier_of_token, self.importance, self.valid), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- queries ----------------------------------------------------------
    @property
    def max_tokens(self) -> int:
        return self.tier_of_token.shape[0]

    def tokens_on_tier(self, tier: int) -> jax.Array:
        return jnp.sum((self.tier_of_token == tier) & self.valid)

    def tier_counts(self, num_tiers: int = 3) -> jax.Array:
        return jax.ops.segment_sum(
            self.valid.astype(jnp.int32), self.tier_of_token,
            num_segments=num_tiers)


def clamp_hot_to_window(tier: jax.Array, lengths: jax.Array,
                        window: int) -> jax.Array:
    """Demote HOT tokens that slid out of the hot-window ring (PR 5).

    With a ring-buffered hot tier only the last ``window`` positions of a
    sequence have hot-tier storage; a token at position ``p < lengths -
    window`` was overwritten by the append that evicted it (its bytes
    live on in its mapped pool block), so a HOT tag there is stale — this
    re-tags it WARM. Demotion through the ring is therefore a *tag* edit:
    the eviction itself already happened in the append's overwrite.

    tier: (B, S) int32; lengths: (B,) int32. Returns the clamped tags.
    Alg. 2 promotions of out-of-window tokens are likewise undone here —
    a token with no ring slot cannot be hot-tier resident, however
    important; it stays a capacity-tier (block-table) read.
    """
    B, S = tier.shape
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    out_of_window = pos < (lengths[:, None] - window)
    return jnp.where(out_of_window & (tier == HOT), WARM, tier)


def block_residency(tier_of_token: jax.Array, valid: jax.Array,
                    block_size: int) -> jax.Array:
    """Per-block tier residency for the paged pool view.

    tier_of_token/valid: (..., tokens) with ``tokens % block_size == 0``.
    Returns (..., tokens // block_size) int32: the HOTTEST (minimum) tier
    id among a block's valid tokens — a page must be served by the
    fastest tier any of its tokens resides on — or COLD for empty blocks.

    Analysis/capacity-accounting view for tools and tests; the decode
    path itself never needs it (per-token tier masks reach the kernel
    directly, and the engine derives its pages-touched stats from
    ``paged_kv.token_block_mask``).
    """
    shape = tier_of_token.shape[:-1] + (-1, block_size)
    t = jnp.where(valid, tier_of_token, COLD).reshape(shape)
    return jnp.min(t, axis=-1).astype(jnp.int32)


def blocks_per_tier(tier_of_token: jax.Array, valid: jax.Array,
                    block_size: int, num_tiers: int = 3) -> jax.Array:
    """(num_tiers,) count of pool blocks whose residency is each tier —
    per-tier page populations for capacity accounting (paper Table 1)."""
    res = block_residency(tier_of_token, valid, block_size)
    occupied = valid.reshape(valid.shape[:-1] + (-1, block_size)).any(-1)
    return jnp.stack([jnp.sum((res == t) & occupied)
                      for t in range(num_tiers)])


def initial_placement(num_tokens: int, max_tokens: int,
                      tier_capacity_tokens: Sequence[int]) -> TieredKVState:
    """Fill-down placement after prefill (§4.3): newest tokens are hottest.

    The paper observes critical tokens cluster near the current token
    (Fig. 3), so prefill places the tail of the context in HBM, the middle in
    DDR, and the head in SSD, respecting capacities.
    """
    idx = jnp.arange(max_tokens)
    valid = idx < num_tokens
    # distance from the sequence tail (newest token = 0)
    dist = jnp.maximum(num_tokens - 1 - idx, 0)
    cap_h, cap_d = tier_capacity_tokens[0], tier_capacity_tokens[1]
    tier = jnp.where(dist < cap_h, HOT, jnp.where(dist < cap_h + cap_d,
                                                  WARM, COLD))
    # recency prior as the initial importance signal
    imp = jnp.where(valid, 1.0 / (1.0 + dist.astype(jnp.float32)), 0.0)
    return TieredKVState(tier_of_token=tier.astype(jnp.int32),
                         importance=imp, valid=valid)
