"""Benchmark harness entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--section figs|kernels|engine|roofline]
"""

from __future__ import annotations

import argparse


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=["all", "figs", "kernels", "engine",
                             "roofline"])
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    args = ap.parse_args(argv)

    rows: list[tuple] = []
    if args.section in ("all", "figs"):
        from benchmarks import paper_figs
        rows += paper_figs.fig9_online_slo()
        rows += paper_figs.fig10_offline()
        rows += paper_figs.fig11_energy()
        rows += paper_figs.fig12_ablation()
        rows += paper_figs.fig13_scalability()
        rows += paper_figs.headline_claims()
    if args.section in ("all", "kernels"):
        from benchmarks.kernel_bench import bench_kernels
        rows += bench_kernels()
    if args.section in ("all", "engine"):
        from benchmarks.engine_bench import bench_engine
        rows += bench_engine()
    if args.section in ("all", "roofline"):
        from benchmarks.roofline import roofline_rows
        rows += roofline_rows(args.dryrun_dir)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
