"""Distributed tests. Multi-device checks run in a subprocess so the fake
8-device XLA flag never leaks into this session (smoke tests & benches must
see 1 device). Host-side elastic logic is tested inline."""

import os
import subprocess
import sys

import pytest

from repro.distributed.elastic import (HeartbeatLedger, StragglerMonitor,
                                       plan_recovery, rescale_batch)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_multi_device_suite():
    """shard_map PAMattention, sharded train step, pipeline, elastic
    restore — all on 8 fake devices in a subprocess."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests",
                                      "distributed_checks.py")],
        env=env, capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    assert "ALL DISTRIBUTED CHECKS PASSED" in out.stdout


# ------------------------------------------------------------ host logic
def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(threshold=2.0, patience=2)
    for step in range(4):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 5.0)
        mon.observe_step()
        flagged = mon.stragglers()
    assert flagged == [2]


def test_straggler_monitor_forgives_transient():
    mon = StragglerMonitor(threshold=2.0, patience=3)
    for h in range(4):
        mon.record(h, 1.0 if h != 1 else 10.0)   # one bad step
    mon.observe_step()
    assert mon.stragglers() == []
    for h in range(4):
        mon.record(h, 1.0)
    mon.observe_step()
    assert mon.stragglers() == []


def test_straggler_query_is_pure():
    """Regression: ``stragglers()`` must NOT mutate strike counters —
    historically the query itself evaluated-and-bumped, so polling it
    twice per step double-counted and halved the effective patience."""
    mon = StragglerMonitor(threshold=2.0, patience=4)
    for step in range(2):
        for h in range(3):
            mon.record(h, 1.0 if h != 0 else 9.0)
        mon.observe_step()
        for _ in range(5):               # poll freely: no side effects
            assert mon.stragglers() == []
    assert mon._strikes[0] == 2          # one strike per observe_step
    for step in range(2):
        for h in range(3):
            mon.record(h, 1.0 if h != 0 else 9.0)
        mon.observe_step()
    assert mon.stragglers() == [0]       # patience reached exactly now


def test_straggler_recovered_host_resets_to_zero():
    """A host that speeds back up after accumulating strikes resets its
    counter to ZERO (not decrement): transient hiccups never add up to
    a false eviction."""
    mon = StragglerMonitor(threshold=2.0, patience=3)
    for _ in range(2):                   # two strikes for host 1
        for h in range(3):
            mon.record(h, 1.0 if h != 1 else 8.0)
        mon.observe_step()
    assert mon._strikes[1] == 2
    for h in range(3):                   # host 1 recovers for one step
        mon.record(h, 1.0)
    mon.observe_step()
    assert mon._strikes[1] == 0
    for _ in range(2):                   # two NEW strikes: still < patience
        for h in range(3):
            mon.record(h, 1.0 if h != 1 else 8.0)
        mon.observe_step()
    assert mon.stragglers() == []


def test_straggler_single_host_never_flags():
    """A single-host fleet has no cross-host median to straggle from —
    it must never be flagged, no matter how slow its steps get."""
    mon = StragglerMonitor(threshold=2.0, patience=1)
    for t in (1.0, 50.0, 500.0):
        mon.record(0, t)
        mon.observe_step()
    assert mon.stragglers() == []


def test_heartbeat_ledger():
    hb = HeartbeatLedger(dead_after=3)
    for s in range(5):
        hb.beat(0, s)
        if s < 2:
            hb.beat(1, s)
    assert hb.dead_hosts() == [1]


def test_heartbeat_silent_then_returning_host_leaves_dead_list():
    """A host silent long enough to be presumed dead rejoins the fleet
    on its next beat (network partition healed) — ``dead_hosts()`` must
    drop it rather than latch the verdict."""
    hb = HeartbeatLedger(dead_after=3)
    for s in range(6):
        hb.beat(0, s)
        if s == 0:
            hb.beat(1, s)
    assert hb.dead_hosts() == [1]
    hb.beat(1, 6)                        # the partition heals
    hb.beat(0, 6)
    assert hb.dead_hosts() == []


def test_heartbeat_ledger_advances_without_beats():
    """``advance`` moves the ledger clock with nobody reporting — the
    serving watchdog's wait-on-a-hung-device path, in fractional
    sim-clock seconds."""
    hb = HeartbeatLedger(dead_after=0.25)
    hb.beat(0, 0.0)
    hb.beat(1, 0.0)
    hb.advance(0.2)
    assert hb.dead_hosts() == []
    hb.beat(0, 0.3)                      # host 0 alive; host 1 silent
    assert hb.dead_hosts() == [1]


def test_plan_recovery_truncates_to_replicas():
    devices = list(range(32))           # 4 hosts x 8
    kept, info = plan_recovery(devices, failed_hosts={3},
                               model_parallel=16, devices_per_host=8)
    assert len(kept) == 16              # 24 survivors -> 1 replica of 16
    assert info["new_dp"] == 1
    assert info["lost_devices"] == 8
    assert info["idle_devices"] == 8


def test_plan_recovery_raises_when_too_small():
    with pytest.raises(RuntimeError):
        plan_recovery(list(range(8)), failed_hosts={0},
                      model_parallel=16, devices_per_host=8)


def test_rescale_batch_keeps_global():
    per, accum = rescale_batch(global_batch=256, old_dp=16, new_dp=8)
    assert per == 16 and accum == 2     # same global via 2x accumulation
