"""Continuous-batching front end (PR 8): load generator, async
streaming server, SLO admission, and the router hooks they drive.

Pinned here:
  * traces are seeded-deterministic, time-ordered, and match their
    statistical shape (gamma burstier than poisson, onoff arrivals
    confined to ON windows);
  * scoring counts TTFT/TPOT/attainment the way the bench relies on,
    and stream integrity catches lost and duplicated tokens;
  * every stream the server emits is bit-identical to a direct engine
    run of the same requests — the front end adds latency accounting,
    never tokens;
  * the NDJSON endpoint round-trips concurrent streams exactly;
  * SLO admission sheds provably-late requests and force-preempts for
    a starving head, and disarms shedding in wall-clock mode;
  * ``ClusterRouter.shed`` / ``force_preempt`` touch only what their
    contracts say (queued requests; recovery-backed fleets).
"""

import asyncio
import json

import numpy as np
import pytest

from conftest import build_model, make_engine, make_pam
from repro.frontend.admission import SLOAdmission, SLOSpec
from repro.frontend.loadgen import (TRACE_KINDS, TraceConfig, make_trace,
                                    score, stream_integrity)
from repro.frontend.server import (AsyncServer, StreamRecord,
                                   single_device_router)
from repro.perfmodel import make_latency_model
from repro.perfmodel.model import PAM_LLAMA_7B, make_system
from repro.serving import Request


def _latency():
    return make_latency_model(make_system("pam"), PAM_LLAMA_7B)


def _engine(max_batch=4, max_len=96, chunk=8, latency="model", **kw):
    cfg, params = build_model()
    lat = _latency() if latency == "model" else latency
    return cfg, make_engine(cfg, params, pam=make_pam(max_len=max_len,
                                                      hot=12, warm=24),
                            latency=lat, max_batch=max_batch,
                            max_len=max_len, block_size=8,
                            prefill_chunk=chunk, **kw)


def _twin_outputs(tcfg, max_batch=4, max_len=96, chunk=8):
    """Direct engine run of the same trace: the exactness reference."""
    _, twin = _engine(max_batch=max_batch, max_len=max_len, chunk=chunk)
    for r in make_trace(tcfg):
        twin.submit(Request(id=r.id, prompt=r.prompt,
                            max_new_tokens=r.max_new_tokens))
    twin.run()
    return {rid: rs.outputs for rid, rs in twin.requests.items()}


# ------------------------------------------------------------------ loadgen
@pytest.mark.parametrize("kind", TRACE_KINDS)
def test_trace_deterministic_and_time_ordered(kind):
    tcfg = TraceConfig(kind=kind, n_requests=64, rate_rps=100.0,
                       prompt_len=(4, 20), max_new=(2, 9), seed=5,
                       first_id=10)
    a, b = make_trace(tcfg), make_trace(tcfg)
    assert [r.id for r in a] == list(range(10, 74))
    for ra, rb in zip(a, b):
        assert ra.arrival == rb.arrival
        assert ra.max_new_tokens == rb.max_new_tokens
        assert np.array_equal(ra.prompt, rb.prompt)
        assert 4 <= len(ra.prompt) <= 20 and 2 <= ra.max_new_tokens <= 9
    arr = [r.arrival for r in a]
    assert arr == sorted(arr) and arr[0] > 0


def test_gamma_burstier_than_poisson():
    def cv2(kind, **kw):
        t = np.array([r.arrival for r in make_trace(TraceConfig(
            kind=kind, n_requests=4000, rate_rps=100.0, seed=1, **kw))])
        g = np.diff(t)
        return float(np.var(g) / np.mean(g) ** 2)

    assert 0.7 < cv2("poisson") < 1.4      # memoryless: CV^2 ~= 1
    assert cv2("gamma", burstiness=4.0) > 2.5


def test_onoff_arrivals_confined_to_on_windows():
    tcfg = TraceConfig(kind="onoff", n_requests=300, rate_rps=80.0,
                       duty_cycle=0.25, period_s=1.0, seed=2)
    phase = np.array([r.arrival for r in make_trace(tcfg)]) % 1.0
    assert np.all(phase < 0.25 + 1e-9)


def test_trace_validation_errors():
    for bad in (TraceConfig(kind="weibull"),
                TraceConfig(rate_rps=0.0),
                TraceConfig(kind="gamma", burstiness=-1.0),
                TraceConfig(kind="onoff", duty_cycle=1.5),
                TraceConfig(prompt_len=(9, 4)),
                TraceConfig(max_new=(0, 4))):
        with pytest.raises(ValueError):
            make_trace(bad)


def _rec(rid, arrival, times, indices=None, done=True, rejected=False):
    times = list(times)
    return StreamRecord(
        rid=rid, arrival=arrival, prompt_len=8, max_new=len(times),
        tokens=[100 + i for i in range(len(times))], times=times,
        indices=list(indices if indices is not None
                     else range(len(times))),
        done=done, rejected=rejected)


def test_score_counts_attainment_and_integrity():
    records = [
        _rec(0, 0.0, [0.1, 0.2, 0.3]),                 # attains
        _rec(1, 0.0, [], rejected=True),               # rejected
        _rec(2, 0.0, [0.9, 1.0, 1.1], indices=[0, 2, 3]),   # lost idx 1
        _rec(3, 0.0, [0.9, 1.0, 1.1], indices=[0, 1, 1]),   # dup idx 1
        _rec(4, 0.0, [0.05], done=False),              # never finished
    ]
    assert stream_integrity(records) == (1, 1)
    sc = score(records, ttft_slo_s=0.15, tpot_slo_s=0.15)
    assert sc["n"] == 5 and sc["finished"] == 3 and sc["rejected"] == 1
    assert sc["lost_tokens"] == 1 and sc["dup_tokens"] == 1
    # only rid 0 is finished AND inside both budgets
    assert sc["slo_attainment"] == pytest.approx(1 / 5)
    # percentiles go through the obs log-bucket histogram: exact to
    # within one bucket width (~7.5% at 32 buckets/decade), and exact
    # when every sample shares a value (min/max clamp)
    assert sc["ttft_s"]["p50"] == pytest.approx(0.9, rel=0.08)
    assert sc["ttft_s"]["n"] == 3                      # of [.1, .9, .9]
    assert sc["tpot_s"]["p50"] == pytest.approx(0.1)
    # pooled gaps: six decode gaps of 0.1 across the finished streams
    assert sc["itl_s"]["p99"] == pytest.approx(0.1)


def test_score_empty_is_neutral():
    sc = score([], ttft_slo_s=1.0, tpot_slo_s=1.0)
    assert sc["n"] == 0 and sc["slo_attainment"] == 1.0
    # NaN-safe zeros carry the explicit empty marker: zeros mean
    # "no samples", never "zero latency"
    assert sc["ttft_s"] == {"p50": 0.0, "p95": 0.0, "p99": 0.0, "n": 0}


# ------------------------------------------------------------------- server
def test_server_streams_match_direct_engine_run():
    tcfg = TraceConfig(kind="poisson", n_requests=8, rate_rps=200.0,
                       prompt_len=(6, 40), max_new=(3, 10), seed=7)
    _, eng = _engine()
    srv = AsyncServer(eng)
    records = asyncio.run(srv.serve_trace(make_trace(tcfg)))
    twin = _twin_outputs(tcfg)
    assert set(records) == set(twin)
    for rid, rec in records.items():
        assert rec.done and not rec.rejected
        assert rec.tokens == twin[rid]
        assert rec.indices == list(range(len(rec.tokens)))
        assert rec.times == sorted(rec.times)
        assert rec.times[0] >= rec.arrival
    assert stream_integrity(records.values()) == (0, 0)
    assert srv.summary()["requests"] == 8


def test_stream_handle_iterates_live_events():
    _, eng = _engine()
    srv = AsyncServer(eng, ticks_per_yield=1)

    async def run():
        rng = np.random.default_rng(0)
        h = srv.submit(rng.integers(0, 1000, 12), 6)

        async def collect():
            return [ev async for ev in h]

        evs, _ = await asyncio.gather(collect(), srv.drain())
        return h.record, evs

    rec, evs = asyncio.run(run())
    assert [ev.token for ev in evs] == rec.tokens and len(evs) == 6
    assert [ev.index for ev in evs] == list(range(6))
    assert evs[-1].done and not any(ev.rejected for ev in evs)


def test_duplicate_rid_rejected_at_submit():
    _, eng = _engine()
    srv = AsyncServer(eng)
    srv.submit([1, 2, 3], 2, rid=5)
    with pytest.raises(ValueError):
        srv.submit([4, 5, 6], 2, rid=5)


def test_unserviceable_request_rejects_synchronously():
    _, eng = _engine(max_len=64)
    srv = AsyncServer(eng)
    h = srv.submit(np.zeros(200, np.int32), 4)   # window 204 > 64

    async def collect():
        return [ev async for ev in h]

    evs = asyncio.run(collect())                 # no pump needed
    assert len(evs) == 1 and evs[0].rejected and evs[0].done
    assert h.record.rejected and h.record.tokens == []
    assert srv.summary()["rejected"] == 1


def test_ndjson_endpoint_streams_exactly():
    tcfg = TraceConfig(kind="poisson", n_requests=4, rate_rps=500.0,
                       prompt_len=(6, 24), max_new=(3, 8), seed=9)
    reqs = make_trace(tcfg)
    twin = _twin_outputs(tcfg)
    _, eng = _engine()
    srv = AsyncServer(eng, ticks_per_yield=1)

    async def client(port, req):
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        writer.write(json.dumps({
            "prompt": [int(t) for t in req.prompt],
            "max_new_tokens": req.max_new_tokens,
            "id": req.id}).encode() + b"\n")
        await writer.drain()
        evs = []
        while True:
            line = await reader.readline()
            if not line:
                break
            evs.append(json.loads(line))
            if evs[-1]["done"]:
                break
        writer.close()
        return evs

    async def run():
        server, port, pump = await srv.serve_endpoint()
        try:
            return await asyncio.gather(*(client(port, r) for r in reqs))
        finally:
            pump.cancel()
            server.close()
            await server.wait_closed()

    streams = asyncio.run(run())
    for req, evs in zip(reqs, streams):
        assert [ev["token"] for ev in evs] == twin[req.id]
        assert [ev["index"] for ev in evs] == list(range(len(evs)))
        assert evs[-1]["done"] and not any(ev["rejected"] for ev in evs)


# ---------------------------------------------------------- SLO admission
def test_slospec_validation():
    for bad in (dict(ttft_s=0.0), dict(tpot_s=-1.0),
                dict(starvation_frac=0.0), dict(starvation_frac=1.0)):
        with pytest.raises(ValueError):
            SLOSpec(**bad)


def test_slo_admission_sheds_under_overload():
    # a burst far beyond one small device's capacity with a tight TTFT
    # budget: admission must shed rather than serve everyone late
    tcfg = TraceConfig(kind="gamma", n_requests=24, rate_rps=5000.0,
                       prompt_len=(16, 48), max_new=(4, 10), seed=3,
                       burstiness=6.0)
    _, eng = _engine(max_batch=2)
    adm = SLOAdmission(SLOSpec(ttft_s=0.02, tpot_s=0.05))
    srv = AsyncServer(eng, admission=adm)
    records = asyncio.run(srv.serve_trace(make_trace(tcfg)))
    sc = score(records.values(), ttft_slo_s=0.02, tpot_slo_s=0.05)
    assert adm.shed > 0
    assert sc["rejected"] == adm.shed
    assert sc["finished"] + sc["rejected"] == tcfg.n_requests
    assert sc["lost_tokens"] == 0 and sc["dup_tokens"] == 0
    # survivors stream bit-identically to a direct run of the SAME
    # requests (shedding changes membership, never tokens)
    _, twin = _engine(max_batch=2)
    for r in make_trace(tcfg):
        if not records[r.id].rejected:
            twin.submit(Request(id=r.id, prompt=r.prompt,
                                max_new_tokens=r.max_new_tokens))
    twin.run()
    for rid, rec in records.items():
        if not rec.rejected:
            assert rec.tokens == twin.requests[rid].outputs


def test_slo_admission_force_preempts_starving_head():
    tcfg = TraceConfig(kind="poisson", n_requests=12, rate_rps=2000.0,
                       prompt_len=(12, 40), max_new=(6, 14), seed=4)
    _, eng = _engine(max_batch=2)
    # generous TTFT (no shedding), aggressive starvation trigger
    adm = SLOAdmission(SLOSpec(ttft_s=10.0, tpot_s=1.0,
                               starvation_frac=0.001,
                               preempt_cooldown_ticks=4))
    srv = AsyncServer(eng, admission=adm)
    records = asyncio.run(srv.serve_trace(make_trace(tcfg)))
    assert adm.forced_preemptions > 0 and adm.shed == 0
    assert all(r.done and not r.rejected for r in records.values())
    assert stream_integrity(records.values()) == (0, 0)
    # suspend/resume is exact: preempted streams still match the twin
    twin = _twin_outputs(tcfg, max_batch=2)
    for rid, rec in records.items():
        assert rec.tokens == twin[rid]


def test_wallclock_mode_disarms_shedding():
    _, eng = _engine(latency=None)        # no model: no provable bound
    router = single_device_router(eng)
    adm = SLOAdmission(SLOSpec(ttft_s=1e-9, tpot_s=1.0))
    rng = np.random.default_rng(0)
    for i in range(6):
        router.submit(Request(id=i, prompt=rng.integers(0, 1000, 8),
                              max_new_tokens=2, arrival=0.0))
    router.tick()
    assert adm._prefill_floor(router) == 0.0
    queued = len(router.queue)
    assert queued > 0
    adm.control(router)
    assert adm.shed == 0 and len(router.queue) == queued
    # no RecoveryManager either: force-preempt must refuse, not crash
    assert adm.forced_preemptions == 0
    while router.tick():
        pass
    assert router.summary()["rejected"] == 0


# ------------------------------------------------------------ router hooks
def test_router_shed_hits_queued_requests_only():
    _, eng = _engine(max_batch=2)
    router = single_device_router(eng)
    rng = np.random.default_rng(1)
    for i in range(5):
        router.submit(Request(id=i, prompt=rng.integers(0, 1000, 8),
                              max_new_tokens=3, arrival=0.0))
    router.tick()                          # 2 admitted, 3 queued
    router.drain_events()
    queued = [r.id for r in router.queue]
    running = [i for i in range(5) if i not in queued]
    assert len(queued) == 3 and len(running) == 2
    assert router.shed(queued[0]) is True
    evs = router.drain_events()
    assert [ev.request_id for ev in evs if ev.rejected] == [queued[0]]
    assert queued[0] not in [r.id for r in router.queue]
    assert router.shed(running[0]) is False     # past admission
    assert router.shed(999) is False            # unknown
    while router.tick():
        pass
    s = router.summary()
    assert s["finished"] == 4 and s["rejected"] == 1


def test_force_preempt_suspends_victim_and_stays_exact():
    cfg, eng = _engine(max_batch=1, max_len=64)
    router = single_device_router(eng, preemptible=True)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, 10) for _ in range(2)]
    for i, p in enumerate(prompts):
        router.submit(Request(id=i, prompt=p, max_new_tokens=10,
                              arrival=0.0))
    while not router.finished and not eng.requests.get(0, None):
        router.tick()
    while 0 in eng.slots and len(eng.requests[0].outputs) < 2:
        router.tick()
    assert router.force_preempt(999) is False   # unknown rid
    assert router.force_preempt(1) is True      # suspends rid 0
    assert [snap.request.id
            for snap, _ in router.recovery.suspended] == [0]
    while router.tick():
        pass
    assert router.summary()["finished"] == 2
    # resume-after-preempt is exact
    _, twin = _engine(max_batch=1, max_len=64)
    for i, p in enumerate(prompts):
        twin.submit(Request(id=i, prompt=p, max_new_tokens=10))
    twin.run()
    for i in range(2):
        assert router.finished[i].outputs == twin.requests[i].outputs


def test_force_preempt_requires_recovery_manager():
    _, eng = _engine(max_batch=1)
    router = single_device_router(eng)      # preemptible=False
    router.submit(Request(id=0, prompt=np.zeros(8, np.int32),
                          max_new_tokens=2, arrival=0.0))
    assert router.force_preempt(0) is False
