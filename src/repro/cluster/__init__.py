"""Multi-device PAM cluster (paper §4.3): heterogeneous-device router,
inter-device KV migration, online load balancing, and fault-tolerant
serving (chaos injection, device-loss recovery, graceful degradation)
over N serving engines."""

from repro.cluster.balancer import BalancerConfig, KVBalancer
from repro.cluster.faults import FaultEvent, FaultInjector, parse_chaos
from repro.cluster.migration import (KVSnapshot, SnapshotCorruption,
                                     can_migrate, migrate)
from repro.cluster.recovery import RecoveryConfig, RecoveryManager
from repro.cluster.router import (ClusterDevice, ClusterRouter,
                                  RouterConfig, TokenEvent, build_cluster)
from repro.cluster.spec import ClusterSpec, ReplicaGroup

__all__ = ["BalancerConfig", "ClusterSpec", "KVBalancer", "KVSnapshot",
           "ReplicaGroup", "SnapshotCorruption", "can_migrate",
           "migrate", "FaultEvent", "FaultInjector", "parse_chaos",
           "RecoveryConfig", "RecoveryManager", "ClusterDevice",
           "ClusterRouter", "RouterConfig", "TokenEvent",
           "build_cluster"]
