"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes per the kernel-test contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels import ops

jax.config.update("jax_platform_name", "cpu")


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ flash_attention
@pytest.mark.parametrize("B,H,Hkv,S,d", [
    (1, 2, 2, 64, 32),       # MHA, one block
    (2, 4, 2, 96, 16),       # GQA, ragged seq vs block
    (1, 8, 1, 200, 64),      # MQA, multi-block with padding
    (2, 2, 2, 130, 8),       # tiny d, cross-block causal boundary
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, H, Hkv, S, d, causal):
    key = jax.random.PRNGKey(B * 100 + H + S)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, S, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d))
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               **_tol(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(7)
    B, H, Hkv, S, d = 1, 4, 2, 128, 32
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, S, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_long_context_stability():
    """Large logits must not overflow (online rescaling)."""
    key = jax.random.PRNGKey(3)
    B, H, S, d = 1, 1, 256, 16
    q = 30.0 * jax.random.normal(jax.random.fold_in(key, 0), (B, H, S, d))
    k = 30.0 * jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, d))
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- flash_decode
@pytest.mark.parametrize("B,H,Hkv,S,d,bs", [
    (1, 4, 4, 128, 32, 64),     # MHA two splits
    (2, 8, 2, 300, 16, 128),    # GQA, padding in last split
    (1, 16, 1, 64, 64, 64),     # MQA single split
    (3, 4, 2, 1024, 8, 256),    # many splits
])
def test_flash_decode_matches_ref(B, H, Hkv, S, d, bs):
    key = jax.random.PRNGKey(S + d)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d))
    mask = jax.random.uniform(jax.random.fold_in(key, 3), (B, S)) < 0.7
    # guarantee at least one live token per row
    mask = mask.at[:, 0].set(True)
    out = ops.decode_attention(q, k, v, mask, block_s=bs, interpret=True)
    want = ref.flash_decode_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_flash_decode_kv_len():
    """kv_len must exclude tokens past the live length even if mask=None."""
    key = jax.random.PRNGKey(9)
    B, H, Hkv, S, d = 2, 4, 4, 96, 16
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d))
    out = ops.decode_attention(q, k, v, None, kv_len=40, block_s=32,
                               interpret=True)
    want = ref.flash_decode_ref(q, k, v, None, kv_len=40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_flash_decode_ragged_kv_lens():
    """Per-sequence dynamic kv_lens (the serving engine's ragged batch)
    folds into the participation mask — equals per-batch masking."""
    key = jax.random.PRNGKey(13)
    B, H, Hkv, S, d = 2, 4, 2, 64, 16
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d))
    lens = jnp.array([50, 17], jnp.int32)
    out = ops.decode_attention(q, k, v, None, kv_lens=lens, block_s=32,
                               interpret=True)
    live = jnp.arange(S)[None, :] < lens[:, None]
    want = ref.flash_decode_ref(q, k, v, live)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_masked_decode_attention_kernel_equals_einsum():
    """ops.masked_decode_attention: Pallas-kernel path (interpret) and the
    grouped-einsum fallback agree on output AND per-token mass."""
    key = jax.random.PRNGKey(17)
    B, H, Hkv, S, d = 2, 4, 2, 48, 16
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d))
    part = jax.random.uniform(jax.random.fold_in(key, 3), (B, S)) < 0.7
    part = part.at[:, 0].set(True)
    lens = jnp.array([40, 23], jnp.int32)
    out_k, mass_k = ops.masked_decode_attention(q, k, v, part, lens,
                                                use_kernel=True)
    out_e, mass_e = ops.masked_decode_attention(q, k, v, part, lens,
                                                use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_e),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(mass_k), np.asarray(mass_e),
                               rtol=1e-4, atol=1e-4)


def test_pam_decode_attention_tiers_equals_dense():
    """Alg. 1 across 3 uneven tier pools == dense attention over the
    concatenated KV — the paper's exactness claim, at kernel level."""
    key = jax.random.PRNGKey(21)
    B, H, Hkv, d = 2, 4, 2, 32
    sizes = (32, 96, 160)     # hot < warm < cold (uneven)
    ks, vs, masks = [], [], []
    for i, s_t in enumerate(sizes):
        ks.append(jax.random.normal(jax.random.fold_in(key, 3 * i), (B, Hkv, s_t, d)))
        vs.append(jax.random.normal(jax.random.fold_in(key, 3 * i + 1), (B, Hkv, s_t, d)))
        m = jax.random.uniform(jax.random.fold_in(key, 3 * i + 2), (B, s_t)) < 0.8
        masks.append(m.at[:, 0].set(True))
    q = jax.random.normal(jax.random.fold_in(key, 99), (B, H, d))

    out = ops.pam_decode_attention(q, list(zip(ks, vs)), masks,
                                   interpret=True)

    k_all = jnp.concatenate(ks, axis=2)
    v_all = jnp.concatenate(vs, axis=2)
    m_all = jnp.concatenate(masks, axis=1)
    want = ref.flash_decode_ref(q, k_all, v_all, m_all)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_dtypes(dtype):
    key = jax.random.PRNGKey(17)
    B, H, Hkv, S, d = 1, 4, 2, 256, 32
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d), dtype)
    out = ops.decode_attention(q, k, v, None, block_s=128, interpret=True)
    want = ref.flash_decode_ref(q, k, v, None)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ------------------------------------------------------------------ ssd_scan
@pytest.mark.parametrize("B,L,H,G,N,P,chunk", [
    (1, 64, 2, 1, 16, 8, 32),     # multi-chunk
    (2, 100, 4, 2, 8, 16, 64),    # padding + groups
    (1, 32, 2, 2, 32, 32, 32),    # single chunk
])
def test_ssd_scan_matches_sequential_ref(B, L, H, G, N, P, chunk):
    key = jax.random.PRNGKey(L + N)
    x = jax.random.normal(jax.random.fold_in(key, 0), (B, L, H, P))
    dt = jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(key, 1), (B, L, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.5)
    b = jax.random.normal(jax.random.fold_in(key, 3), (B, L, G, N)) / np.sqrt(N)
    c = jax.random.normal(jax.random.fold_in(key, 4), (B, L, G, N)) / np.sqrt(N)
    d_skip = jax.random.normal(jax.random.fold_in(key, 5), (H,))
    out = ssd_scan(x, dt, a, b, c, d_skip, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(x, dt, a, b, c, d_skip)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_scan_long_decay_stability():
    """Strong decay over many chunks stays finite and accurate."""
    key = jax.random.PRNGKey(5)
    B, L, H, G, N, P = 1, 256, 2, 1, 16, 8
    x = jax.random.normal(jax.random.fold_in(key, 0), (B, L, H, P))
    dt = jnp.full((B, L, H), 2.0)
    a = jnp.array([-4.0, -0.01])
    b = jax.random.normal(jax.random.fold_in(key, 1), (B, L, G, N)) / 4.0
    c = jax.random.normal(jax.random.fold_in(key, 2), (B, L, G, N)) / 4.0
    d_skip = jnp.zeros((H,))
    out = ssd_scan(x, dt, a, b, c, d_skip, chunk=64, interpret=True)
    want = ref.ssd_scan_ref(x, dt, a, b, c, d_skip)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
