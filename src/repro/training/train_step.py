"""Train-step builder: loss + grad + AdamW, with microbatch gradient
accumulation (the collective/compute overlap unit) and optional int8
gradient compression with error feedback for cross-pod reduction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.training import optim

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: optim.AdamWConfig = optim.AdamWConfig()
    microbatches: int = 1       # grad-accumulation steps per update
    remat: bool = False
    use_kernel: bool = False
    compress_grads: bool = False  # int8 + error feedback (cross-pod DP)
    activation_spec: Any = None   # sequence-parallel residual constraint


class TrainState(NamedTuple):
    params: Pytree
    opt: optim.AdamWState
    error_feedback: Optional[Pytree]   # compression residuals (or None)


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key) -> TrainState:
    params = tf.init_params(cfg, key)
    ef = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
          if tcfg.compress_grads else None)
    return TrainState(params=params, opt=optim.adamw_init(params),
                      error_feedback=ef)


# ------------------------------------------------- int8 grad compression
def compress_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _compress_with_feedback(grads: Pytree, ef: Pytree
                            ) -> tuple[Pytree, Pytree]:
    """1-bit-Adam-style error feedback: quantize (g + residual), carry the
    quantization error to the next step. The all-reduce then moves int8
    (4x fewer bytes on the cross-pod links)."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = compress_int8(target)
        deq = decompress_int8(q, scale)
        return deq.astype(g.dtype), target - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


# ------------------------------------------------------------ train step
def build_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch`` arrays carry a leading microbatch axis when
    ``tcfg.microbatches > 1``: (M, B/M, ...). Gradient accumulation runs as
    a lax.scan over microbatches so each microbatch's backward can overlap
    the previous microbatch's gradient reduction when sharded.
    """

    def loss(params, mb):
        return tf.loss_fn(cfg, params, mb, use_kernel=tcfg.use_kernel,
                          remat=tcfg.remat,
                          activation_spec=tcfg.activation_spec)

    def train_step(state: TrainState, batch: dict[str, jax.Array]):
        params = state.params

        if tcfg.microbatches > 1:
            def acc_body(acc, mb):
                l, g = jax.value_and_grad(loss)(params, mb)
                return jax.tree.map(jnp.add, acc,
                                    (jax.tree.map(
                                        lambda x: x / tcfg.microbatches, g),
                                     )), l

            zero = (jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),)
            (grads,), losses = jax.lax.scan(acc_body, zero, batch)
            loss_val = jnp.mean(losses)
        else:
            loss_val, grads = jax.value_and_grad(loss)(params, batch)

        ef = state.error_feedback
        if tcfg.compress_grads:
            grads, ef = _compress_with_feedback(grads, ef)

        new_params, new_opt, gnorm = optim.adamw_update(
            tcfg.adamw, grads, state.opt, params)
        metrics = {"loss": loss_val, "grad_norm": gnorm,
                   "step": new_opt.step}
        return TrainState(new_params, new_opt, ef), metrics

    return train_step
