"""Sharded single-dispatch engine (PR 10). The multi-device checks run
in a subprocess so the fake 8-device XLA flag never leaks into this
session (every other module must keep seeing 1 device); see
tests/sharded_engine_checks.py for what is pinned."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_sharded_engine_suite():
    """Twin exactness at shard 2/4 (greedy, sampled, micro_steps=8),
    1-dispatch/step + donation, cross-shard migration, replica-group
    param bytes — all on 8 fake devices in a subprocess."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests",
                                      "sharded_engine_checks.py")],
        env=env, capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    assert "ALL SHARDED ENGINE CHECKS PASSED" in out.stdout
