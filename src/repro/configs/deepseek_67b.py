"""deepseek-67b [arXiv:2401.02954; hf] — llama-arch dense GQA."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=102400, d_head=128,
    rope_theta=1e4,
))
