"""minicpm-2b [arXiv:2404.06395; hf] — llama-like MHA; WSD schedule lives
in the trainer (repro.training.optim.wsd_schedule)."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab=122753, d_head=64,
    rope_theta=1e4,
))
