"""The PAM serving engine (paper §4): request pool, continuous batching
with prefill priority, PAM-managed decode loop, SLO accounting.

Control flow is real (host Python over jit'd device steps, like vLLM's
scheduler over CUDA graphs); *hardware timing* is injectable — pass a
``latency_model`` (see ``repro.perfmodel``) to account each step at the
modeled speed of a PAM / L-PIM / vLLM-offloading system, which is exactly
the paper's simulator methodology. Without one, wall-clock is used.

Decode fast path
----------------
The whole per-step PAM pipeline — participation mask, masked decode step,
step-score -> importance EMA, tier-read/hit-rate counters, Alg. 2 (under a
``schedule_interval`` cond) and greedy sampling — is ONE ``jax.jit`` with
``donate_argnums`` for the KV cache, the PAM state and the token vector:
a decode step is a single device dispatch with zero cache copies, and the
host only reads back a small ``StepBufs`` stats/tokens struct. Tokens stay
on device between steps (the sampled token feeds the next dispatch without
a host round-trip), ``run()`` consumes step *t-1*'s buffers while step *t*
runs (async dispatch), and ``micro_steps > 1`` wraps a ``lax.fori_loop``
micro-loop around the fused body so the host is visited only once every k
steps. Sampling is on-device too: ``temperature``/``top_k`` with
PER-REQUEST keys derived in-dispatch as ``fold_in(fold_in(seed, rid),
position)`` (0 = exact greedy argmax) — a request's sampled stream is a
pure function of (seed, rid, positions, logits), independent of batch
composition, slot or step phase, which is what makes migration and
failure replay bit-exact even at temperature > 0 — and ``eos_token >=
0`` folds EOS detection into the dispatch — a slot that samples EOS drops
out of the ``active`` carry, so the micro-loop serves EOS traffic as well.
Prefill lengths are bucketed to powers of two (capping jit-cache blowup)
and admissions sharing a bucket commit as a GROUP: one batched prefill +
one donated multi-slot dispatch for cache scatter + PAM placement + token
seeds.

Cluster hooks
-------------
``export_request``/``import_request`` detach and re-admit a RUNNING
request mid-decode (inter-device KV migration, paper §4.3/§6.2): export
gathers the request's KV into the portable logical layout — hot tokens
from the dense cache, warm/cold THROUGH the block table — and frees the
slot and pool blocks without finishing; import is one donated
admission-style dispatch on the target. ``load_signal``/``can_accept``/
``slot_importance_mass`` feed the router and balancer cost signals
(``repro.cluster``).

Paged warm/cold tiers
---------------------
With ``ServingConfig.block_size > 0`` the warm/cold tiers additionally
live on a shared ``PagedKVPool`` (paper §4.2.2): a host ``BlockAllocator``
maps each request to physical pool blocks at admission (one table write
per request — never per step), the table rides ``PAMState.block_table``
through the donated dispatch, and the fused step splits the participation
set by tier: hot tokens read the dense kernel-ready cache, warm/cold
tokens are gathered from the pool *through the block table* (a kernel
operand — ``flash_decode_paged`` on TPU, a jnp table gather elsewhere)
so pages with no participating token are never touched. Both partials
merge exactly (Alg. 1), the single-dispatch/donation invariants are
unchanged, and ``StepBufs`` additionally reports pages touched vs. the
dense window for the sparse-read accounting. Pool capacity is admission
backpressure: requests wait (instead of erroring) until finished
sequences free their blocks, so a pool smaller than ``max_batch``'s
worst case overcommits gracefully.

Prefix sharing (PR 7)
---------------------
With ``ServingConfig.prefix_cache`` the pool becomes REFCOUNTED and a
``PrefixTrie`` keyed on token ids indexes every committed prompt's
blocks. Admission looks up the prompt's longest cached prefix, ADOPTS
those physical blocks into the new table (refcount +1 — zero prefill
compute for the shared part), prefills only the novel suffix
(``prefill_suffix`` attends over the pool-gathered prefix; exact by
causality), and commits in one donated dispatch. A partially-filled
shared tail block is always duplicated into a fresh block BEFORE the
suffix scatter (copy-on-write); fully-shared interior blocks are never
copied and never written — appends land strictly above the shared
prefix by construction. ``free`` is a decref everywhere (finish,
export, preemption), so shared blocks outlive any individual owner; the
trie holds its own reference per block, which is what keeps prefixes
cached after their publisher finishes, and LRU-evicts trie-only blocks
under pool pressure. Tier-tag migration (Alg. 2) is per-request
metadata, so sharers can tag the same physical block differently —
shared bytes are never touched. Token streams are twin-exact with
from-scratch admission (greedy and sampled — the per-request sampling
keys don't see any of this).

Hot-window ring (PR 5)
----------------------
With ``ServingConfig.hot_window > 0`` the dense hot-tier buffer shrinks
from ``(L, B, Hkv, max_len, dh)`` to a RING ``(L, B, Hkv, W, dh)``:
absolute position ``p`` lives at ring slot ``p % W``, so per-slot
hot-tier bytes are independent of ``max_len`` — the paper's §4.1-4.2
capacity argument (only the hot window needs dense high-bandwidth
storage; warm/cold tokens live ONLY in pool blocks). The per-step
append is one ring write whose overwrite IS the eviction (the evicted
token was mirrored into its mapped pool block when it was appended, in
the same donated dispatch), demotion completes as a tier-tag clamp, and
promotion of an in-window token needs no copy at all — the ring already
holds every in-window position, so Alg. 2 promotions just flip which
storage the split reads. The hot partial reads the ring through the
rotated position map (``kernels.flash_decode.ring_position_map``) and
merges with the paged partial exactly (Alg. 1), so token streams are
bit-for-bit those of the full-window engine; admission commit,
migration export/import and the micro-loop are all rebased onto ring
coordinates while participation, importance and block tables stay
absolute.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
import warnings
from typing import Any, Iterable, Iterator, NamedTuple, Optional, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pam_interface as pam_if
from repro.core import tiers as tiers_mod
from repro.frontend.chunking import ChunkPlan, validate_budget
from repro.core.tiers import HOT
from repro.kernels.flash_decode import ring_position_map
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving import pam_manager as pm
from repro.serving import paged_kv as pkv
from repro.serving.paged_kv import (BlockAllocator, OutOfBlocks,
                                    PrefixTrie)
from repro.serving.pam_manager import (PAMManager, PAMManagerConfig,
                                       init_pam_state,
                                       make_masked_decode_attn,
                                       make_masked_latent_attn)

WAITING, PREFILLING, RUNNING, DONE = (
    "waiting", "prefilling", "running", "done")


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    arrival: float = 0.0


@dataclasses.dataclass
class RequestState:
    request: Request
    status: str = WAITING
    slot: int = -1
    outputs: list[int] = dataclasses.field(default_factory=list)
    planned: int = 0                   # tokens dispatched (>= len(outputs))
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: list[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Engine configuration.

    ``block_size > 0`` turns on the paged warm/cold KV path: the pool
    holds ``pool_blocks`` physical blocks of ``block_size`` tokens
    (default: enough for every slot's full window, i.e. no overcommit;
    set it lower to exercise capacity backpressure). Requires a PAM
    config (tier residency decides dense-vs-paged reads) and a GQA-cache
    model family, and ``max_len`` must be a block multiple.

    ``hot_window > 0`` (paged mode only) shrinks the dense hot-tier
    buffer to a RING of that many slots — absolute position ``p`` lives
    at ring slot ``p % hot_window`` — so per-slot hot-tier bytes are
    ``O(hot_window)`` instead of ``O(max_len)``. Every appended token is
    mirrored into its mapped pool block in the same donated dispatch, so
    the append's ring overwrite IS the eviction (the evicted token's
    only live copy becomes its pool block, where warm/cold reads already
    go); token streams are exactly those of the full-window engine.
    0 keeps the legacy full-window buffer (a ring with ``max_len``
    slots, i.e. the identity rotation).
    """
    max_batch: int = 4
    max_len: int = 256
    eos_token: int = -1                # -1: run to max_new_tokens
    pam: Optional[PAMManagerConfig] = None   # None -> dense baseline
    micro_steps: int = 1               # decode steps fused per dispatch
    bucket_prefill: bool = True        # pow-2 prompt-length buckets
    block_size: int = 0                # paged-KV block tokens (0 = dense)
    pool_blocks: Optional[int] = None  # physical blocks (None = full)
    hot_window: int = 0                # hot ring slots (0 = max_len)
    temperature: float = 0.0           # 0 = greedy argmax (exact tests)
    top_k: int = 0                     # 0 = full softmax when sampling
    sample_seed: int = 0               # per-request sampling key seed:
    # token at position p of request rid draws from
    # fold_in(fold_in(PRNGKey(sample_seed), rid), p)
    prefix_cache: bool = False         # trie-indexed prompt-prefix
    # sharing over the paged pool (PR 7): admission maps a prompt's
    # longest cached prefix onto existing physical blocks (refcounted,
    # zero prefill compute for the shared part), prefills only the novel
    # suffix, and copy-on-writes a partially-filled shared tail block
    # before its first divergent write. Requires block_size > 0 and a
    # token-only GQA family. Off by default: the engine is then
    # bit-identical to PR 6.
    prefill_chunk: int = 0             # chunked prefill budget (PR 8):
    # a prompt whose novel part exceeds this many tokens admits in
    # bounded power-of-two slices interleaved with decode steps — each
    # slice is ONE fused dispatch appending its KV through the pool
    # commit path, the final slice rides the suffix-commit path (hot-row
    # rebuild + first-token sample), and no engine step ever prefills
    # more than `prefill_chunk` tokens per in-flight admission. Token
    # streams are bit-identical to single-shot admission. Requires the
    # paged pool (block_size > 0) and a token-only GQA family; must be a
    # power of two. 0 = off (single-shot prefill, PR 7 behavior).


class StepBufs(NamedTuple):
    """Per-dispatch device->host readback: k fused decode steps' tokens and
    stats. Small — the only thing the host ever copies back per step."""
    tokens: jax.Array       # (k, B) int32 greedy samples per fused step
    tier_reads: jax.Array   # (k, 3) int32 participating tokens per tier
    hit_rate: jax.Array     # (k,)   f32 context-locality hit rate
    moved: jax.Array        # (k,)   int32 Alg. 2 migrations this step
    lengths: jax.Array      # (k, B) int32 post-step cache lengths
    blocks: jax.Array       # (k, 2) int32 (paged pages touched, dense
                            #               window pages) — paged mode


# ---------------------------------------------------- shared jit builders
# Compiled executables are keyed by (model config, PAM config, shapes) at
# module level, NOT per engine instance: constructing a second engine with
# the same configuration reuses the compiled fused step instead of paying
# compile again (configs are frozen dataclasses, hence hashable).

def _sample_tokens(logits, seed: int, rids, positions,
                   temperature: float, top_k: int):
    """On-device sampling: greedy argmax when ``temperature == 0``
    (static — compiles to the exact PR-1 fast path), else temperature
    softmax with optional top-k filtering. Each row draws from its own
    PER-REQUEST key ``fold_in(fold_in(PRNGKey(seed), rid), position)``
    — the sampled token at absolute position ``p`` of request ``rid``
    depends only on (seed, rid, p) and the logits, never on batch
    composition, slot index or the engine's global step history. That
    replay-stability is what makes sampled streams bit-identical across
    migration AND failure recovery (a replayed request regenerates the
    exact tokens it already emitted — ``repro.cluster.recovery``)."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / temperature
    if 0 < top_k < lg.shape[-1]:
        kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    base = jax.random.PRNGKey(seed)

    def draw(rid, pos, row):
        key = jax.random.fold_in(jax.random.fold_in(base, rid), pos)
        return jax.random.categorical(key, row, axis=-1)

    return jax.vmap(draw)(rids.astype(jnp.uint32),
                          positions.astype(jnp.uint32),
                          lg).astype(jnp.int32)


def _fused_decode_body(cfg: ModelConfig, pcfg: Optional[PAMManagerConfig],
                       smax: int, bs: int, sentinel: int,
                       temperature: float, top_k: int, eos: int,
                       hot_window: int, seed: int, mesh,
                       params, tokens, cache, pam_state, active, rids):
    """ONE decode step of the full PAM pipeline, pure & traceable:
    participation -> masked decode -> stats -> observe -> sample.

    ``bs`` > 0 selects the paged warm/cold path: the participation set is
    split by tier, warm/cold reads gather the pool through
    ``pam_state.block_table`` (dead pages remapped to ``sentinel``), and
    the appended token is mirrored into its mapped block.

    ``hot_window`` > 0 is the hot ring's slot count: hot-tier tags are
    first clamped to the ring window (a token the append evicted cannot
    stay hot — demotion is the ring overwrite plus this tag edit), the
    participation split confines hot reads to in-window tokens, and the
    dense append in ``attention_decode`` wraps modulo the window. All
    other coordinates (participation, importance EMA, block tables) stay
    absolute.

    ``eos >= 0`` folds EOS detection into the dispatch: a slot that
    samples EOS is deactivated *on device* (returned ``active`` drops
    it), so the multi-step micro-loop can serve eos traffic without a
    host check between fused steps — finished slots freeze their cache
    lengths and token for the remaining micro-steps.
    """
    B = active.shape[0]
    lengths = cache.lengths + active.astype(jnp.int32)
    if pcfg is not None:
        participate = pm.participation_mask(
            pcfg, pam_state.importance, lengths)
    else:
        participate = jnp.arange(smax)[None, :] < lengths[:, None]
    l_fn = make_masked_latent_attn(participate)
    paged_append = None
    blocks = jnp.zeros((2,), jnp.int32)
    if bs:
        nb = smax // bs
        if hot_window:
            # ring demotion, part 2: the append overwrote the evicted
            # slot; re-tag tokens that slid out of the window so the
            # split (and the tier accounting) reads them from the pool
            pam_state = pam_state._replace(tier=tiers_mod.clamp_hot_to_window(
                pam_state.tier, lengths, hot_window))
        hot_m, pgd_m, block_live = pm.paged_participation_split(
            participate, pam_state.tier, lengths, bs, hot_window)
        bt_eff = jnp.where(block_live, pam_state.block_table, sentinel)
        if mesh is not None:
            # PR 10: hot ring + pool reads fan out over the mesh's
            # "model" axis under shard_map; partials re-merge with the
            # exact online-softmax (pmax/psum of (O, m, l)) so the
            # sharded step is bit-identical to the unsharded one
            from repro.distributed import pam_shard as psh
            d_fn = psh.make_sharded_paged_decode_attn(
                mesh, hot_m, pgd_m, bt_eff, block_live)
        else:
            d_fn = pm.make_paged_decode_attn(hot_m, pgd_m, bt_eff,
                                             block_live)
        # append coordinates for the new token (same for every layer);
        # inactive rows write the sentinel trash page
        pos = cache.lengths
        lb = jnp.clip(pos // bs, 0, nb - 1)
        dst_block = jnp.where(
            active, pam_state.block_table[jnp.arange(B), lb], sentinel)
        paged_append = (dst_block.astype(jnp.int32),
                        (pos % bs).astype(jnp.int32))
        valid = jnp.arange(smax)[None, :] < lengths[:, None]
        window = pkv.token_block_mask(valid, bs)
        act = active[:, None]
        blocks = jnp.stack([jnp.sum(block_live & act),
                            jnp.sum(window & act)]).astype(jnp.int32)
    else:
        d_fn = make_masked_decode_attn(participate)
    old_lens = cache.lengths
    logits, cache, scores = tf.decode_step(
        cfg, params, tokens, cache, decode_attn_fn=d_fn,
        latent_attn_fn=l_fn, paged_append=paged_append)
    # inactive slots: freeze their lengths
    cache = cache._replace(
        lengths=jnp.where(active, cache.lengths, old_lens))

    if pcfg is not None:
        read_mask = participate & active[:, None]
        tier_reads = pm.tier_read_counts_of(pam_state.tier, read_mask)
        hit = pm.hit_rate_of(pam_state.last_hot, participate)
        if scores is None:     # attention-free: recency-only scores
            scores = (jnp.arange(smax)[None, :]
                      == (cache.lengths - 1)[:, None]).astype(jnp.float32)
        before = pam_state.moved_tokens
        pam_state = pm.observe_update(pcfg, pam_state, scores,
                                      cache.lengths, participate)
        moved = pam_state.moved_tokens - before
    else:
        tier_reads = jnp.zeros((3,), jnp.int32)
        hit = jnp.zeros((), jnp.float32)
        moved = jnp.zeros((), jnp.int32)

    # the sampled token's absolute position is the post-append cache
    # length — the (rid, position) pair keys the per-request PRNG
    nxt = _sample_tokens(logits, seed, rids, cache.lengths,
                         temperature, top_k)
    tokens = jnp.where(active, nxt, tokens)
    if eos >= 0:
        active = active & (tokens != eos)   # EOS emitted -> slot freezes
    return tokens, cache, pam_state, active, (tier_reads, hit, moved,
                                              cache.lengths, blocks)


@functools.lru_cache(maxsize=None)
def _fused_decode_fn(cfg: ModelConfig, pcfg: Optional[PAMManagerConfig],
                     smax: int, batch: int, k: int, bs: int = 0,
                     sentinel: int = 0, temperature: float = 0.0,
                     top_k: int = 0, eos: int = -1, hot_window: int = 0,
                     seed: int = 0, mesh=None, cache_shardings=None):
    """Fused decode dispatch running ``k`` steps on device. Cache (dense
    buffers AND paged pools), PAM state (including the block table) and
    the token vector are DONATED — zero per-step copies. ``rids`` is the
    per-slot request-id vector: sampling keys derive on device as
    ``fold_in(fold_in(seed, rid), position)``, so no PRNG state is
    threaded between dispatches at all (the key is a pure function of
    what the request is and where it is in its stream — replayable).
    The active mask rides the micro-loop carry so on-device EOS
    detection (``eos >= 0``) freezes finished slots mid-dispatch."""
    def run_k(params, tokens, cache, pam_state, active, rids):
        bufs = StepBufs(
            tokens=jnp.zeros((k, batch), jnp.int32),
            tier_reads=jnp.zeros((k, 3), jnp.int32),
            hit_rate=jnp.zeros((k,), jnp.float32),
            moved=jnp.zeros((k,), jnp.int32),
            lengths=jnp.zeros((k, batch), jnp.int32),
            blocks=jnp.zeros((k, 2), jnp.int32))

        def step_i(i, carry):
            tokens, cache, pam_state, active, bufs = carry
            tokens, cache, pam_state, active, \
                (reads, hit, moved, lens, blk) = _fused_decode_body(
                    cfg, pcfg, smax, bs, sentinel, temperature, top_k,
                    eos, hot_window, seed, mesh, params, tokens, cache,
                    pam_state, active, rids)
            bufs = StepBufs(
                tokens=bufs.tokens.at[i].set(tokens),
                tier_reads=bufs.tier_reads.at[i].set(reads),
                hit_rate=bufs.hit_rate.at[i].set(hit),
                moved=bufs.moved.at[i].set(moved),
                lengths=bufs.lengths.at[i].set(lens),
                blocks=bufs.blocks.at[i].set(blk))
            return tokens, cache, pam_state, active, bufs

        carry = (tokens, cache, pam_state, active, bufs)
        if k == 1:
            carry = step_i(0, carry)
        else:
            carry = jax.lax.fori_loop(0, k, step_i, carry)
        tokens, cache, pam_state, active, bufs = carry
        return tokens, cache, pam_state, bufs

    if cache_shardings is not None:
        # pin outputs so donation stays shape-AND-layout compatible
        # across steps: the cache keeps its shard layout, everything
        # else stays replicated (``lengths`` is always replicated, so
        # its sharding doubles as the replicated spec)
        rep = cache_shardings.lengths
        return jax.jit(run_k, donate_argnums=(1, 2, 3),
                       out_shardings=(rep, cache_shardings, rep, rep))
    return jax.jit(run_k, donate_argnums=(1, 2, 3))


@functools.lru_cache(maxsize=None)
def _prefill_fn(cfg: ModelConfig, smax: int, rep=None):
    # one jit per (cfg, smax); jax retraces per prompt-bucket shape
    # SSM/hybrid prompts are never padded (bucket == exact length),
    # so the dynamic-length machinery is skipped entirely.
    # Returns LOGITS (not a token): the admission commit samples the
    # first token under the same temperature/top-k/PRNG policy as the
    # fused decode dispatch.
    exact = cfg.family in ("ssm", "hybrid")

    def pre(params, tokens, true_len):
        logits, cache = tf.prefill(cfg, params, tokens, smax,
                                   true_len=None if exact else true_len)
        return logits, cache

    if rep is not None:
        # sharded engines: the prefill SUB-cache feeds the (replicated-
        # operand) admission commit — pin it replicated so GSPMD never
        # invents a layout the commit has to rematerialize away from
        return jax.jit(pre, out_shardings=(rep, rep))
    return jax.jit(pre)


@functools.lru_cache(maxsize=None)
def _admit_commit_fn(pcfg: Optional[PAMManagerConfig], block_size: int,
                     n: int, temperature: float = 0.0, top_k: int = 0,
                     hot_window: int = 0, seed: int = 0,
                     cache_shardings=None):
    """One donated dispatch per admission GROUP: scatter ``n`` prefilled
    sequences (one batched prefill's sub-cache) into their slots, SAMPLE
    each first token from the prefill logits (same temperature/top-k/
    per-request-key policy as the decode dispatch), seed the device
    token vector and place each sequence's initial tier layout. In paged mode
    (``block_size`` > 0) the same dispatch also scatters each prompt's
    KV into its allocated pool blocks and installs its block-table row.
    With a hot ring (``hot_window`` > 0) the dense scatter is rebased
    onto ring coordinates: only each prompt's last ``hot_window`` tokens
    land in the ring (through the rotated position map), while the pool
    write above keeps every token — older prompt positions exist ONLY in
    their pool blocks from the moment of admission.
    ``n == 1`` is the single-admission case; same-bucket admission
    bursts ride one dispatch."""
    def commit(cache, pam_state, tokens_dev, sub, logits, slots, lengths,
               rids, table_rows=None):
        # first token = absolute position `prompt_len` of request `rid`
        firsts = _sample_tokens(logits, seed, rids, lengths,
                                temperature, top_k)
        def put(full, batch_rows):
            if full.ndim == 0 or full.size == 0:
                return full
            if full.ndim == 1:                      # lengths (B,) <- (n,)
                return full.at[slots].set(batch_rows)
            return full.at[:, slots].set(batch_rows)    # (L, B, ...)
        if block_size:
            # pool fields have no batch axis — peel them off the generic
            # per-slot scatter and fill them through the block tables
            # (full logical rows, BEFORE any ring re-layout of sub)
            pk, pv = cache.pk, cache.pv
            for i in range(n):
                pk = pkv.write_prefill(pk, sub.k[:, i], table_rows[i],
                                       block_size)
                pv = pkv.write_prefill(pv, sub.v[:, i], table_rows[i],
                                       block_size)
            if hot_window:
                ring_pos, valid = ring_position_map(lengths, hot_window)
                ring_of = jax.vmap(pam_if.logical_to_ring,
                                   in_axes=(1, 0, 0), out_axes=1)
                sub = sub._replace(k=ring_of(sub.k, ring_pos, valid),
                                   v=ring_of(sub.v, ring_pos, valid))
            cache = cache._replace(pk=sub.pk, pv=sub.pv)
            cache = jax.tree.map(put, cache, sub)
            cache = cache._replace(pk=pk, pv=pv)
        else:
            cache = jax.tree.map(put, cache, sub)
        tokens_dev = tokens_dev.at[slots].set(firsts)
        if pcfg is not None:
            for i in range(n):
                pam_state = pm.place_prefill_state(
                    pcfg, pam_state, slots[i], lengths[i],
                    table_rows[i] if block_size else None)
        return cache, pam_state, tokens_dev, firsts

    if cache_shardings is not None:
        rep = cache_shardings.lengths
        return jax.jit(commit, donate_argnums=(0, 1, 2),
                       out_shardings=(cache_shardings, rep, rep, rep))
    return jax.jit(commit, donate_argnums=(0, 1, 2))


@functools.lru_cache(maxsize=None)
def _suffix_prefill_fn(cfg: ModelConfig, smax: int, rep=None):
    """Batched suffix-only prefill dispatch (PR 7 path, batched in
    PR 8): gather each row's cached prefix from the pool THROUGH its
    block table (the §6.2 sharer-side re-layout — a pure read of the
    shared blocks), then run ``tf.prefill_suffix`` over just the novel
    tokens of every row at once. Rows with ``prefix_len == 0`` are
    plain admissions riding the same dispatch — the gathered prefix is
    all zeros and masked inside attention, so their result is exactly
    the from-scratch prefill. One dispatch; retraces per (group size,
    suffix bucket) like ``_prefill_fn``. Returns (last-token logits
    (n, V), suffix K/V (L, n, Hkv, S, dh))."""
    def pre(params, tokens, pk, pv, read_rows, prefix_lens, true_lens):
        gather = jax.vmap(pam_if.gather_prefix_logical,
                          in_axes=(None, 0, 0), out_axes=1)
        gk = gather(pk, read_rows, prefix_lens)    # (L, n, Hkv, P, dh)
        gv = gather(pv, read_rows, prefix_lens)
        return tf.prefill_suffix(cfg, params, tokens, gk, gv,
                                 prefix_lens, true_len=true_lens)

    if rep is not None:
        return jax.jit(pre, out_shardings=(rep, rep, rep))
    return jax.jit(pre)


@functools.lru_cache(maxsize=None)
def _suffix_commit_fn(pcfg: Optional[PAMManagerConfig], block_size: int,
                      n: int, temperature: float = 0.0, top_k: int = 0,
                      hot_window: int = 0, seed: int = 0,
                      cache_shardings=None):
    """ONE donated dispatch committing a suffix-prefill admission GROUP
    (prefix-cache hits, the plain same-bucket admissions batched with
    them, and final chunked-prefill slices):

    1. Copy-on-write each row's shared, partially-filled tail block
       (``cow_srcs[i]``, still owned by its publisher/trie) into that
       row's fresh ``cow_dsts[i]`` BEFORE any write. Rows with nothing
       to copy pass the sentinel for both — a self-copy of the trash
       block, i.e. a no-op. Fully-shared interior blocks are never
       copied: the table maps them directly.
    2. Scatter each row's novel-suffix K/V token-by-token into its
       fresh blocks (pad positions routed to the sentinel trash block).
    3. Rebuild each slot's dense hot row by gathering the FULL logical
       sequence back through its table (shared prefix + fresh suffix),
       re-based onto ring coordinates when ``hot_window`` is set.
    4. Sample each first token at absolute position ``lengths[i]``
       under the same per-request-key policy as every other dispatch,
       and place the PAM rows + block tables.

    The donation/one-dispatch invariants match ``_admit_commit_fn``: a
    burst of n same-bucket admissions costs 2 dispatches whether or not
    any of them hit the prefix cache."""
    def commit(cache, pam_state, tokens_dev, suf_k, suf_v, logits,
               slots, lengths, rids, table_rows, bids, sids, cow_srcs,
               cow_dsts):
        pk, pv = cache.pk, cache.pv
        for i in range(n):
            pk = pkv.copy_block(pk, cow_srcs[i], cow_dsts[i])
            pv = pkv.copy_block(pv, cow_srcs[i], cow_dsts[i])
        sk = jnp.moveaxis(suf_k, 2, 3)             # (L, n, S, Hkv, dh)
        sv = jnp.moveaxis(suf_v, 2, 3)
        pk = pk.at[:, bids, sids].set(sk)          # bids/sids: (n, S)
        pv = pv.at[:, bids, sids].set(sv)
        gat = jax.vmap(pkv.gather_sequence, in_axes=(None, 0),
                       out_axes=1)
        gk = gat(pk, table_rows)                   # (L, n, Hkv, smax, dh)
        gv = gat(pv, table_rows)
        live = (jnp.arange(gk.shape[3])[None, None, None, :, None]
                < lengths[None, :, None, None, None])
        gk = jnp.where(live, gk, jnp.zeros((), gk.dtype))
        gv = jnp.where(live, gv, jnp.zeros((), gv.dtype))
        if hot_window:
            ring_pos, valid = ring_position_map(lengths, hot_window)
            ring_of = jax.vmap(pam_if.logical_to_ring,
                               in_axes=(1, 0, 0), out_axes=1)
            dk = ring_of(gk, ring_pos, valid)
            dv = ring_of(gv, ring_pos, valid)
        else:
            dk, dv = gk, gv
        cache = cache._replace(
            k=cache.k.at[:, slots].set(dk),
            v=cache.v.at[:, slots].set(dv),
            lengths=cache.lengths.at[slots].set(lengths),
            pk=pk, pv=pv)
        firsts = _sample_tokens(logits, seed, rids, lengths,
                                temperature, top_k)
        tokens_dev = tokens_dev.at[slots].set(firsts)
        if pcfg is not None:
            for i in range(n):
                pam_state = pm.place_prefill_state(
                    pcfg, pam_state, slots[i], lengths[i],
                    table_rows[i])
        return cache, pam_state, tokens_dev, firsts

    if cache_shardings is not None:
        rep = cache_shardings.lengths
        return jax.jit(commit, donate_argnums=(0, 1, 2),
                       out_shardings=(cache_shardings, rep, rep, rep))
    return jax.jit(commit, donate_argnums=(0, 1, 2))


@functools.lru_cache(maxsize=None)
def _chunk_fill_fn(cfg: ModelConfig, smax: int, cow: bool = False,
                   cache_shardings=None):
    """ONE donated dispatch advancing a chunked-prefill admission by an
    INTERMEDIATE slice (PR 8): optionally copy-on-write the shared tail
    block (first slice of a prefix-cache hit), gather the already-
    filled prefix ``[0, begin)`` from the pool through the request's
    own table, run the suffix prefill over just this slice's tokens,
    and scatter the slice's K/V into the mapped pool blocks. No dense
    hot row, no sampling, no PAM placement — those happen once, in the
    FINAL slice's suffix commit, after which the request is
    indistinguishable from a single-shot admission. The slice logits
    are discarded (only the final slice's feed sampling)."""
    def fill(params, cache, tokens, table_row, begin, true_len, bids,
             sids, cow_src, cow_dst):
        pk, pv = cache.pk, cache.pv
        if cow:
            # after the copy the request's own table maps cow_dst, which
            # now holds the shared tail bytes — the gather below reads
            # the prefix entirely through the request's own row
            pk = pkv.copy_block(pk, cow_src, cow_dst)
            pv = pkv.copy_block(pv, cow_src, cow_dst)
        gk = pam_if.gather_prefix_logical(pk, table_row, begin)
        gv = pam_if.gather_prefix_logical(pv, table_row, begin)
        _, suf_k, suf_v = tf.prefill_suffix(
            cfg, params, tokens, gk[:, None], gv[:, None], begin[None],
            true_len=true_len)
        sk = jnp.moveaxis(suf_k[:, 0], 1, 2)       # (L, S, Hkv, dh)
        sv = jnp.moveaxis(suf_v[:, 0], 1, 2)
        pk = pk.at[:, bids, sids].set(sk)
        pv = pv.at[:, bids, sids].set(sv)
        return cache._replace(pk=pk, pv=pv)

    if cache_shardings is not None:
        return jax.jit(fill, donate_argnums=(1,),
                       out_shardings=cache_shardings)
    return jax.jit(fill, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _import_commit_fn(has_pam: bool, block_size: int,
                      hot_window: int = 0, cache_shardings=None):
    """One donated dispatch per migrated-request import: install the
    snapshot's logical-layout KV into the dense cache slot (and, in
    paged mode, scatter it through the target's freshly-allocated block
    table — the §6.2 address-generation/receiver step), insert the PAM
    rows and seed the device token vector. With a hot ring the dense
    install is re-based onto ring coordinates (last ``hot_window``
    tokens through the rotated position map; the pool scatter below
    keeps the full context). The admission twin of ``export``: a
    migrated request resumes with zero host state left on the source."""
    def commit(cache, pam_state, tokens_dev, k_row, v_row, imp_row,
               tier_row, lh_row, slot, length, token, table_row=None):
        if hot_window:
            ring_pos, valid = ring_position_map(length[None], hot_window)
            dk = pam_if.logical_to_ring(k_row, ring_pos[0], valid[0])
            dv = pam_if.logical_to_ring(v_row, ring_pos[0], valid[0])
        else:
            dk, dv = k_row, v_row
        cache = cache._replace(
            k=cache.k.at[:, slot].set(dk),
            v=cache.v.at[:, slot].set(dv),
            lengths=cache.lengths.at[slot].set(length))
        if block_size:
            cache = cache._replace(
                pk=pkv.write_prefill(cache.pk, k_row, table_row,
                                     block_size),
                pv=pkv.write_prefill(cache.pv, v_row, table_row,
                                     block_size))
        tokens_dev = tokens_dev.at[slot].set(token)
        if has_pam:
            pam_state = pm.insert_slot_state(
                pam_state, slot, imp_row, tier_row, lh_row,
                table_row if block_size else None)
        return cache, pam_state, tokens_dev

    if cache_shardings is not None:
        rep = cache_shardings.lengths
        return jax.jit(commit, donate_argnums=(0, 1, 2),
                       out_shardings=(cache_shardings, rep, rep))
    return jax.jit(commit, donate_argnums=(0, 1, 2))


@functools.lru_cache(maxsize=None)
def _export_gather_fn(block_size: int, hot_window: int = 0):
    """Snapshot gather for inter-device migration (§6.2 sender side):
    hot tokens read the dense cache row, warm/cold tokens are gathered
    from the pool THROUGH the block table (``paged_kv.gather_sequence``)
    — one fused gather producing the portable logical (L, Hkv, Smax, dh)
    layout. With a hot ring, the hot rows stream through the rotated
    ring index map (``ring_to_logical``) on top of the pool gather — the
    snapshot layout is unchanged, so engines with different (or no) hot
    windows interoperate. Dense-only engines just slice the cache."""
    @jax.jit
    def go(k, v, pk, pv, table_row, tier_row, slot, length):
        kc, vc = k[:, slot], v[:, slot]       # (L, Hkv, Smax|W, dh)
        if not block_size:
            return kc, vc
        gk = pkv.gather_sequence(pk, table_row)
        gv = pkv.gather_sequence(pv, table_row)
        if hot_window:
            ring_pos, valid = ring_position_map(length[None], hot_window)
            ring_pos, valid = ring_pos[0], valid[0]
            smax = gk.shape[2]
            hot_at = jnp.take(tier_row, jnp.clip(ring_pos, 0, smax - 1))
            sel = valid & (hot_at == HOT)
            return (pam_if.ring_to_logical(kc, ring_pos, sel, gk),
                    pam_if.ring_to_logical(vc, ring_pos, sel, gv))
        hot = (tier_row == HOT)[None, None, :, None]
        return jnp.where(hot, kc, gk), jnp.where(hot, vc, gv)

    return go


class ServingEngine:
    """The PAM serving engine (alias ``PAMEngine``).

    Construct with a model config + params and a ``ServingConfig``;
    ``submit`` requests, then drive with ``step()`` (synchronous, one
    fused dispatch per call) or ``run()`` (to completion; pipelined
    multi-step micro-loop when ``micro_steps > 1``). See the module
    docstring for the fused-dispatch, donation, and paged-tier
    invariants, and ``summary()`` for the metrics contract.
    """

    def __init__(self, spec, params=None, scfg: Optional[ServingConfig]
                 = None,
                 latency_model: Optional[Callable[[dict], float]] = None,
                 name: Optional[str] = None):
        # canonical construction is EngineSpec.build(params) — the spec
        # carries model + serving config + shard + name declaratively.
        # The legacy (cfg, params, scfg, ...) positional signature still
        # works through this shim, with a DeprecationWarning.
        from repro.serving.spec import EngineSpec
        if isinstance(spec, EngineSpec):
            if scfg is not None or name is not None:
                raise TypeError(
                    "ServingEngine(EngineSpec, params, ...): serving "
                    "config and name live on the spec; pass only "
                    "latency_model as a keyword")
        else:
            warnings.warn(
                "ServingEngine(cfg, params, scfg, ...) is deprecated; "
                "use EngineSpec(model=cfg, serving=scfg, name=...)"
                ".build(params, latency_model=...)",
                DeprecationWarning, stacklevel=2)
            if scfg is None:
                raise TypeError("legacy ServingEngine(cfg, params, scfg)"
                                " signature requires a ServingConfig")
            spec = EngineSpec(model=spec, serving=scfg,
                              name=name if name is not None else "dev0")
        cfg, scfg = spec.model, spec.serving
        assert cfg.has_decode, f"{cfg.name} is encoder-only"
        self.spec = spec
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.latency_model = latency_model
        self.name = spec.name                  # cluster device handle
        self.shard = spec.shard
        self.mesh = None                       # set when spec.shard > 1
        self.cache_shardings = None
        self.clock = 0.0                       # simulated seconds
        self.busy_time = 0.0                   # sim seconds with active>0
        self.last_step_time = 0.0              # modeled latency, last step
        self.last_step_stats = None            # stats of that decode step

        B, Smax = scfg.max_batch, scfg.max_len
        self.pam_cfg = scfg.pam
        self.mgr = PAMManager(scfg.pam) if scfg.pam else None
        self.block_size = scfg.block_size
        self.hot_window = scfg.hot_window
        self.allocator: Optional[BlockAllocator] = None
        self.sentinel = 0
        if self.hot_window and not self.block_size:
            raise ValueError("hot_window (ring hot tier) requires the "
                             "paged pool (block_size > 0): evicted "
                             "tokens live only in their mapped blocks")
        if self.hot_window and not 0 < self.hot_window <= Smax:
            raise ValueError(f"hot_window {self.hot_window} must be in "
                             f"(0, max_len={Smax}]")
        if self.block_size:
            if scfg.pam is None:
                raise ValueError("paged KV (block_size > 0) requires a "
                                 "PAM config: tier residency decides "
                                 "dense-vs-paged reads")
            if Smax % self.block_size:
                raise ValueError(f"max_len {Smax} not a multiple of "
                                 f"block_size {self.block_size}")
            nb_seq = Smax // self.block_size
            if scfg.pool_blocks is not None and scfg.pool_blocks <= 0:
                raise ValueError(f"pool_blocks must be positive, got "
                                 f"{scfg.pool_blocks}")
            pool_blocks = (scfg.pool_blocks if scfg.pool_blocks is not None
                           else B * nb_seq)
            self.allocator = BlockAllocator(pool_blocks, self.block_size)
            self.sentinel = pool_blocks
            self.cache = tf.init_decode_cache(
                cfg, B, Smax, paged_blocks=pool_blocks,
                block_size=self.block_size, hot_window=self.hot_window)
            self.pam_state = init_pam_state(B, Smax, num_blocks=nb_seq,
                                            sentinel=pool_blocks)
            self.peak_occupancy = 0.0
            self.blocks_touched_total = 0
            self.blocks_window_total = 0
        else:
            self.cache = tf.init_decode_cache(cfg, B, Smax)
            self.pam_state = init_pam_state(B, Smax)

        if spec.shard > 1:
            # PR 10: tensor-shard params and sequence-shard KV over one
            # shared device group. Params are GSPMD-sharded (a replica
            # GROUP holds ONE copy, ~1/shard bytes per device); the hot
            # ring splits on its slot axis and the pool on its physical-
            # block axis (``serving_cache_shardings``). The fused step
            # pins its out_shardings so donation keeps the layout.
            spec.validate()
            from repro.distributed import pam_shard as psh
            from repro.distributed import sharding as shd
            self.mesh = psh.decode_mesh(spec.shard)
            self.params = jax.device_put(
                params, shd.param_shardings(cfg, self.mesh))
            self.cache_shardings = shd.serving_cache_shardings(
                self.mesh, self.cache)
            rep = self.cache_shardings.lengths
            self.cache = jax.device_put(self.cache, self.cache_shardings)
            self.pam_state = jax.device_put(
                self.pam_state, jax.tree.map(lambda _: rep,
                                             self.pam_state))

        self.trie: Optional[PrefixTrie] = None
        if scfg.prefix_cache:
            if not self.block_size:
                raise ValueError("prefix_cache requires the paged pool "
                                 "(block_size > 0): shared prefixes live "
                                 "in refcounted blocks")
            if cfg.family == "vlm":
                raise ValueError("prefix_cache keys on token ids; the "
                                 "vlm patch prefix has none")
            self.trie = PrefixTrie(self.block_size, self.allocator)
            self.prefix_hits = 0            # admissions with matched > 0
            self.cached_prefix_tokens = 0   # prefill compute skipped
            self.novel_prefill_tokens = 0   # prefill compute performed
            self.cow_copies = 0             # tail blocks duplicated

        self.chunk = scfg.prefill_chunk
        self._chunking: dict[int, ChunkPlan] = {}  # rid -> in-flight plan
        if self.chunk:
            validate_budget(self.chunk)
            if not self.block_size:
                raise ValueError("prefill_chunk (chunked prefill) "
                                 "requires the paged pool (block_size > "
                                 "0): slices append KV through the pool "
                                 "commit path")
            # chunk_slices counts slice dispatches; max_chunk_slice is
            # the largest slice actually prefilled (tests pin <= budget)
            self.chunked_admissions = 0
            self.chunk_slices = 0
            self.max_chunk_slice = 0

        self.requests: dict[int, RequestState] = {}
        self.waiting: collections.deque[int] = collections.deque()
        self.slots: list[Optional[int]] = [None] * B
        self.tokens_dev = jnp.zeros((B,), jnp.int32)  # lives on device
        if self.mesh is not None:
            self.tokens_dev = jax.device_put(
                self.tokens_dev, self.cache_shardings.lengths)
        # per-slot request ids: the sampling-key operand of the fused
        # dispatch (keys derive as fold_in(fold_in(seed, rid), position),
        # so no PRNG state survives between dispatches)
        self.rids_host = np.zeros((B,), np.uint32)
        self.steps = 0
        # fast-path observability: one fused dispatch should serve one (or
        # k) decode steps — asserted by tests and reported by benchmarks
        self.decode_dispatches = 0
        self.decode_device_steps = 0
        self.prefill_dispatches = 0
        self.admit_dispatches = 0
        self.migrations_in = 0
        self.migrations_out = 0

        self._micro_jits: dict[int, Any] = {}    # keyed by fused step count
        self._prefill_jit: dict[int, Any] = {}   # keyed by prompt bucket
        self._admit_jit = self._admit_commit_dispatch
        self._bind_obs()

    def _bind_obs(self) -> None:
        """Bind this engine's labeled instruments against the registry
        installed RIGHT NOW (``repro.obs.metrics.install`` before
        construction). Every series carries a ``device`` label so a
        cluster fleet shares one registry. With the default (disabled)
        registry each update is a single attribute check — nothing
        allocates on the step path; the canonical name table lives in
        docs/ARCHITECTURE.md."""
        reg = obs_metrics.get_registry()
        self._mreg = reg
        c, g, h = reg.counter, reg.gauge, reg.histogram
        dl = ("device",)
        d = {"device": self.name}
        self._m_steps = c(
            "pam_engine_steps_total",
            "engine iterations (admission pass + decode step)",
            dl).labels(**d)
        self._m_decode_disp = c(
            "pam_engine_decode_dispatches_total",
            "fused decode device dispatches", dl).labels(**d)
        self._m_device_steps = c(
            "pam_engine_decode_device_steps_total",
            "decode steps executed on device (k per micro dispatch)",
            dl).labels(**d)
        self._m_prefill_disp = c(
            "pam_engine_prefill_dispatches_total",
            "prefill / suffix-prefill / chunk-slice dispatches",
            dl).labels(**d)
        self._m_admit_disp = c(
            "pam_engine_admit_dispatches_total",
            "donated admission-commit dispatches", dl).labels(**d)
        self._m_prefill_tokens = c(
            "pam_engine_prefill_tokens_total",
            "prompt tokens prefilled (novel only under prefix cache)",
            dl).labels(**d)
        self._m_decode_tokens = c(
            "pam_engine_decode_tokens_total",
            "decode tokens emitted to requests", dl).labels(**d)
        self._m_finished = c(
            "pam_engine_finished_total",
            "requests finished (EOS or budget)", dl).labels(**d)
        self._m_step_h = h(
            "pam_engine_step_seconds",
            "per-step latency (modeled or wall-clock)", dl).labels(**d)
        self._m_active = g(
            "pam_engine_active_slots",
            "slots decoding in the last step", dl).labels(**d)
        self._m_queue = g(
            "pam_engine_queue_depth",
            "requests waiting for admission", dl).labels(**d)
        self._m_pool = g(
            "pam_engine_pool_occupancy",
            "paged-pool block occupancy fraction", dl).labels(**d)
        tier_c = c("pam_engine_tier_read_tokens_total",
                   "participating tokens read, by KV tier",
                   ("device", "tier"))
        self._m_tier = tuple(tier_c.labels(device=self.name, tier=t)
                             for t in ("hot", "warm", "cold"))
        self._m_moved = c(
            "pam_engine_moved_tokens_total",
            "Alg. 2 tier migrations (tokens)", dl).labels(**d)
        self._m_blocks_touched = c(
            "pam_engine_blocks_touched_total",
            "pool pages touched by paged reads", dl).labels(**d)
        self._m_blocks_window = c(
            "pam_engine_blocks_window_total",
            "dense-window pages a full read would touch",
            dl).labels(**d)
        self._m_prefix_hits = c(
            "pam_engine_prefix_hits_total",
            "admissions that matched a cached prefix", dl).labels(**d)
        self._m_cached_prefix_tokens = c(
            "pam_engine_cached_prefix_tokens_total",
            "prefill compute skipped via prefix sharing (tokens)",
            dl).labels(**d)
        self._m_cow = c(
            "pam_engine_cow_copies_total",
            "copy-on-write tail-block duplications", dl).labels(**d)
        self._m_chunk_adm = c(
            "pam_engine_chunked_admissions_total",
            "admissions that went through chunked prefill",
            dl).labels(**d)
        self._m_chunk_slices = c(
            "pam_engine_chunk_slices_total",
            "chunked-prefill slice dispatches", dl).labels(**d)
        mig = c("pam_engine_migrations_total",
                "requests migrated (suspend/resume rides the same "
                "path)", ("device", "direction"))
        self._m_mig_in = mig.labels(device=self.name, direction="in")
        self._m_mig_out = mig.labels(device=self.name, direction="out")

    def _observe_step(self, stats: dict[str, Any], dt: float) -> None:
        """Per-step telemetry fan-out. Costs one ``enabled`` check when
        metrics are off plus one ``None`` check when tracing is off —
        the fused-dispatch fast path never allocates for telemetry."""
        if self._mreg.enabled:
            self._m_steps.inc()
            self._m_step_h.observe(dt)
            if stats["prefill_tokens"]:
                self._m_prefill_tokens.inc(stats["prefill_tokens"])
            self._m_active.set(stats["active"])
            self._m_queue.set(len(self.waiting))
            for m, v in zip(self._m_tier, stats["tier_reads"]):
                if v:
                    m.inc(int(v))
            if stats["moved_tokens"]:
                self._m_moved.inc(stats["moved_tokens"])
            if "blocks_touched" in stats:
                self._m_blocks_touched.inc(stats["blocks_touched"])
                self._m_blocks_window.inc(stats["blocks_window"])
            if self.allocator is not None:
                self._m_pool.set(self.allocator.occupancy)
        tr = obs_trace.COLLECTOR
        if tr is not None:
            tr.slice(self.name, "step", self.clock - dt, dt,
                     active=stats["active"],
                     prefill_tokens=stats["prefill_tokens"])
            tr.counter(self.name, "occupancy", self.clock,
                       active=stats["active"],
                       queue=len(self.waiting),
                       pool=(self.allocator.occupancy
                             if self.allocator is not None else 0.0))

    def _trace_finish(self, rs: RequestState) -> None:
        """Close a finished request's lifecycle track (finish instant +
        end of its open phase) and count it."""
        self._m_finished.inc()
        tr = obs_trace.COLLECTOR
        if tr is not None:
            rid = rs.request.id
            tr.mark(rid, "finish", self.clock, tokens=len(rs.outputs))
            phase = tr.open_phase(rid)
            if phase is not None:
                tr.end(rid, phase, self.clock)

    # ------------------------------------------------------------ builders
    def _get_micro(self, k: int):
        """Fused decode dispatch for ``k`` steps, from the shared cache."""
        if k not in self._micro_jits:
            self._micro_jits[k] = _fused_decode_fn(
                self.cfg, self.pam_cfg, self.scfg.max_len,
                self.scfg.max_batch, k, self.block_size, self.sentinel,
                self.scfg.temperature, self.scfg.top_k,
                self.scfg.eos_token, self.hot_window,
                self.scfg.sample_seed, self.mesh, self.cache_shardings)
        return self._micro_jits[k]

    def _admit_commit_dispatch(self, cache, pam_state, tokens_dev, sub,
                               logits, slots, lengths, rids,
                               table_rows=None):
        """ONE donated device dispatch committing an admission group
        (resolved per group size from the shared compile cache)."""
        fn = _admit_commit_fn(self.pam_cfg, self.block_size,
                              int(slots.shape[0]), self.scfg.temperature,
                              self.scfg.top_k, self.hot_window,
                              self.scfg.sample_seed,
                              self.cache_shardings)
        args = (cache, pam_state, tokens_dev, sub, logits, slots, lengths,
                rids)
        if table_rows is not None:
            args += (table_rows,)
        return fn(*args)

    def _bucket_len(self, s_len: int) -> int:
        """Pow-2 prefill buckets cap the jit cache at O(log max_len)
        entries (SSM/hybrid running state can't absorb padding: exact)."""
        if (not self.scfg.bucket_prefill
                or self.cfg.family in ("ssm", "hybrid")):
            return s_len
        b = 1
        while b < s_len:
            b *= 2
        return min(b, self.scfg.max_len)

    def _prefill_for_len(self, bucket: int):
        if bucket not in self._prefill_jit:
            self._prefill_jit[bucket] = _prefill_fn(
                self.cfg, self.scfg.max_len,
                None if self.cache_shardings is None
                else self.cache_shardings.lengths)
        return self._prefill_jit[bucket]

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request) -> None:
        self.requests[req.id] = RequestState(request=req)
        self.waiting.append(req.id)
        tr = obs_trace.COLLECTOR
        if tr is not None:
            tr.begin(req.id, "queued", self.clock,
                     device=self.name, prompt=len(req.prompt))

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _reserve_fresh(self, need: int) -> None:
        """Best-effort headroom for ``need`` fresh blocks: under pool
        pressure, evict LRU trie-only cached prefixes (refcount 1 —
        nothing live maps them) until the free list covers the ask.
        Cache pressure degrades to recompute, never to failure; if live
        requests pin everything, the caller's ``allocate`` raises
        ``OutOfBlocks`` and normal admission backpressure applies."""
        if self.trie is not None and need > self.allocator.free_blocks:
            self.trie.evict(need - self.allocator.free_blocks)

    def _admit(self) -> int:
        """Prefill-priority admission (paper §4.2.3). Returns prompt
        tokens PROCESSED — with the prefix cache that is only each
        admission's novel suffix, so the latency model's admission cost
        scales with novel tokens, not prompt length. In paged mode each
        admission first claims pool blocks for its full window (prompt +
        budget); an exhausted pool leaves the request queued — capacity
        backpressure instead of failure.

        With ``prefix_cache`` the prompt is first split against the trie
        into cached-prefix + novel-suffix: the cached prefix's blocks
        are ADOPTED (refcount +1, zero prefill compute), a partially-
        covered tail block is pinned for copy-on-write, and only the
        suffix is prefilled (``_commit_trie``). Unmatched admissions
        flow through the unchanged group path below.

        Admissions sharing a prefill bucket are BATCHED: one bucket group
        = one prefill dispatch + one donated commit dispatch (scatter,
        pool fill, PAM placement and token seeds for every member), so a
        router burst of n same-length prompts costs 2 dispatches, not 2n.
        """
        # unified admission items: (rid, rs, prompt, s_len, slot,
        # table_row, start, cow_src) — start = cache-resident prefix
        # tokens (0 for plain admissions), cow_src = shared tail block
        # pinned for copy-on-write (-1 = none)
        admitted: list[tuple] = []
        free = self._free_slots()
        while self.waiting and free:
            rid = self.waiting.popleft()
            rs = self.requests[rid]
            prompt = np.asarray(rs.request.prompt, np.int32)
            s_len = len(prompt)
            if s_len + rs.request.max_new_tokens > self.scfg.max_len:
                raise ValueError(f"request {rid} exceeds max_len")
            table_row = None
            matched, cow_src = 0, -1
            if self.allocator is not None:
                window = s_len + rs.request.max_new_tokens
                need = self.allocator.blocks_for(window)
                if need > self.allocator.num_blocks:
                    # waiting would never help — fail loudly instead of
                    # starving this and every queued-behind request
                    raise ValueError(
                        f"request {rid} needs {need} blocks but the pool "
                        f"holds {self.allocator.num_blocks}")
                shared: list[int] = []
                if self.trie is not None:
                    # ≥ 1 token is always recomputed (the suffix prefill
                    # must produce first-token logits), so a full-prompt
                    # hit caps at s_len - 1
                    matched, ids = self.trie.lookup(prompt)
                    matched = min(matched, s_len - 1)
                    nfull = matched // self.block_size
                    shared = ids[:nfull]
                    if matched % self.block_size:
                        cow_src = ids[nfull]
                try:
                    if shared:
                        # adopt first: the incref shields the matched
                        # blocks from the eviction pass below
                        self.allocator.adopt(rid, shared)
                    if cow_src >= 0:
                        self.allocator.incref(cow_src)  # CoW-source pin
                    self._reserve_fresh(need - len(shared))
                    self.allocator.allocate(rid, window)
                except OutOfBlocks:
                    # roll back the adoption (decref) and the CoW pin,
                    # then wait for freed blocks
                    if cow_src >= 0:
                        self.allocator.decref(cow_src)
                    self.allocator.free(rid)
                    self.waiting.appendleft(rid)
                    break
                table_row = self.allocator.padded_table(
                    rid, self.scfg.max_len // self.block_size,
                    self.sentinel)
                self.peak_occupancy = max(self.peak_occupancy,
                                          self.allocator.occupancy)
            slot = free.pop(0)
            if matched > 0:
                self.prefix_hits += 1
                self.cached_prefix_tokens += matched
                self._m_prefix_hits.inc()
                self._m_cached_prefix_tokens.inc(matched)
            if self.chunk and s_len - matched > self.chunk:
                # chunked admission (PR 8): claim the slot and the full
                # block window NOW, then fill the prompt one bounded
                # slice per engine step — interleaved with decode. The
                # slot is occupied but NOT decode-eligible (PREFILLING)
                # until the final slice's suffix commit seeds its first
                # token.
                rs.status, rs.slot = PREFILLING, slot
                self.slots[slot] = rid
                self.rids_host[slot] = rid
                self._chunking[rid] = ChunkPlan(
                    rid=rid, slot=slot, start=matched, total=s_len,
                    budget=self.chunk, cow_src=cow_src)
                self.chunked_admissions += 1
                self._m_chunk_adm.inc()
                tr = obs_trace.COLLECTOR
                if tr is not None:
                    tr.begin(rid, "prefill", self.clock,
                             device=self.name, novel=s_len - matched)
                continue
            admitted.append((rid, rs, prompt, s_len, slot, table_row,
                             matched, cow_src))

        # group by NOVEL-length prefill bucket, preserving admission
        # order. A group with any prefix-cache hit commits through the
        # batched suffix path (plain members ride along: their zeroed
        # prefix is masked inside attention — exact); prefix-free groups
        # keep the PR 1/4 full-prefill path unchanged.
        groups: dict[int, list[tuple]] = {}
        for item in admitted:
            bucket = self._bucket_len(item[3] - item[6])
            groups.setdefault(bucket, []).append(item)
        return sum(
            self._commit_suffix_group(bucket, group)
            if any(it[6] > 0 for it in group)
            else self._commit_group(bucket, group)
            for bucket, group in groups.items())

    def _commit_group(self, bucket: int, group: list[tuple]) -> int:
        """Prefill + commit one same-bucket admission group: ONE batched
        prefill dispatch and ONE donated multi-slot commit dispatch."""
        n = len(group)
        padded = np.zeros((n, bucket), np.int32)
        lens = np.zeros((n,), np.int32)
        for i, (_, _, prompt, s_len, *_rest) in enumerate(group):
            padded[i, :s_len] = prompt
            lens[i] = s_len
        pre = self._prefill_for_len(bucket)
        logits, sub = pre(self.params, jnp.asarray(padded),
                          jnp.asarray(lens))
        self.prefill_dispatches += 1
        self._m_prefill_disp.inc()
        slots = np.array([g[4] for g in group], np.int32)
        rids = np.array([g[0] for g in group], np.uint32)
        args = (self.cache, self.pam_state, self.tokens_dev, sub, logits,
                jnp.asarray(slots), jnp.asarray(lens), jnp.asarray(rids))
        if self.allocator is not None:
            args += (jnp.asarray(np.stack([g[5] for g in group])),)
        (self.cache, self.pam_state, self.tokens_dev,
         first_dev) = self._admit_jit(*args)
        self.admit_dispatches += 1
        self._m_admit_disp.inc()
        for rid, _, _, _, slot, *_rest in group:
            self.rids_host[slot] = rid
        if self.trie is not None:
            # publish AFTER the commit lands the prompts' KV in the pool
            # (and before any EOS teardown below frees the tables): the
            # trie takes its own refcount, so these prefixes stay cached
            # even after their publisher finishes
            self.novel_prefill_tokens += int(lens.sum())
            for rid, _, prompt, _, _, *_rest in group:
                self.trie.insert(prompt, self.allocator.table(rid))
        firsts = np.asarray(first_dev)
        for i, (rid, rs, _, _, slot, *_rest) in enumerate(group):
            self._finish_admit(rid, rs, slot, int(firsts[i]))
        return int(lens.sum())

    def _finish_admit(self, rid: int, rs: RequestState, slot: int,
                      tok: int) -> None:
        """Shared admission epilogue: record the first token and mark
        the request RUNNING — or DONE immediately when the PREFILL's
        token already ends it (EOS, or a max_new_tokens budget of 1).
        Such requests never join a decode wave (the fast path's
        _consume would otherwise skip them), so their times stamp
        here."""
        eos = self.scfg.eos_token
        rs.status, rs.slot = RUNNING, slot
        rs.outputs.append(tok)
        rs.planned = 1
        rs.first_token_time = None         # stamped after latency charge
        self.slots[slot] = rid
        self._m_decode_tokens.inc()
        tr = obs_trace.COLLECTOR
        if tr is not None:
            # begin() auto-closes the open queued/prefill phase
            tr.begin(rid, "decode", self.clock, device=self.name)
        if (eos >= 0 and tok == eos) or rs.request.max_new_tokens <= 1:
            rs.status = DONE
            rs.first_token_time = self.clock
            rs.token_times = [self.clock]
            rs.finish_time = self.clock
            self.slots[slot] = None
            if self.allocator is not None:
                self.allocator.free(rid)
            self._trace_finish(rs)

    def _suffix_coords(self, row: np.ndarray, start: int, t: int,
                       width: int) -> tuple[np.ndarray, np.ndarray]:
        """Token-granular pool scatter coordinates for ``width`` suffix
        positions beginning at absolute position ``start`` (``t`` of
        them real); padding past ``t`` routes to the sentinel trash
        block."""
        bs = self.block_size
        nb = self.scfg.max_len // bs
        pos = start + np.arange(width)
        bids = np.where(np.arange(width) < t,
                        row[np.minimum(pos // bs, nb - 1)],
                        self.sentinel).astype(np.int32)
        sids = (pos % bs).astype(np.int32)
        return bids, sids

    def _commit_suffix_group(self, bucket: int,
                             group: list[tuple]) -> int:
        """Prefill + commit one same-bucket admission group through the
        SUFFIX path: ONE batched suffix-prefill dispatch (each row's
        cached prefix gathered from the pool through its table — all
        zeros for plain riders) and ONE donated multi-slot commit
        dispatch (per-row CoW -> suffix scatter -> hot-row rebuild ->
        first-token sample -> PAM placement; ``_suffix_commit_fn``).
        Also commits FINAL chunked-prefill slices (``start`` = the last
        slice's begin; earlier slices already live in the pool).
        Returns the novel-token count — the group's actual prefill
        cost."""
        bs = self.block_size
        nb = self.scfg.max_len // bs
        n = len(group)
        padded = np.zeros((n, bucket), np.int32)
        suf_lens = np.zeros((n,), np.int32)
        starts = np.zeros((n,), np.int32)
        full_lens = np.zeros((n,), np.int32)
        rows = np.zeros((n, nb), np.int32)
        read_rows = np.zeros((n, nb), np.int32)
        bids = np.zeros((n, bucket), np.int32)
        sids = np.zeros((n, bucket), np.int32)
        cow_srcs = np.full((n,), self.sentinel, np.int32)
        cow_dsts = np.full((n,), self.sentinel, np.int32)
        cow_pins: list[int] = []
        for i, (rid, _, prompt, s_len, _, _, start, cow_src) \
                in enumerate(group):
            t = s_len - start
            padded[i, :t] = prompt[start:]
            suf_lens[i], starts[i], full_lens[i] = t, start, s_len
            row = self.allocator.padded_table(rid, nb, self.sentinel)
            rows[i] = row
            # READ view of the table for the prefix gather: a CoW row's
            # tail positions live in the publisher's cow_src until the
            # commit dispatch duplicates it — the prefill runs first,
            # so it must read through the source block
            read_rows[i] = row
            if cow_src >= 0:
                nfull = start // bs
                read_rows[i, nfull] = cow_src
                cow_srcs[i] = cow_src
                cow_dsts[i] = row[nfull]
                cow_pins.append(cow_src)
            bids[i], sids[i] = self._suffix_coords(row, start, t, bucket)
        pre = _suffix_prefill_fn(self.cfg, self.scfg.max_len,
                                 None if self.cache_shardings is None
                                 else self.cache_shardings.lengths)
        logits, suf_k, suf_v = pre(
            self.params, jnp.asarray(padded), self.cache.pk,
            self.cache.pv, jnp.asarray(read_rows), jnp.asarray(starts),
            jnp.asarray(suf_lens))
        self.prefill_dispatches += 1
        self._m_prefill_disp.inc()
        slots = np.array([g[4] for g in group], np.int32)
        rids = np.array([g[0] for g in group], np.uint32)
        fn = _suffix_commit_fn(self.pam_cfg, bs, n,
                               self.scfg.temperature, self.scfg.top_k,
                               self.hot_window, self.scfg.sample_seed,
                               self.cache_shardings)
        (self.cache, self.pam_state, self.tokens_dev, first_dev) = fn(
            self.cache, self.pam_state, self.tokens_dev, suf_k, suf_v,
            logits, jnp.asarray(slots), jnp.asarray(full_lens),
            jnp.asarray(rids), jnp.asarray(rows), jnp.asarray(bids),
            jnp.asarray(sids), jnp.asarray(cow_srcs),
            jnp.asarray(cow_dsts))
        self.admit_dispatches += 1
        self._m_admit_disp.inc()
        for src in cow_pins:
            # the dispatch reading cow_src is enqueued; device ordering
            # makes any later reuse of the block safe — release the pin
            self.allocator.decref(src)
            self.cow_copies += 1
            self._m_cow.inc()
        if self.trie is not None:
            self.novel_prefill_tokens += int(suf_lens.sum())
        for rid, _, _, _, slot, *_rest in group:
            self.rids_host[slot] = rid
        if self.trie is not None:
            # publish AFTER the commit lands the suffix KV in the pool
            # and before any EOS teardown frees the tables
            for rid, _, prompt, _, _, *_rest in group:
                self.trie.insert(prompt, self.allocator.table(rid))
        firsts = np.asarray(first_dev)
        for i, (rid, rs, _, _, slot, *_rest) in enumerate(group):
            self._finish_admit(rid, rs, slot, int(firsts[i]))
        return int(suf_lens.sum())

    # --------------------------------------------- chunked prefill (PR 8)
    def _advance_chunks(self) -> int:
        """Advance every in-flight chunked admission by ONE slice (one
        fused dispatch each): intermediate slices scatter their KV into
        the pool (``_chunk_fill_fn``); the final slice commits through
        the batched suffix path, seeding the first token — the request
        turns RUNNING and joins the next decode wave. Returns prefill
        tokens processed (the latency model's admission charge), which
        never exceeds ``prefill_chunk`` per in-flight admission per
        step: that bound is what turns one monolithic prefill stall
        into evenly-spread slices."""
        if not self._chunking:
            return 0
        total = 0
        for rid in list(self._chunking):
            plan = self._chunking[rid]
            begin, t = plan.next_slice()
            final = begin + t >= plan.total
            rs = self.requests[rid]
            prompt = np.asarray(rs.request.prompt, np.int32)
            if final:
                del self._chunking[rid]
                # cow_src is -1 here by construction: a chunked plan
                # has >= 2 slices, so the first (CoW-carrying) slice
                # was an intermediate fill
                self._commit_suffix_group(
                    self._bucket_len(t),
                    [(rid, rs, prompt, plan.total, plan.slot, None,
                      begin, -1)])
            else:
                self._chunk_fill(plan, prompt, begin, t)
                plan.done += t
            plan.slices += 1
            self.chunk_slices += 1
            self._m_chunk_slices.inc()
            self.max_chunk_slice = max(self.max_chunk_slice, t)
            total += t
        return total

    def _chunk_fill(self, plan: ChunkPlan, prompt: np.ndarray,
                    begin: int, t: int) -> None:
        """One INTERMEDIATE slice: a single fused dispatch (optional
        first-slice CoW -> prefix gather -> suffix prefill over the
        slice -> pool scatter). Slices are always exactly ``budget``
        tokens, so this traces once per engine config."""
        nb = self.scfg.max_len // self.block_size
        bs = self.block_size
        row = self.allocator.padded_table(plan.rid, nb, self.sentinel)
        cow = plan.cow_src >= 0
        cow_dst = row[begin // bs] if cow else self.sentinel
        bids, sids = self._suffix_coords(row, begin, t, t)
        fn = _chunk_fill_fn(self.cfg, self.scfg.max_len, cow,
                            self.cache_shardings)
        self.cache = fn(
            self.params, self.cache,
            jnp.asarray(prompt[begin:begin + t][None]),
            jnp.asarray(row), jnp.int32(begin), jnp.int32(t),
            jnp.asarray(bids), jnp.asarray(sids),
            jnp.int32(max(plan.cow_src, 0)), jnp.int32(cow_dst))
        self.prefill_dispatches += 1
        self._m_prefill_disp.inc()
        if cow:
            self.allocator.decref(plan.cow_src)
            self.cow_copies += 1
            self._m_cow.inc()
            plan.cow_src = -1
        if self.trie is not None:
            self.novel_prefill_tokens += t

    # ------------------------------------------------------------ stepping
    def step(self) -> dict[str, Any]:
        """One engine iteration: admission (prefill) + one decode step for
        all running sequences — a single fused device dispatch. Returns
        step stats."""
        t0 = time.perf_counter()
        prefill_tokens = self._admit() + self._advance_chunks()

        # decode-eligible = occupied AND past prefill (a chunking slot
        # is claimed but PREFILLING until its final slice commits)
        active_np = np.array([
            s is not None and self.requests[s].status == RUNNING
            for s in self.slots])
        stats: dict[str, Any] = {"prefill_tokens": prefill_tokens,
                                 "active": int(active_np.sum()),
                                 "tier_reads": np.zeros(3, np.int64),
                                 "moved_tokens": 0}
        if active_np.any():
            fused = self._get_micro(1)
            (self.tokens_dev, self.cache, self.pam_state,
             bufs) = fused(
                self.params, self.tokens_dev, self.cache, self.pam_state,
                jnp.asarray(active_np), jnp.asarray(self.rids_host))
            self.decode_dispatches += 1
            self.decode_device_steps += 1
            self._m_decode_disp.inc()
            self._m_device_steps.inc()
            if self.mgr:
                stats["tier_reads"] = np.asarray(
                    bufs.tier_reads[0], dtype=np.int64)
                stats["hit_rate"] = float(bufs.hit_rate[0])
                stats["moved_tokens"] = int(bufs.moved[0])
            if self.block_size:
                stats["blocks_touched"] = int(bufs.blocks[0, 0])
                stats["blocks_window"] = int(bufs.blocks[0, 1])
                stats["pool_occupancy"] = self.allocator.occupancy
                self.blocks_touched_total += stats["blocks_touched"]
                self.blocks_window_total += stats["blocks_window"]
            stats["batch_lengths"] = np.asarray(bufs.lengths[0])
            nxt = np.asarray(bufs.tokens[0])
            self._emit_tokens(nxt, active_np)
        else:
            stats["batch_lengths"] = np.asarray(self.cache.lengths)

        # --- timing: modeled or wall-clock --------------------------------
        if self.latency_model is not None:
            dt = float(self.latency_model(stats))
        else:
            dt = time.perf_counter() - t0
        self.clock += dt
        if not prefill_tokens:
            # load signal: steady DECODE latency only — admission steps
            # carry a prefill spike that would whipsaw router/balancer
            # cost comparisons (prefill is priced separately there)
            self.last_step_time = dt
            self.last_step_stats = stats
        if active_np.any():
            self.busy_time += dt
        stats["step_time_s"] = dt
        self._stamp_times()
        self.steps += 1
        self._observe_step(stats, dt)
        return stats

    def _emit_tokens(self, nxt: np.ndarray, active: np.ndarray) -> None:
        for slot, rid in enumerate(self.slots):
            if rid is None or not active[slot]:
                continue
            rs = self.requests[rid]
            tok = int(nxt[slot])
            rs.outputs.append(tok)
            self._m_decode_tokens.inc()
            rs.planned = len(rs.outputs)
            done = (len(rs.outputs) >= rs.request.max_new_tokens
                    or tok == self.scfg.eos_token)
            if done:
                rs.status = DONE
                rs.finish_time = None  # stamped in _stamp_times
                self.slots[slot] = None
                if self.allocator is not None:
                    self.allocator.free(rid)   # blocks recycle; the next
                    # owner overwrites them at prefill commit

    def _stamp_times(self) -> None:
        for rs in self.requests.values():
            if rs.status in (RUNNING, DONE):
                if rs.first_token_time is None:
                    rs.first_token_time = self.clock
                if len(rs.token_times) < len(rs.outputs):
                    rs.token_times += [self.clock] * (
                        len(rs.outputs) - len(rs.token_times))
                if rs.status == DONE and rs.finish_time is None:
                    rs.finish_time = self.clock
                    self._trace_finish(rs)

    def run(self, max_steps: int = 10_000) -> dict[str, Any]:
        """Run until all submitted requests finish. Returns summary."""
        if self.scfg.micro_steps > 1:
            return self._run_fast(max_steps)
        for _ in range(max_steps):
            if not self.waiting and all(s is None for s in self.slots):
                break
            self.step()
        return self.summary()

    # ------------------------------------------------- pipelined fast path
    def _run_fast(self, max_steps: int) -> dict[str, Any]:
        """Multi-step fused micro-loop. With ``eos_token == -1`` the loop
        is PIPELINED: the host consumes step *t-1*'s token/stat buffers
        while step *t* runs on device, and request lifecycle (doneness,
        slot frees, admission) advances from *planned* token counts —
        known without reading token values.

        With ``eos_token >= 0`` the micro-loop still fuses k device steps
        per dispatch (EOS detection runs ON DEVICE: a slot that samples
        EOS freezes for the remaining micro-steps), but each dispatch's
        buffers are consumed synchronously so EOS completions free their
        slot before the next admission pass."""
        micro = self.scfg.micro_steps
        pipelined = self.scfg.eos_token < 0
        pending: Optional[tuple] = None
        self._wall_anchor = time.perf_counter()
        while self.steps < max_steps:
            if not self.waiting and all(s is None for s in self.slots):
                break
            prefill_tokens = self._admit() + self._advance_chunks()
            pairs = [(i, rid) for i, rid in enumerate(self.slots)
                     if rid is not None
                     and self.requests[rid].status == RUNNING]
            if not pairs:
                if self._chunking:
                    # chunk slices are filling with nothing decoding:
                    # charge the admission latency directly (there is
                    # no decode dispatch to carry it) so TTFT stays
                    # honest in micro mode
                    self._charge_prefill_only(prefill_tokens)
                if prefill_tokens:
                    continue   # the whole admission wave finished at
                    # prefill (EOS / 1-token budgets); admit the rest
                break   # nothing runnable (all waiting requests invalid)
            remaining = min(self.requests[rid].request.max_new_tokens
                            - self.requests[rid].planned
                            for _, rid in pairs)
            k = 1       # largest pow-2 micro-count no request overshoots
            while k * 2 <= min(remaining, micro):
                k *= 2
            active_np = np.zeros((self.scfg.max_batch,), bool)
            for slot, _ in pairs:
                active_np[slot] = True
            fused = self._get_micro(k)
            (self.tokens_dev, self.cache, self.pam_state,
             bufs) = fused(
                self.params, self.tokens_dev, self.cache, self.pam_state,
                jnp.asarray(active_np), jnp.asarray(self.rids_host))
            self.decode_dispatches += 1
            self.decode_device_steps += k
            self._m_decode_disp.inc()
            self._m_device_steps.inc(k)
            self.steps += k
            rec = (bufs, pairs, k, prefill_tokens)
            if pipelined:
                # advance lifecycle from planned counts — no token readback
                for slot, rid in pairs:
                    rs = self.requests[rid]
                    rs.planned += k
                    if rs.planned >= rs.request.max_new_tokens:
                        rs.status = DONE
                        self.slots[slot] = None
                        if self.allocator is not None:
                            self.allocator.free(rid)
                if pending is not None:
                    self._consume(pending)  # overlaps with this dispatch
                pending = rec
            else:
                self._consume(rec)          # EOS needs the token values
        if pending is not None:
            self._consume(pending)
        return self.summary()

    def _consume(self, rec: tuple) -> None:
        """Drain one dispatch's StepBufs: append token values, charge the
        latency model per fused sub-step, stamp times. In EOS mode this
        also drives the lifecycle: the first EOS (or the max_new_tokens
        boundary) marks the request DONE and frees its slot and blocks —
        post-EOS micro-steps were frozen on device and are skipped."""
        bufs, pairs, k, prefill_tokens = rec
        eos = self.scfg.eos_token
        toks = np.asarray(bufs.tokens)              # blocks until done
        reads = np.asarray(bufs.tier_reads, dtype=np.int64)
        moved = np.asarray(bufs.moved)
        lens = np.asarray(bufs.lengths)
        hits = np.asarray(bufs.hit_rate)
        if self.block_size:
            blocks = np.asarray(bufs.blocks)
            self.blocks_touched_total += int(blocks[:, 0].sum())
            self.blocks_window_total += int(blocks[:, 1].sum())
        if self.latency_model is None:
            wall = time.perf_counter()
            dt_wall = (wall - self._wall_anchor) / k
            self._wall_anchor = wall
        for j in range(k):
            stats = {"prefill_tokens": prefill_tokens if j == 0 else 0,
                     "active": len(pairs), "tier_reads": reads[j],
                     "moved_tokens": int(moved[j]),
                     "batch_lengths": lens[j]}
            if self.mgr:
                stats["hit_rate"] = float(hits[j])
            dt = (float(self.latency_model(stats))
                  if self.latency_model is not None else dt_wall)
            self.clock += dt
            if not stats["prefill_tokens"]:
                self.last_step_time = dt     # decode-only load signal
                self.last_step_stats = stats
            self.busy_time += dt
            self._observe_step(stats, dt)
            for slot, rid in pairs:
                rs = self.requests[rid]
                if eos >= 0 and rs.status == DONE:
                    continue                 # froze at EOS mid-dispatch
                tok = int(toks[j, slot])
                rs.outputs.append(tok)
                self._m_decode_tokens.inc()
                rs.planned = max(rs.planned, len(rs.outputs))
                if rs.first_token_time is None:
                    rs.first_token_time = self.clock
                while len(rs.token_times) < len(rs.outputs):
                    rs.token_times.append(self.clock)
                done = (len(rs.outputs) >= rs.request.max_new_tokens
                        or (eos >= 0 and tok == eos))
                if done and rs.finish_time is None:
                    rs.finish_time = self.clock
                    self._trace_finish(rs)
                if done and rs.status != DONE:
                    rs.status = DONE
                    if eos >= 0:             # EOS mode frees slots here
                        self.slots[slot] = None
                        if self.allocator is not None:
                            self.allocator.free(rid)

    def _charge_prefill_only(self, prefill_tokens: int) -> None:
        """Clock charge for a fast-path iteration that did admission/
        chunk-fill work but dispatched no decode step (nothing RUNNING
        yet). Only the chunked path takes it — legacy admission waves
        keep their PR 1 timing behavior bit-for-bit."""
        stats = {"prefill_tokens": prefill_tokens, "active": 0,
                 "tier_reads": np.zeros(3, np.int64), "moved_tokens": 0,
                 "batch_lengths": np.asarray(self.cache.lengths)}
        if self.latency_model is not None:
            self.clock += float(self.latency_model(stats))
        else:
            wall = time.perf_counter()
            self.clock += wall - self._wall_anchor
            self._wall_anchor = wall

    # ------------------------------------------ cluster / migration hooks
    def can_accept(self, n_tokens: int, *,
                   reserve_queued: bool = True) -> bool:
        """True iff a request with an ``n_tokens`` window (prompt +
        generation budget) could be admitted RIGHT NOW: a free slot and,
        in paged mode, enough free pool blocks.

        With ``reserve_queued`` (default) both are counted NET of the
        engine's own waiting queue — requests already bound here but not
        yet prefilled — so a router's dispatch round cannot over-assign
        a device. Migration rescues pass ``reserve_queued=False`` on
        purpose: pulling a straggler off a slow device is allowed to
        compete with queued admissions for slots/blocks (shortage
        degrades to admission backpressure, never failure), which beats
        strict admission order when the alternative is the straggler
        finishing on a device several times slower."""
        queued_slots = len(self.waiting) if reserve_queued else 0
        if len(self._free_slots()) - queued_slots < 1:
            return False
        if self.allocator is None:
            return True
        queued = sum(
            self.allocator.blocks_for(
                len(self.requests[rid].request.prompt)
                + self.requests[rid].request.max_new_tokens)
            for rid in self.waiting) if reserve_queued else 0
        return (self.allocator.blocks_for(n_tokens)
                <= self.allocator.free_blocks - queued)

    def serviceable(self, n_tokens: int) -> bool:
        """True iff an ``n_tokens`` window fits this device at all
        (``max_len`` and total pool size) — the admission feasibility
        check routers use before assigning a request."""
        if n_tokens > self.scfg.max_len:
            return False
        if self.allocator is None:
            return True
        return self.allocator.blocks_for(n_tokens) <= self.allocator.num_blocks

    def load_signal(self) -> dict[str, Any]:
        """Host-visible load snapshot for routers/balancers: queue depth,
        running count, modeled last-step latency and pool occupancy —
        the paper's inter-device scheduling cost signal (§4.3)."""
        running = sum(s is not None for s in self.slots)
        return {
            "queue_depth": len(self.waiting),
            "running": running,
            "free_slots": self.scfg.max_batch - running,
            "step_time_s": self.last_step_time,
            "pool_occupancy": (self.allocator.occupancy
                               if self.allocator is not None else 0.0),
            "free_blocks": (self.allocator.free_blocks
                            if self.allocator is not None else -1),
            "clock": self.clock,
        }

    def slot_importance_mass(self) -> dict[int, float]:
        """Per running request: total importance mass (sum of the eq. 7
        EMA over its tokens) — the balancer's migration-victim signal
        (move the LOWEST mass first: cheapest accuracy stake)."""
        running = [(slot, rid) for slot, rid in enumerate(self.slots)
                   if rid is not None
                   and self.requests[rid].status == RUNNING]
        if self.pam_cfg is None:
            return {rid: 0.0 for _, rid in running}
        mass = np.asarray(jnp.sum(self.pam_state.importance, axis=-1))
        return {rid: float(mass[slot]) for slot, rid in running}

    def _require_migratable(self) -> None:
        if self.cache.k.size == 0 or self.cache.conv.size > 0 \
                or self.cache.ckv.size > 0:
            raise ValueError(f"{self.cfg.name}: KV migration requires a "
                             f"pure GQA decode cache")

    def export_request(self, rid: int) -> dict[str, Any]:
        """Export a RUNNING request for inter-device migration: gather
        its KV into the portable logical layout (hot tokens from the
        dense cache, warm/cold through the block table — the §6.2 sender
        side), copy its PAM rows and host bookkeeping, then free the slot
        and pool blocks WITHOUT finishing the request. Returns the
        snapshot dict consumed by ``import_request`` (see
        ``repro.cluster.migration.KVSnapshot``)."""
        self._require_migratable()
        rs = self.requests.get(rid)
        if rs is None or rs.status != RUNNING:
            raise ValueError(f"request {rid} is not running here")
        slot = rs.slot
        nb = self.scfg.max_len // self.block_size if self.block_size else 0
        table_row = (jnp.asarray(self.allocator.padded_table(
            rid, nb, self.sentinel)) if self.allocator is not None
            else jnp.zeros((0,), jnp.int32))
        tier_row = (self.pam_state.tier[slot] if self.pam_cfg is not None
                    else jnp.zeros((self.scfg.max_len,), jnp.int32))
        k_row, v_row = _export_gather_fn(self.block_size, self.hot_window)(
            self.cache.k, self.cache.v, self.cache.pk, self.cache.pv,
            table_row, tier_row, jnp.int32(slot),
            self.cache.lengths[slot])
        snap = {
            "request": rs.request,
            "outputs": list(rs.outputs),
            "planned": len(rs.outputs),
            "length": int(np.asarray(self.cache.lengths[slot])),
            "token": int(np.asarray(self.tokens_dev[slot])),
            "k": np.asarray(k_row),
            "v": np.asarray(v_row),
            "importance": (np.asarray(self.pam_state.importance[slot])
                           if self.pam_cfg is not None else None),
            "tier": (np.asarray(tier_row)
                     if self.pam_cfg is not None else None),
            "last_hot": (np.asarray(self.pam_state.last_hot[slot])
                         if self.pam_cfg is not None else None),
            "first_token_time": rs.first_token_time,
            "token_times": list(rs.token_times),
            "arrival": rs.request.arrival,
            "src": self.name,
        }
        # free-without-finish: the slot recycles and the request's
        # reference on each block DECREFS — with prefix sharing, blocks
        # another live request or the trie also maps survive the export
        # untouched (their bytes stay valid for every remaining sharer);
        # the migrating request's only live copy is now the snapshot
        self.slots[slot] = None
        if self.allocator is not None:
            self.allocator.free(rid)
        del self.requests[rid]
        self.migrations_out += 1
        self._m_mig_out.inc()
        tr = obs_trace.COLLECTOR
        if tr is not None:
            tr.mark(rid, "migrate_out", self.clock, src=self.name)
            tr.begin(rid, "suspended", self.clock)  # closes "decode"
        return snap

    def import_request(self, snap: dict[str, Any]) -> None:
        """Admit a migrated request mid-decode (§6.2 receiver side): ONE
        donated dispatch installs the snapshot KV into a free slot (and
        through a freshly-allocated block table in paged mode), inserts
        the PAM rows and seeds the device token vector; decode resumes
        exactly where the source stopped. Raises ``OutOfBlocks`` /
        ``ValueError`` when this device cannot take the request — check
        ``can_accept`` first."""
        self._require_migratable()
        req: Request = snap["request"]
        free = self._free_slots()
        if not free:
            raise ValueError(f"{self.name}: no free slot for migrated "
                             f"request {req.id}")
        if snap["k"].shape[2] != self.scfg.max_len:
            raise ValueError("snapshot window does not match max_len "
                             f"({snap['k'].shape[2]} vs {self.scfg.max_len})")
        window = len(req.prompt) + req.max_new_tokens
        table_row = None
        if self.allocator is not None:
            # physical ids never travel: the import always allocates
            # fresh blocks here (no cross-device sharing); trie-only
            # cached prefixes yield first under pressure
            self._reserve_fresh(self.allocator.blocks_for(window))
            self.allocator.allocate(req.id, window)   # may raise OutOfBlocks
            table_row = self.allocator.padded_table(
                req.id, self.scfg.max_len // self.block_size, self.sentinel)
            self.peak_occupancy = max(self.peak_occupancy,
                                      self.allocator.occupancy)
        slot = free[0]
        Smax = self.scfg.max_len
        imp = (snap["importance"] if snap["importance"] is not None
               else np.zeros((Smax,), np.float32))
        tier = (snap["tier"] if snap["tier"] is not None
                else np.zeros((Smax,), np.int32))
        lh = (snap["last_hot"] if snap["last_hot"] is not None
              else np.zeros((Smax,), bool))
        args = (self.cache, self.pam_state, self.tokens_dev,
                jnp.asarray(snap["k"]), jnp.asarray(snap["v"]),
                jnp.asarray(imp), jnp.asarray(tier), jnp.asarray(lh),
                jnp.int32(slot), jnp.int32(snap["length"]),
                jnp.int32(snap["token"]))
        if table_row is not None:
            args += (jnp.asarray(table_row),)
        fn = _import_commit_fn(self.pam_cfg is not None, self.block_size,
                               self.hot_window, self.cache_shardings)
        self.cache, self.pam_state, self.tokens_dev = fn(*args)
        rs = RequestState(
            request=req, status=RUNNING, slot=slot,
            outputs=list(snap["outputs"]), planned=snap["planned"],
            first_token_time=snap["first_token_time"],
            token_times=list(snap["token_times"]))
        self.requests[req.id] = rs
        self.slots[slot] = req.id
        self.rids_host[slot] = req.id
        if self.trie is not None:
            # the imported row holds the prompt's KV at its prompt
            # positions — publish it so later arrivals share it here too
            self.trie.insert(np.asarray(req.prompt, np.int32),
                             self.allocator.table(req.id))
        self.migrations_in += 1
        self._m_mig_in.inc()
        tr = obs_trace.COLLECTOR
        if tr is not None:
            tr.mark(req.id, "migrate_in", self.clock, dst=self.name)
            tr.begin(req.id, "decode", self.clock, device=self.name)

    # ----------------------------------------- suspend / resume (recovery)
    def suspend_request(self, rid: int) -> dict[str, Any]:
        """Preemption-by-demotion hook: detach a RUNNING request into a
        host-held snapshot, freeing its slot and pool blocks for a more
        urgent admission. The snapshot is ``export_request``'s portable
        dict — resuming it later (here or on any compatible engine) via
        ``resume_request`` continues the stream bit-exactly, because the
        per-request sampling keys depend only on (seed, rid, position)."""
        return self.export_request(rid)

    def resume_request(self, snap: dict[str, Any]) -> None:
        """Re-admit a suspended request (one donated dispatch); the twin
        of ``suspend_request``. Raises ``OutOfBlocks``/``ValueError``
        when capacity is still short — check ``can_accept`` first."""
        self.import_request(snap)

    # ----------------------------------------------- unified serving surface
    def as_router(self, *, preemptible: bool = False):
        """This engine wrapped as a one-device ``ClusterRouter`` — the
        single backend shape every serving surface (CLI, async server,
        benchmarks) drives since PR 10. Scheduling stays a no-op with
        one device; the router contributes admission, eventing and the
        ``serve`` generator."""
        from repro.cluster.router import ClusterRouter
        return ClusterRouter.for_engine(self, preemptible=preemptible)

    def serve(self, requests: Optional[Iterable[Request]] = None, *,
              max_ticks: Optional[int] = None) -> Iterator[Any]:
        """Unified streaming surface: submit ``requests`` (if given) and
        yield ``ServeEvent``s until everything drains. Identical shape
        on a bare engine and on a cluster (``ClusterRouter.serve``)."""
        yield from self.as_router().serve(requests, max_ticks=max_ticks)

    def params_bytes_per_device(self) -> int:
        """Bytes of model params RESIDENT PER DEVICE. Unsharded engines
        hold the full tree; a shard-``s`` replica group holds one
        GSPMD-sharded copy, so this is ~1/s of the total (replicated
        leaves — norms, biases — keep full size)."""
        total = 0
        for leaf in jax.tree.leaves(self.params):
            shape = getattr(leaf, "shape", ())
            shd = getattr(leaf, "sharding", None)
            if shd is not None and hasattr(shd, "shard_shape"):
                shape = shd.shard_shape(shape)
            n = 1
            for d in shape:
                n *= d
            total += n * getattr(leaf, "dtype", np.dtype(np.float32)
                                 ).itemsize
        return total

    # ------------------------------------------------------------ metrics
    def summary(self) -> dict[str, Any]:
        """Run metrics: throughput, TPOT percentiles, dispatch counts; in
        paged mode also pages-touched vs dense-window-pages per step (the
        sparse-read win) and pool occupancy."""
        done = [r for r in self.requests.values() if r.status == DONE]
        total_tokens = sum(len(r.outputs) for r in done)
        tpots = []
        for r in done:
            if len(r.token_times) > 1:
                gaps = np.diff(r.token_times)
                tpots.extend(gaps.tolist())
        out = {
            "finished": len(done),
            "total_tokens": total_tokens,
            "sim_time_s": self.clock,
            "throughput_tok_s": total_tokens / max(self.clock, 1e-9),
            "p50_tpot_s": float(np.percentile(tpots, 50)) if tpots else 0.0,
            "p99_tpot_s": float(np.percentile(tpots, 99)) if tpots else 0.0,
            "steps": self.steps,
            "decode_dispatches": self.decode_dispatches,
            "decode_device_steps": self.decode_device_steps,
            "prefill_dispatches": self.prefill_dispatches,
            "admit_dispatches": self.admit_dispatches,
            "migrations_in": self.migrations_in,
            "migrations_out": self.migrations_out,
        }
        if self.shard > 1:
            out["shard"] = self.shard
            out["param_bytes_per_device"] = self.params_bytes_per_device()
        if self.block_size:
            n = max(self.decode_device_steps, 1)
            out["blocks_touched_per_step"] = self.blocks_touched_total / n
            out["blocks_window_per_step"] = self.blocks_window_total / n
            out["pool_occupancy_peak"] = self.peak_occupancy
            out["pool_occupancy_now"] = self.allocator.occupancy
            # hot-tier footprint: ring slots x KV bytes, per batch slot —
            # independent of max_len once hot_window is set (PR 5)
            out["hot_window"] = self.hot_window or self.scfg.max_len
            out["hot_bytes_per_slot"] = int(
                (self.cache.k.nbytes + self.cache.v.nbytes)
                // self.scfg.max_batch)
        if self.chunk:
            out["chunked_admissions"] = self.chunked_admissions
            out["chunk_slices"] = self.chunk_slices
            out["max_chunk_slice_tokens"] = self.max_chunk_slice
        if self.trie is not None:
            out["prefix_hits"] = self.prefix_hits
            out["cached_prefix_tokens"] = self.cached_prefix_tokens
            out["novel_prefill_tokens"] = self.novel_prefill_tokens
            out["cow_copies"] = self.cow_copies
            out["trie_blocks"] = self.trie.num_blocks
            out["trie_evictions"] = self.trie.evictions
        return out

    def slo_attainment(self, slo_s: float) -> float:
        """Fraction of decode-token gaps within the SLO (paper Fig. 9)."""
        gaps = []
        for r in self.requests.values():
            if len(r.token_times) > 1:
                gaps.extend(np.diff(r.token_times).tolist())
        if not gaps:
            return 1.0
        return float(np.mean(np.asarray(gaps) <= slo_s))


# Public alias matching the paper's naming.
PAMEngine = ServingEngine
