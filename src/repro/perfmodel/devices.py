"""Heterogeneous PIM device classes for the multi-device cluster
(paper §4.3: "the KV interface ... balances load across heterogeneous
PIM devices").

A ``DeviceClass`` parameterizes one *kind* of serving device by scaling
the Table-1 node hardware: an HBM-PIM-class device is fast but holds a
small KV pool; a CXL/DDR-PIM-class device is slower but holds a much
larger pool and batch. ``make_device_latency_model`` turns a class into
the per-step latency model a ``ServingEngine`` runs under, so a cluster
of engines built from different classes models the paper's
heterogeneous fleet with the same injectable-timing machinery single
engines already use (``repro.perfmodel.latency``).

Class registry + the ``--devices hbm:1,cxl:2`` CLI syntax parser live
here so the router, benchmarks and launcher share one source of truth.
"""

from __future__ import annotations

import dataclasses

from repro.core.tiers import DDR_PIM, HBM_PIM, SSD_PIM
from repro.perfmodel.latency import make_latency_model
from repro.perfmodel.model import (PAM_LLAMA_7B, ModelDesc, NodeHW,
                                   SystemKind, make_system)


@dataclasses.dataclass(frozen=True)
class DeviceClass:
    """One kind of serving device in a heterogeneous cluster.

    ``bw_scale`` multiplies every tier's bandwidth/compute (and the NPU
    roofline) relative to the Table-1 node; ``pool_scale`` sizes the
    paged KV pool relative to full residency (``max_batch`` windows), so
    < 1 overcommits and admission backpressure engages earlier.
    """
    name: str
    kind: SystemKind = SystemKind.PAM
    bw_scale: float = 1.0          # tier + NPU bandwidth multiplier
    max_batch: int = 4             # concurrent sequences on this device
    pool_scale: float = 1.0        # pool blocks / full-residency blocks
    context_scale: int = 4096      # engine token -> hardware tokens

    def pool_blocks(self, max_len: int, block_size: int) -> int:
        """Physical pool blocks for a given engine geometry."""
        full = self.max_batch * (max_len // block_size)
        return max(int(round(self.pool_scale * full)), 1)


# The two classes the paper's heterogeneity argument needs: a fast
# small-capacity device and a slow large-capacity one. "cxl" models a
# CXL-attached DDR-PIM expander at the paper's DDR:HBM bandwidth ratio
# (~1:4, Table 1), with twice the batch room and an uncut pool.
HBM_CLASS = DeviceClass("hbm", bw_scale=1.0, max_batch=4, pool_scale=0.75)
CXL_CLASS = DeviceClass("cxl", bw_scale=0.25, max_batch=8, pool_scale=1.0)
DDR_CLASS = DeviceClass("ddr", bw_scale=0.5, max_batch=6, pool_scale=1.0)

DEVICE_CLASSES: dict[str, DeviceClass] = {
    d.name: d for d in (HBM_CLASS, CXL_CLASS, DDR_CLASS)
}


def get_device_class(name: str) -> DeviceClass:
    try:
        return DEVICE_CLASSES[name]
    except KeyError:
        raise ValueError(f"unknown device class {name!r}; have "
                         f"{sorted(DEVICE_CLASSES)}") from None


def parse_devices(spec: str) -> list[DeviceClass]:
    """Parse the launcher syntax ``"hbm:1,cxl:2"`` into a device list
    (one ``DeviceClass`` entry per physical device, order preserved)."""
    out: list[DeviceClass] = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, count = part.partition(":")
        n = int(count) if count else 1
        if n <= 0:
            raise ValueError(f"device count must be positive: {part!r}")
        out.extend([get_device_class(name)] * n)
    if not out:
        raise ValueError(f"empty device spec: {spec!r}")
    return out


def replica_group_class(dc: DeviceClass, group: int) -> DeviceClass:
    """Aggregate ``group`` same-class devices into ONE replica-group
    device (PR 10): tier/NPU bandwidth and pool capacity scale with the
    member count (the members serve one request stream cooperatively,
    each holding 1/group of the params and KV), while ``max_batch`` and
    ``context_scale`` describe the shared stream and stay per-group.
    Identity at ``group == 1``."""
    if group <= 1:
        return dc
    return dataclasses.replace(dc, bw_scale=dc.bw_scale * group,
                               pool_scale=dc.pool_scale * group)


def _scaled_hw(scale: float) -> NodeHW:
    base = NodeHW()
    s = lambda tier: dataclasses.replace(
        tier, read_bw=tier.read_bw * scale,
        compute_flops=tier.compute_flops * scale,
        link_bw=tier.link_bw * scale)
    return dataclasses.replace(
        base, npu_flops=base.npu_flops * scale,
        npu_hbm_bw=base.npu_hbm_bw * scale,
        pcie_bw=base.pcie_bw * scale,
        hbm=s(HBM_PIM), ddr=s(DDR_PIM), ssd=s(SSD_PIM))


def make_device_latency_model(dc: DeviceClass,
                              model_desc: ModelDesc = PAM_LLAMA_7B):
    """Latency model (engine step stats -> simulated seconds) for one
    device of class ``dc`` — the per-class timing the router/balancer
    cost signals are computed from."""
    system = make_system(dc.kind, hw=_scaled_hw(dc.bw_scale))
    return make_latency_model(system, model_desc,
                              context_scale=dc.context_scale)


def step_time_prior(dc: DeviceClass, model_desc: ModelDesc = PAM_LLAMA_7B,
                    *, batch: int | None = None, context_tokens: int = 64,
                    compression: int = 4) -> float:
    """A-priori decode-step latency estimate for a device class — the
    router's cost signal before the device has stepped once (afterwards
    the engine's real modeled ``last_step_time`` takes over). Assumes a
    PAM working set: ~``context/compression`` participating tokens per
    sequence, concentrated on the hot tier."""
    import numpy as np
    lat = make_device_latency_model(dc, model_desc)
    b = max(batch if batch is not None else dc.max_batch // 2, 1)
    ctx = np.full((b,), context_tokens, np.int64)
    reads = np.array([b * max(context_tokens // compression, 1), 0, 0],
                     np.int64)
    stats = {"prefill_tokens": 0, "active": b, "tier_reads": reads,
             "moved_tokens": 0, "batch_lengths": ctx}
    return float(lat(stats))
