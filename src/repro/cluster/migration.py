"""Inter-device KV migration (paper §4.3 / §6.2) — move a *running*
request between serving engines.

The currency is the ``KVSnapshot``: the request's KV in the portable
logical layout (hot tokens read from the source's dense hot-tier buffer
— THROUGH the rotated ring index map when the source runs a hot-window
ring (``ServingConfig.hot_window``) — warm/cold tokens gathered from
the paged pool THROUGH the block table — ``paged_kv.gather_sequence``,
the §6.2 command-reorder/sender step), plus the per-token PAM state
(importance EMA, tier tags, participation history) and the host
bookkeeping (emitted tokens, timing marks, the on-device next-token
seed). Because the snapshot is always absolute-coordinate, engines with
DIFFERENT hot windows (or none) interoperate: the importer re-bases
onto its own ring at commit.

Export frees the source's slot and pool blocks *without finishing* the
request; import is an admission-style donated dispatch on the target
that scatters the snapshot into a free slot and a freshly-allocated
block table (the §6.2 address-generation/receiver step). Physical block
ids never travel — they are device-local; only logical-layout KV does.
With the PR 7 prefix cache, "frees" means DECREFS: blocks of the
exported request that other requests (or the source's prefix trie)
still reference stay live on the source, so migrating one sharer never
invalidates its siblings' prefixes. The import side allocates fresh
blocks as before and then publishes the migrated prompt to the
*target's* trie, so later arrivals on the target can share it there.

Because the fused decode step's token choice depends only on the KV
bytes, the importance EMA and the cache length — never on tier tags or
the engine's global step parity (tier residency selects *which storage
is read*, and Alg. 1 merging makes the output exact under any split) —
a migrated request's token stream is IDENTICAL to an unmigrated twin's;
``tests/test_cluster.py`` pins that exactness across device classes.
This now holds at ANY temperature: sampling keys derive per request
inside the dispatch as ``fold_in(fold_in(seed, rid), position)``, so a
request's draws carry no engine-local PRNG state — ``can_migrate``
requires matching sampling policy (temperature, top_k, seed) and the
stream continues bit-exactly on the target.

Snapshots are CHECKSUMMED for the fault-tolerance layer
(``repro.cluster.recovery``): ``export`` seals a crc32 over the KV
bytes and host bookkeeping, ``verify`` re-derives it, and ``commit``
refuses a sealed snapshot whose checksum no longer matches
(``SnapshotCorruption``) — the detection point for corrupted transfers,
which the recovery manager turns into bounded retry/backoff.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Optional

import numpy as np

from repro.serving.engine import Request, ServingEngine


class SnapshotCorruption(RuntimeError):
    """A sealed ``KVSnapshot`` failed its checksum at commit time."""


@dataclasses.dataclass
class KVSnapshot:
    """Portable mid-decode state of one request (see module docstring).

    ``kv_bytes`` is the transfer volume a real interconnect would carry
    — only the *live* window (length tokens x layers x heads x head_dim
    x 2 tensors), which the router charges against the migration link.
    """
    request: Request
    outputs: list[int]             # tokens emitted so far (incl. prefill)
    length: int                    # cache length at export
    token: int                     # on-device next-token seed
    k: np.ndarray                  # (L, Hkv, Smax, dh) logical layout
    v: np.ndarray
    importance: Optional[np.ndarray]   # (Smax,) eq. 7 EMA, or None
    tier: Optional[np.ndarray]         # (Smax,) tier tags, or None
    last_hot: Optional[np.ndarray]     # (Smax,) participation history
    first_token_time: Optional[float]
    token_times: list[float]
    src: str                       # exporting device name
    checksum: Optional[int] = None   # crc32 seal; None = unsealed
    # PR 10: shard count of the EXPORTING engine, recorded for
    # observability only. The snapshot's logical (L, Hkv, Smax, dh)
    # layout is the resharding interface itself: the source's export
    # gather all-gathers its ring/pool shards into absolute
    # coordinates, and the target's import commit re-scatters through
    # ITS mesh's out_shardings — so migration between engines of any
    # two shard counts (1<->2, 2<->4, ...) needs no shard-aware code
    # here and stays bit-exact (the checksum intentionally excludes
    # this field: the same KV bytes seal identically at any shard).
    src_shard: int = 1

    @property
    def kv_bytes(self) -> int:
        L, Hkv, _, dh = self.k.shape
        return 2 * L * Hkv * dh * self.length * self.k.dtype.itemsize

    @classmethod
    def export(cls, engine: ServingEngine, rid: int) -> "KVSnapshot":
        """Detach a running request from ``engine`` (frees its slot and
        blocks) and wrap its state portably, sealed with a checksum."""
        d = engine.export_request(rid)
        snap = cls(request=d["request"], outputs=d["outputs"],
                   length=d["length"], token=d["token"], k=d["k"],
                   v=d["v"], importance=d["importance"], tier=d["tier"],
                   last_hot=d["last_hot"],
                   first_token_time=d["first_token_time"],
                   token_times=d["token_times"], src=d["src"],
                   src_shard=getattr(engine, "shard", 1))
        snap.seal()
        return snap

    # ------------------------------------------------------ wire integrity
    def _digest(self) -> int:
        """crc32 over everything exactness depends on: the KV bytes and
        the host bookkeeping that seeds the resumed decode."""
        head = repr((self.request.id, self.outputs, self.length,
                     self.token)).encode()
        crc = zlib.crc32(head)
        crc = zlib.crc32(np.ascontiguousarray(self.k), crc)
        crc = zlib.crc32(np.ascontiguousarray(self.v), crc)
        if self.importance is not None:
            crc = zlib.crc32(np.ascontiguousarray(self.importance), crc)
        return crc & 0xFFFFFFFF

    def seal(self) -> "KVSnapshot":
        self.checksum = self._digest()
        return self

    def verify(self) -> bool:
        """True iff unsealed or the seal still matches the content."""
        return self.checksum is None or self.checksum == self._digest()

    def clone(self) -> "KVSnapshot":
        """Deep copy — the 'wire copy' a transfer puts on the link, so
        in-flight corruption never touches the sender's pristine state
        (which rollback and retries re-send from)."""
        return dataclasses.replace(
            self, outputs=list(self.outputs), k=self.k.copy(),
            v=self.v.copy(),
            importance=(None if self.importance is None
                        else self.importance.copy()),
            tier=None if self.tier is None else self.tier.copy(),
            last_hot=(None if self.last_hot is None
                      else self.last_hot.copy()),
            token_times=list(self.token_times))

    def commit(self, engine: ServingEngine) -> None:
        """Install this snapshot on ``engine`` (one donated dispatch);
        decode resumes at the next engine step. A sealed snapshot is
        checksum-verified first — raising ``SnapshotCorruption`` BEFORE
        any slot/block is claimed, so a corrupted transfer is always
        retryable and never half-committed."""
        if not self.verify():
            raise SnapshotCorruption(
                f"request {self.request.id}: snapshot checksum mismatch "
                f"(corrupted in transfer from {self.src})")
        engine.import_request({
            "request": self.request, "outputs": self.outputs,
            "planned": len(self.outputs), "length": self.length,
            "token": self.token, "k": self.k, "v": self.v,
            "importance": self.importance, "tier": self.tier,
            "last_hot": self.last_hot,
            "first_token_time": self.first_token_time,
            "token_times": self.token_times,
        })


def can_migrate(src: ServingEngine, dst: ServingEngine, rid: int) -> bool:
    """Feasibility precheck: ``rid`` runs on ``src`` and ``dst`` can take
    its window right now (free slot + pool blocks) with a matching cache
    geometry AND an identical PAM policy — the participation mask (and
    hence the token stream) depends on the PAM config, so migrating
    between mismatched policies would silently break exactness. (Model
    config/params equality is the cluster builder's invariant: every
    device serves one model.)"""
    rs = src.requests.get(rid)
    if rs is None or rs.status != "running":
        return False
    if dst.scfg.max_len != src.scfg.max_len:
        return False
    if dst.pam_cfg != src.pam_cfg:
        return False
    # sampling policy must match, seed included: per-request keys
    # (fold_in(fold_in(seed, rid), position)) make sampled streams
    # bit-exact across the move as long as the policy tuple agrees
    if ((dst.scfg.temperature, dst.scfg.top_k, dst.scfg.sample_seed)
            != (src.scfg.temperature, src.scfg.top_k,
                src.scfg.sample_seed)):
        return False
    window = len(rs.request.prompt) + rs.request.max_new_tokens
    # reserve_queued=False: a rescue may compete with the target's own
    # queued admissions (see ServingEngine.can_accept)
    return dst.serviceable(window) and dst.can_accept(
        window, reserve_queued=False)


def migrate(src: ServingEngine, dst: ServingEngine, rid: int,
            link_bw: float = 0.0) -> dict[str, Any]:
    """Move running request ``rid`` from ``src`` to ``dst``.

    Returns a migration record (bytes moved, modeled transfer seconds at
    ``link_bw`` — 0 disables the charge). The caller (normally the
    balancer) is responsible for the feasibility precheck and for
    advancing the destination clock by ``transfer_s``.
    """
    snap = KVSnapshot.export(src, rid)
    try:
        snap.commit(dst)
    except Exception:
        # roll back: the source freed slot/blocks on export, so it can
        # always take its own request back
        snap.commit(src)
        raise
    transfer_s = snap.kv_bytes / link_bw if link_bw > 0 else 0.0
    return {"rid": rid, "src": src.name, "dst": dst.name,
            "tokens": snap.length, "bytes": snap.kv_bytes,
            "transfer_s": transfer_s}
