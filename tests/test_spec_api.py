"""EngineSpec / ClusterSpec declarative construction API (PR 10):
CLI round-trips, actionable validation errors, the unified ServeEvent
surface, and the deprecation shims that keep the legacy
``ServingEngine(cfg, params, scfg, ...)`` / ``build_cluster(...)``
signatures alive (warning) during the migration window."""

import dataclasses

import jax
import pytest

from conftest import build_model, make_pam

from repro.cluster import ClusterSpec, TokenEvent, build_cluster
from repro.cluster.spec import ReplicaGroup
from repro.perfmodel.devices import CXL_CLASS, HBM_CLASS
from repro.serving import (EngineSpec, PAMManagerConfig, Request,
                           ServeEvent, ServingConfig, ServingEngine)

jax.config.update("jax_platform_name", "cpu")

_CFG, _PARAMS = build_model("qwen3-0.6b")


def _scfg(**kw):
    base = dict(max_batch=2, max_len=64, pam=make_pam(), block_size=8,
                pool_blocks=23, hot_window=16)
    base.update(kw)
    return ServingConfig(**base)


# ------------------------------------------------------ CLI round-trip
def test_from_cli_round_trips_through_cli():
    spec = ClusterSpec.from_cli("hbm:1,cxl:2", model=_CFG,
                                serving=_scfg())
    assert spec.cli() == "hbm:1,cxl:2"
    assert spec.physical_devices == 3
    assert [g.devices for g in spec.groups] == [1, 1, 1]
    # shard=2: the lone hbm stays a group of 1, the cxl run pairs up —
    # and the canonical string still round-trips to the same topology
    spec2 = ClusterSpec.from_cli("hbm:1,cxl:2", model=_CFG,
                                 serving=_scfg(), shard=2)
    assert [g.devices for g in spec2.groups] == [1, 2]
    assert spec2.cli() == "hbm:1,cxl:2"
    assert ClusterSpec.from_cli(spec2.cli(), model=_CFG,
                                serving=_scfg(),
                                shard=2).groups == spec2.groups


def test_of_merges_only_consecutive_runs():
    spec = ClusterSpec.of(_CFG, [HBM_CLASS, CXL_CLASS, HBM_CLASS],
                          serving=_scfg(), shard=2)
    # no consecutive same-class run longer than 1: all groups stay 1
    assert [g.devices for g in spec.groups] == [1, 1, 1]
    assert spec.cli() == "hbm:1,cxl:1,hbm:1"


# ------------------------------------------------- actionable failures
def test_bad_device_string_raises():
    with pytest.raises(ValueError):
        ClusterSpec.from_cli("warp:2", model=_CFG, serving=_scfg())


def test_unsplittable_run_error_names_the_fix():
    with pytest.raises(ValueError, match=r"hbm:4|shard that divides"):
        ClusterSpec.of(_CFG, [HBM_CLASS] * 3, serving=_scfg(), shard=2)


def test_empty_cluster_spec_rejected():
    with pytest.raises(ValueError, match="at least one replica group"):
        ClusterSpec(model=_CFG, groups=(), serving=_scfg())


def test_replica_group_needs_a_device():
    with pytest.raises(ValueError, match=">= 1 device"):
        ReplicaGroup(HBM_CLASS, devices=0)


def test_engine_spec_shard_validation_messages():
    dense = ServingConfig(max_batch=2, max_len=64)
    with pytest.raises(ValueError, match="paged path"):
        EngineSpec(model=_CFG, serving=dense, shard=2).validate()
    with pytest.raises(ValueError, match="hot_window"):
        EngineSpec(model=_CFG, serving=_scfg(hot_window=18),
                   shard=4).validate()
    with pytest.raises(ValueError, match="pool_blocks=27"):
        EngineSpec(model=_CFG, serving=_scfg(pool_blocks=24),
                   shard=4).validate()
    with pytest.raises(ValueError, match=">= 1"):
        EngineSpec(model=_CFG, serving=_scfg(), shard=0).validate()
    # a well-formed sharded spec validates (build needs the devices,
    # validate must not)
    EngineSpec(model=_CFG, serving=_scfg(), shard=4).validate()


def test_specs_are_frozen_and_hashable():
    spec = EngineSpec(model=_CFG, serving=_scfg(), name="a")
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.shard = 2
    assert spec == EngineSpec(model=_CFG, serving=_scfg(), name="a")
    hash(spec)                        # usable as a cache key


# ------------------------------------------------ unified event surface
def test_token_event_is_the_one_event_type():
    from repro.frontend import server as frontend_server
    assert TokenEvent is ServeEvent
    assert frontend_server.TokenEvent is ServeEvent


def test_engine_serve_streams_unified_events():
    eng = EngineSpec(model=_CFG, serving=_scfg()).build(_PARAMS)
    eng.submit(Request(id=0, prompt=[1, 2, 3, 4], max_new_tokens=4))
    events = list(eng.serve())
    assert events and all(isinstance(ev, ServeEvent) for ev in events)
    assert events[-1].done
    twin = EngineSpec(model=_CFG, serving=_scfg()).build(_PARAMS)
    twin.submit(Request(id=0, prompt=[1, 2, 3, 4], max_new_tokens=4))
    twin.run()
    assert [ev.token for ev in events] == twin.requests[0].outputs


# ---------------------------------------------------- deprecation shims
def test_legacy_engine_ctor_warns_and_still_works():
    with pytest.warns(DeprecationWarning, match="EngineSpec"):
        eng = ServingEngine(_CFG, _PARAMS, _scfg(), name="old")
    assert eng.name == "old"
    assert eng.spec == EngineSpec(model=_CFG, serving=_scfg(),
                                  name="old")
    eng.submit(Request(id=0, prompt=[1, 2, 3, 4], max_new_tokens=3))
    eng.run()
    twin = EngineSpec(model=_CFG, serving=_scfg()).build(_PARAMS)
    twin.submit(Request(id=0, prompt=[1, 2, 3, 4], max_new_tokens=3))
    twin.run()
    assert eng.requests[0].outputs == twin.requests[0].outputs


def test_legacy_engine_ctor_requires_scfg():
    with pytest.raises(TypeError):
        with pytest.warns(DeprecationWarning):
            ServingEngine(_CFG, _PARAMS)


def test_legacy_build_cluster_warns_and_matches_spec_build():
    scfg = _scfg()
    with pytest.warns(DeprecationWarning, match="ClusterSpec"):
        router = build_cluster(_CFG, _PARAMS, [HBM_CLASS, CXL_CLASS],
                               scfg=scfg)
    assert [d.name for d in router.devices] == ["hbm0", "cxl0"]
    spec_router = ClusterSpec.of(_CFG, [HBM_CLASS, CXL_CLASS],
                                 serving=scfg).build(_PARAMS)
    assert ([d.name for d in router.devices]
            == [d.name for d in spec_router.devices])
    assert ([d.engine.scfg for d in router.devices]
            == [d.engine.scfg for d in spec_router.devices])
