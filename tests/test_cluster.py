"""Multi-device cluster (paper §4.3): inter-device migration exactness,
router dispatch/streaming, online balancer behaviour, and the fused
single-dispatch/donation invariants on cluster engines.

The headline acceptance test: a request migrated mid-decode between
device classes emits a token stream IDENTICAL to the same request
served unmigrated on one device.
"""

import jax
import numpy as np
import pytest

from conftest import build_model, make_pam

from repro.cluster import (BalancerConfig, ClusterSpec, KVBalancer,
                           KVSnapshot, can_migrate, migrate)
from repro.perfmodel.devices import (CXL_CLASS, HBM_CLASS, DeviceClass,
                                     get_device_class,
                                     make_device_latency_model,
                                     parse_devices, step_time_prior)
from repro.serving import EngineSpec, Request, ServingConfig
from repro.serving.paged_kv import OutOfBlocks

jax.config.update("jax_platform_name", "cpu")


_CFG, _PARAMS = build_model("qwen3-0.6b")


def _pam(max_len=64):
    return make_pam(max_len=max_len, hot=4, warm=8, recency_window=2)


def _engine(name="dev", max_batch=3, max_len=64, block_size=8, pool=None,
            latency=None):
    scfg = ServingConfig(max_batch=max_batch, max_len=max_len,
                         pam=_pam(max_len), block_size=block_size,
                         pool_blocks=pool)
    return EngineSpec(model=_CFG, serving=scfg,
                      name=name).build(_PARAMS, latency_model=latency)


def _submit(eng_or_router, n, plen=20, max_new=12, seed=0, arrivals=False):
    rng = np.random.default_rng(seed)
    t = 0.0
    for i in range(n):
        t += float(rng.exponential(0.001))
        eng_or_router.submit(Request(
            id=i, prompt=rng.integers(0, _CFG.vocab, plen),
            max_new_tokens=max_new, arrival=t if arrivals else 0.0))


# ------------------------------------------------------ migration exactness
def test_migration_exactness_across_device_classes():
    """A request migrated mid-decode HBM-class -> CXL-class emits the
    exact token stream of its unmigrated twin (acceptance criterion)."""
    twin = _engine("twin")
    _submit(twin, 3)
    twin.run()

    src = _engine("src", latency=make_device_latency_model(HBM_CLASS))
    dst = _engine("dst", max_batch=2,
                  latency=make_device_latency_model(CXL_CLASS))
    _submit(src, 3)
    for _ in range(5):                 # mid-decode: past prefill, mid-gen
        src.step()
    assert can_migrate(src, dst, 1)
    rec = migrate(src, dst, 1)
    assert rec["tokens"] > 0 and rec["bytes"] > 0
    assert 1 not in src.requests       # free-without-finish on the source
    while any(s is not None for s in src.slots) or src.waiting:
        src.step()
    while any(s is not None for s in dst.slots) or dst.waiting:
        dst.step()
    for rid in range(3):
        ref = twin.requests[rid].outputs
        got = (dst if rid == 1 else src).requests[rid].outputs
        assert got == ref, rid
    assert dst.migrations_in == 1 and src.migrations_out == 1


def test_migration_exactness_dense_engines():
    """Migration also serves dense (non-paged) engines: the snapshot is
    the dense cache row."""
    twin = _engine("twin", block_size=0)
    _submit(twin, 2)
    twin.run()
    src = _engine("src", block_size=0)
    dst = _engine("dst", block_size=0)
    _submit(src, 2)
    for _ in range(4):
        src.step()
    migrate(src, dst, 0)
    while any(s is not None for s in src.slots):
        src.step()
    while any(s is not None for s in dst.slots):
        dst.step()
    assert dst.requests[0].outputs == twin.requests[0].outputs
    assert src.requests[1].outputs == twin.requests[1].outputs


def test_export_gathers_warm_tokens_through_block_table():
    """The snapshot's non-hot positions come from the POOL through the
    block table and equal the dense mirror — the §6.2 export path is
    exercised, not just the dense slice."""
    eng = _engine("e")
    _submit(eng, 1, plen=30, max_new=8)
    for _ in range(4):
        eng.step()
    slot = eng.requests[0].slot
    tier = np.asarray(eng.pam_state.tier[slot])
    length = int(np.asarray(eng.cache.lengths[slot]))
    assert (tier[:length] != 0).any()      # warm/cold tokens exist
    dense_k = np.asarray(eng.cache.k[:, slot])
    snap = KVSnapshot.export(eng, 0)
    np.testing.assert_allclose(snap.k[:, :, :length], dense_k[:, :, :length],
                               rtol=0, atol=0)


def test_import_backpressure_and_rollback():
    """A full target refuses the import (OutOfBlocks / no slot) and
    ``migrate`` rolls the request back onto the source unharmed."""
    src = _engine("src")
    dst = _engine("dst", max_batch=1, pool=3)   # too few blocks for 4
    _submit(src, 2)
    for _ in range(3):
        src.step()
    assert not can_migrate(src, dst, 0)         # pre-check refuses
    with pytest.raises(OutOfBlocks):
        migrate(src, dst, 0)                    # forced: rolls back
    assert 0 in src.requests                    # request back on source
    assert src.requests[0].status == "running"
    src.run()
    assert len(src.requests[0].outputs) == 12


# -------------------------------------------------------------- router
def _router(classes, n=8, bal=None, seed=3, max_new=10):
    scfg = ServingConfig(max_batch=4, max_len=64, pam=_pam(), block_size=8)
    router = ClusterSpec.of(_CFG, classes,
                            serving=scfg).build(_PARAMS, balancer=bal)
    _submit(router, n, plen=16, max_new=max_new, seed=seed, arrivals=True)
    return router


def test_router_serves_stream_and_streams_tokens():
    router = _router([HBM_CLASS, CXL_CLASS], n=8)
    s = router.run()
    assert s["finished"] == 8
    assert s["total_tokens"] == 8 * 10
    ev = router.drain_events()
    assert len(ev) == 80
    # per-request event indices are gapless and in order; done marks end
    by_rid = {}
    for e in ev:
        assert e.index == by_rid.get(e.request_id, 0)
        by_rid[e.request_id] = e.index + 1
        # reconstructed streams match the finished requests
    for rid, rs in router.finished.items():
        toks = [e.token for e in ev if e.request_id == rid]
        assert toks == rs.outputs
    assert sum(e.done for e in ev) == 8
    assert router.drain_events() == []          # drained


def test_router_spills_to_slow_device_under_overload():
    """When the fast device cannot hold a burst, the router admits the
    overflow on the slow device instead of queueing forever."""
    # hbm alone: 4 slots; 10 concurrent requests force a spill
    router = _router([HBM_CLASS, CXL_CLASS, CXL_CLASS], n=12, max_new=16)
    s = router.run()
    assert s["finished"] == 12
    used = [n for n, d in s["devices"].items() if d["tokens_emitted"] > 0]
    assert len(used) >= 2
    assert s["throughput_tok_s"] > 0
    assert 0.0 <= router.slo_attainment(1.0) <= 1.0


def test_router_rejects_unserviceable_request():
    """A window no device can serve degrades to a rejection TokenEvent
    (done=True, no token) — the rest of the stream keeps serving."""
    router = _router([HBM_CLASS], n=1)
    router.submit(Request(id=99, prompt=np.arange(60, dtype=np.int32),
                          max_new_tokens=30, arrival=99.0))
    s = router.run()
    assert s["finished"] == 1 and s["rejected"] == 1
    ev = [e for e in router.drain_events() if e.request_id == 99]
    assert len(ev) == 1
    assert ev[0].rejected and ev[0].done and ev[0].token == -1
    assert 99 not in router.finished

    router.submit_to(Request(id=98, prompt=np.arange(60, dtype=np.int32),
                             max_new_tokens=30, arrival=100.0), "hbm0")
    assert router.rejected == 2
    assert [e.request_id for e in router.drain_events()
            if e.rejected] == [98]


# -------------------------------------------------------------- balancer
def test_balancer_migrates_off_overloaded_device():
    """Load a slow device while a fast one idles: the balancer moves the
    lowest-importance-mass request over and the stream still completes
    exactly (every request emits its full budget)."""
    bal = KVBalancer(BalancerConfig(rebalance_interval=2, hysteresis=1.1,
                                    cooldown_ticks=4, min_remaining=2))
    scfg = ServingConfig(max_batch=4, max_len=64, pam=_pam(), block_size=8)
    router = ClusterSpec.of(_CFG, [HBM_CLASS, CXL_CLASS],
                            serving=scfg).build(_PARAMS, balancer=bal)
    # pre-load the SLOW device directly; fast device idle
    rng = np.random.default_rng(7)
    for i in range(4):
        router.submit_to(
            Request(id=100 + i, prompt=rng.integers(0, _CFG.vocab, 16),
                    max_new_tokens=14, arrival=0.0), "cxl0")
    s = router.run()
    assert s["balancer_migrations"] >= 1
    hbm = router._by_name("hbm0")
    assert hbm.engine.migrations_in >= 1
    for rs in router.finished.values():
        assert len(rs.outputs) == rs.request.max_new_tokens
    # hysteresis bookkeeping: migrated requests are in cooldown
    assert bal._last_moved


def test_balancer_hysteresis_blocks_marginal_moves():
    """A nearly-balanced pair of identical devices must not migrate."""
    bal = KVBalancer(BalancerConfig(rebalance_interval=1, hysteresis=10.0))
    scfg = ServingConfig(max_batch=4, max_len=64, pam=_pam(), block_size=8)
    router = ClusterSpec.of(_CFG, [HBM_CLASS, HBM_CLASS],
                            serving=scfg).build(_PARAMS, balancer=bal)
    _submit(router, 8, plen=16, max_new=8, arrivals=True)
    s = router.run()
    assert s["finished"] == 8
    assert s["balancer_migrations"] == 0


# ------------------------------------------- fused-dispatch invariants
def test_cluster_engines_keep_single_dispatch_and_donation():
    """Every cluster engine still runs exactly ONE fused dispatch per
    decode step with donated cache/state (the PR-1/PR-2 invariants
    survive routing and migration)."""
    router = _router([HBM_CLASS, CXL_CLASS], n=6, max_new=8,
                     bal=KVBalancer(BalancerConfig(rebalance_interval=2,
                                                   hysteresis=1.1,
                                                   cooldown_ticks=2)))
    # run a few ticks, then capture buffers and confirm donation
    for _ in range(6):
        router.tick()
    dev = next(d for d in router.devices if d.engine.decode_dispatches > 0)
    k_buf = dev.engine.cache.k
    pk_buf = dev.engine.cache.pk
    tbl_buf = dev.engine.pam_state.block_table
    router.run()
    assert k_buf.is_deleted()
    assert pk_buf.is_deleted()
    assert tbl_buf.is_deleted()
    for d in router.devices:
        assert d.engine.decode_dispatches == d.engine.decode_device_steps
        if d.engine.allocator is not None:
            assert d.engine.allocator.check_no_double_mapping()


# ----------------------------------------------------- device classes
def test_device_class_registry_and_parse():
    assert get_device_class("hbm") is HBM_CLASS
    devs = parse_devices("hbm:1,cxl:2")
    assert [d.name for d in devs] == ["hbm", "cxl", "cxl"]
    assert parse_devices("ddr")[0].name == "ddr"
    with pytest.raises(ValueError):
        parse_devices("warp:1")
    with pytest.raises(ValueError):
        parse_devices("hbm:0")


def test_device_class_latency_ordering():
    """The CXL-class device is modeled strictly slower than the
    HBM-class device, and priors reflect it."""
    assert step_time_prior(CXL_CLASS) > step_time_prior(HBM_CLASS)
    stats = {"prefill_tokens": 0, "active": 2,
             "tier_reads": np.array([8, 4, 0], np.int64),
             "moved_tokens": 0,
             "batch_lengths": np.array([32, 32], np.int64)}
    t_hbm = make_device_latency_model(HBM_CLASS)(dict(stats))
    t_cxl = make_device_latency_model(CXL_CLASS)(dict(stats))
    assert t_cxl > t_hbm

    dc = DeviceClass("t", max_batch=2, pool_scale=0.5)
    assert dc.pool_blocks(64, 8) == 8       # 0.5 * 2 * (64/8)
