"""Fault-tolerant cluster serving: chaos injection, device-loss
recovery and graceful degradation.

The headline acceptance invariant (same style as the migration twins):
a request whose device is KILLED or STALLED mid-decode finishes on a
survivor with a token stream BIT-IDENTICAL to its failure-free twin —
via snapshot-drain for stragglers and replay for hard kills — and the
router's client-visible event stream stays gapless and duplicate-free
(zero lost tokens) across the failure.
"""

import jax
import numpy as np
import pytest

from conftest import build_model, make_pam, make_requests

from repro.cluster import (ClusterSpec, FaultEvent, FaultInjector,
                           KVSnapshot, RecoveryConfig, RecoveryManager,
                           SnapshotCorruption, parse_chaos)
from repro.perfmodel.devices import CXL_CLASS, HBM_CLASS
from repro.serving import EngineSpec, Request, ServingConfig

jax.config.update("jax_platform_name", "cpu")

_CFG, _PARAMS = build_model("qwen3-0.6b")


def _pam(max_len=64):
    return make_pam(max_len=max_len, hot=4, warm=8, recency_window=2)


def _scfg(**kw):
    return ServingConfig(max_batch=4, max_len=64, pam=_pam(),
                         block_size=8, **kw)


def _requests(n, plen=16, max_new=12, seed=0):
    return make_requests(n, _CFG.vocab, plen=plen, max_new=max_new,
                         seed=seed)


def _twin_streams(reqs, **scfg_kw):
    """Failure-free reference: the same requests on one plain engine
    (streams are batch/slot/phase-independent, so any engine run is THE
    canonical stream per request)."""
    eng = EngineSpec(model=_CFG, serving=_scfg(**scfg_kw)).build(_PARAMS)
    for r in reqs:
        eng.submit(Request(id=r.id, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens))
    eng.run()
    return {r.id: eng.requests[r.id].outputs for r in reqs}


def _assert_stream_integrity(router, rids):
    """Zero lost tokens: every request's event stream is gapless,
    duplicate-free, matches its finished outputs and ends with exactly
    one done marker."""
    ev = router.drain_events()
    for rid in rids:
        mine = [e for e in ev if e.request_id == rid and not e.rejected]
        assert [e.index for e in mine] == list(range(len(mine))), rid
        assert [e.token for e in mine] == router.finished[rid].outputs
        assert sum(e.done for e in mine) == 1 and mine[-1].done


# ---------------------------------------------------- replay (hard kill)
def test_kill_replay_twin_exact_greedy():
    """Hard kill mid-decode: in-flight KV is lost, the router replays
    the lost requests on the survivor, and every stream is bit-equal to
    the failure-free twin with no duplicate or missing events."""
    reqs = _requests(4)
    twin = _twin_streams(reqs)
    inj = FaultInjector([FaultEvent(tick=6, kind="kill", device="hbm1")])
    router = ClusterSpec.of(
        _CFG, [HBM_CLASS, HBM_CLASS], serving=_scfg(),
        recovery=RecoveryConfig(
            heartbeat_timeout_s=0.01)).build(_PARAMS, faults=inj)
    for i, r in enumerate(reqs):         # pin 2 per device
        router.submit_to(r, f"hbm{i % 2}")
    s = router.run()
    assert s["finished"] == 4 and s["rejected"] == 0
    ft = s["fault_tolerance"]
    assert ft["kills_detected"] == 1
    assert ft["replays"] >= 1
    assert ft["recovery_latency_mean_s"] > 0
    assert s["devices"]["hbm1"]["state"] == "dead"
    for r in reqs:
        assert router.finished[r.id].outputs == twin[r.id], r.id
    _assert_stream_integrity(router, [r.id for r in reqs])


def test_kill_replay_twin_exact_sampled():
    """Replay exactness holds at temperature > 0: per-request sampling
    keys (fold_in(seed, rid, position)) regenerate the identical
    sampled stream on the survivor."""
    kw = dict(temperature=1.0, sample_seed=11)
    reqs = _requests(4, seed=2)
    twin = _twin_streams(reqs, **kw)
    inj = FaultInjector([FaultEvent(tick=7, kind="kill", device="hbm1")])
    router = ClusterSpec.of(
        _CFG, [HBM_CLASS, HBM_CLASS], serving=_scfg(**kw),
        recovery=RecoveryConfig(
            heartbeat_timeout_s=0.01)).build(_PARAMS, faults=inj)
    for i, r in enumerate(reqs):
        router.submit_to(r, f"hbm{i % 2}")
    s = router.run()
    assert s["finished"] == 4
    assert s["fault_tolerance"]["replays"] >= 1
    for r in reqs:
        assert router.finished[r.id].outputs == twin[r.id], r.id
    _assert_stream_integrity(router, [r.id for r in reqs])


def test_watchdog_waits_out_a_silent_sole_worker():
    """The killed device held ALL in-flight work: nothing is steppable,
    so the watchdog must burn heartbeat-timeout sim-time explicitly to
    detect the silence, then replay on the idle survivor."""
    reqs = _requests(2, seed=3)
    twin = _twin_streams(reqs)
    inj = FaultInjector([FaultEvent(tick=4, kind="kill", device="hbm1")])
    timeout = 0.05
    router = ClusterSpec.of(
        _CFG, [HBM_CLASS, HBM_CLASS], serving=_scfg(),
        recovery=RecoveryConfig(
            heartbeat_timeout_s=timeout)).build(_PARAMS, faults=inj)
    for r in reqs:
        router.submit_to(r, "hbm1")      # hbm0 stays idle
    s = router.run()
    assert s["finished"] == 2
    ft = s["fault_tolerance"]
    assert ft["kills_detected"] == 1 and ft["replays"] == 2
    assert ft["recovery_latency_mean_s"] >= timeout
    for r in reqs:
        assert router.finished[r.id].outputs == twin[r.id]
    _assert_stream_integrity(router, [r.id for r in reqs])


def test_kill_with_no_survivor_degrades_to_rejection():
    """Losing the ONLY serviceable device must not hang or raise: the
    stranded requests end with rejection events and the run drains."""
    reqs = _requests(2, seed=4)
    inj = FaultInjector([FaultEvent(tick=3, kind="kill", device="hbm0")])
    router = ClusterSpec.of(
        _CFG, [HBM_CLASS], serving=_scfg(),
        recovery=RecoveryConfig(
            heartbeat_timeout_s=0.01)).build(_PARAMS, faults=inj)
    for r in reqs:
        router.submit(r)
    s = router.run()
    assert s["finished"] == 0 and s["rejected"] == 2
    ev = router.drain_events()
    assert sum(e.rejected for e in ev) == 2


# ------------------------------------------------- drain (straggler stall)
def test_stall_drain_twin_exact_sampled():
    """A stalled (50x) device is flagged by the prior-normalized
    straggler watchdog and DRAINED: its running requests move to the
    healthy device as checksummed snapshots and finish bit-exactly —
    sampled streams included."""
    kw = dict(temperature=1.0, sample_seed=9)
    reqs = _requests(4, seed=5)
    twin = _twin_streams(reqs, **kw)
    inj = FaultInjector([FaultEvent(tick=4, kind="stall", device="hbm1",
                                    factor=50.0)])
    router = ClusterSpec.of(
        _CFG, [HBM_CLASS, HBM_CLASS], serving=_scfg(**kw),
        recovery=RecoveryConfig()).build(_PARAMS, faults=inj)
    for i, r in enumerate(reqs):
        router.submit_to(r, f"hbm{i % 2}")
    s = router.run()
    assert s["finished"] == 4 and s["rejected"] == 0
    ft = s["fault_tolerance"]
    assert ft["drains"] >= 1 and ft["kills_detected"] == 0
    assert s["devices"]["hbm1"]["state"] == "drained"
    assert router._by_name("hbm0").engine.migrations_in >= 1
    for r in reqs:
        assert router.finished[r.id].outputs == twin[r.id], r.id
    _assert_stream_integrity(router, [r.id for r in reqs])


def test_heterogeneous_slow_device_is_not_a_straggler():
    """A legitimately 4x-slower CXL device must NEVER be flagged: step
    times are normalized by the device-class prior before they reach
    the monitor."""
    reqs = _requests(6, seed=6)
    router = ClusterSpec.of(
        _CFG, [HBM_CLASS, CXL_CLASS], serving=_scfg(),
        recovery=RecoveryConfig()).build(_PARAMS)
    for r in reqs:
        router.submit(r)
    s = router.run()
    assert s["finished"] == 6
    assert s["fault_tolerance"]["drains"] == 0
    assert all(d["state"] == "up" for d in s["devices"].values())


# --------------------------------------------------- transfer corruption
def _mid_decode_pair(n=2, steps=4):
    src = EngineSpec(model=_CFG, serving=_scfg(),
                     name="src").build(_PARAMS)
    dst = EngineSpec(model=_CFG, serving=_scfg(),
                     name="dst").build(_PARAMS)
    reqs = _requests(n, seed=7)
    for r in reqs:
        src.submit(Request(id=r.id, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens))
    for _ in range(steps):
        src.step()
    return src, dst, reqs


def test_snapshot_checksum_detects_corruption():
    src, dst, _ = _mid_decode_pair()
    snap = KVSnapshot.export(src, 0)
    assert snap.checksum is not None and snap.verify()
    wire = snap.clone()
    FaultInjector([]).corrupt(wire)
    assert not wire.verify()
    with pytest.raises(SnapshotCorruption):
        wire.commit(dst)
    assert snap.verify()                 # sender copy untouched
    assert 0 not in dst.requests         # nothing half-committed


def test_transfer_retries_through_drop_and_corruption():
    """One dropped + one corrupted transfer, then success: bounded
    retry re-sends from the pristine copy, backoff is charged to the
    receiver, and the delivered stream is exact."""
    twin = _twin_streams(_requests(2, seed=7))
    src, dst, reqs = _mid_decode_pair()
    inj = FaultInjector([FaultEvent(tick=0, kind="drop"),
                         FaultEvent(tick=0, kind="corrupt")])
    inj.due(0)                           # arm the verdict queue
    rec = RecoveryManager(RecoveryConfig(transfer_retries=3),
                          injector=inj)
    snap = KVSnapshot.export(src, 1)
    charged = []
    assert rec.transfer(snap, dst, charged.append)
    assert rec.stats["transfers_dropped"] == 1
    assert rec.stats["corruptions_detected"] == 1
    assert rec.stats["transfer_retries"] == 2
    assert sum(charged) > 0
    src.run()
    dst.run()
    assert src.requests[0].outputs == twin[0]
    assert dst.requests[1].outputs == twin[1]


def test_transfer_terminal_failure_rolls_back_to_source():
    """Every retry corrupted: the transfer fails terminally, but the
    sender's copy is pristine — rollback re-commits it at home and the
    stream still finishes exactly."""
    twin = _twin_streams(_requests(2, seed=7))
    src, dst, _ = _mid_decode_pair()
    inj = FaultInjector([FaultEvent(tick=0, kind="corrupt", count=8)])
    inj.due(0)
    rec = RecoveryManager(RecoveryConfig(transfer_retries=1),
                          injector=inj)
    snap = KVSnapshot.export(src, 1)
    assert not rec.transfer(snap, dst, lambda s: None)
    assert rec.stats["transfer_failures"] == 1
    assert snap.verify()
    snap.commit(src)                     # rollback
    src.run()
    assert src.requests[1].outputs == twin[1]
    assert dst.migrations_in == 0


# ------------------------------------------- preemption / pool exhaustion
def test_pool_exhaustion_preempts_lowest_importance_and_resumes():
    """An exhausted pool starves the queue head; the router demotes the
    lowest-importance running request to a host-held snapshot (freeing
    its blocks), admits the head, and resumes the victim when capacity
    frees — all three streams bit-equal their failure-free twins."""
    reqs = _requests(3, plen=20, max_new=12, seed=8)
    twin = _twin_streams(reqs)
    inj = FaultInjector([FaultEvent(tick=2, kind="exhaust",
                                    device="hbm0")])
    router = ClusterSpec.of(
        _CFG, [HBM_CLASS], serving=_scfg(),
        recovery=RecoveryConfig(
            preempt_after_ticks=5,
            resume_cooldown_ticks=2)).build(_PARAMS, faults=inj)
    router.submit_to(reqs[0], "hbm0")
    router.submit_to(reqs[1], "hbm0")
    for _ in range(4):                   # both mid-decode before the fault
        router.tick()
    router.submit(reqs[2])
    s = router.run()
    assert s["finished"] == 3 and s["rejected"] == 0
    ft = s["fault_tolerance"]
    assert ft["preemptions"] >= 1 and ft["resumes"] >= 1
    assert ft["suspended_now"] == 0
    for r in reqs:
        assert router.finished[r.id].outputs == twin[r.id], r.id
    _assert_stream_integrity(router, [r.id for r in reqs])


# ------------------------------------------------------ balancer gating
def test_balancer_never_targets_a_killed_device():
    """The balancer must not migrate onto (or off) a non-up device: a
    killed idle fast device would otherwise look like the perfect
    target and strand every moved request."""
    from repro.cluster import BalancerConfig, KVBalancer
    reqs = _requests(4, seed=9)
    inj = FaultInjector([FaultEvent(tick=1, kind="kill", device="hbm0")])
    bal = KVBalancer(BalancerConfig(rebalance_interval=2, hysteresis=1.1,
                                    cooldown_ticks=2, min_remaining=2))
    router = ClusterSpec.of(
        _CFG, [HBM_CLASS, CXL_CLASS], serving=_scfg(),
        recovery=RecoveryConfig(heartbeat_timeout_s=0.01)).build(
            _PARAMS, balancer=bal, faults=inj)
    for r in reqs:
        router.submit_to(r, "cxl0")      # load the slow device only
    s = router.run()
    assert s["finished"] == 4
    assert s["balancer_migrations"] == 0          # nowhere healthy to move
    assert router._by_name("hbm0").engine.migrations_in == 0
    for r in reqs:
        assert len(router.finished[r.id].outputs) == r.max_new_tokens


# ------------------------------------------------------------- chaos spec
def test_chaos_spec_parser():
    evs = parse_chaos("kill:hbm0@120, stall:cxl0@50x8, corrupt@30*2, "
                      "exhaust:cxl1@25")
    assert [e.kind for e in evs] == ["kill", "stall", "corrupt",
                                    "exhaust"]
    assert evs[0] == FaultEvent(tick=120, kind="kill", device="hbm0")
    assert evs[1].factor == 8.0 and evs[1].tick == 50
    assert evs[2].count == 2 and evs[2].device == ""
    with pytest.raises(ValueError):
        parse_chaos("kill:hbm0")         # missing @tick
    with pytest.raises(ValueError):
        parse_chaos("melt:hbm0@3")       # unknown kind
    with pytest.raises(ValueError):
        parse_chaos("kill@3")            # kill needs a device


def test_injector_is_deterministic():
    spec = "corrupt@0*2"
    a, b = (FaultInjector.from_spec(spec, seed=1) for _ in range(2))
    a.due(0), b.due(0)
    arr_a = np.arange(64, dtype=np.uint8).reshape(1, 1, 8, 8)
    arr_b = arr_a.copy()

    class _Snap:                         # minimal corruptible stand-in
        def __init__(self, k):
            self.k = k
    a.corrupt(_Snap(arr_a))
    b.corrupt(_Snap(arr_b))
    np.testing.assert_array_equal(arr_a, arr_b)
    assert [a.transfer_verdict() for _ in range(3)] == [
        "corrupt", "corrupt", "ok"]      # armed twice, then drained
    assert a.exhausted
