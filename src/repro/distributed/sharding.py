"""Sharding rules: ModelConfig + mesh -> PartitionSpecs for params, batch,
optimizer state, and decode caches.

Scheme (Megatron-style TP over the ``model`` axis + PAM sequence sharding):
  column-parallel (last dim on "model"):  wq wk wv gate up in_proj w_uk w_uv
                                          w_kr shared_gate shared_up frontend
  row-parallel (2nd-to-last on "model"):  wo down out_proj shared_down w_dkv
                                          lm_head
  expert-parallel (E dim on "model"):     moe w_gate / w_up / w_down
  replicated:                             norms, router, dt_bias, a_log, ...
  embed:                                  d on "model" (vocab sizes are not
                                          always divisible — e.g. minicpm)
  KV caches (serve):                      sequence dim on "model" — the
                                          distributed PAMattention layout;
                                          batch on (pod, data) when divisible
  optimizer moments:                      param spec + first free axis on
                                          "data" (ZeRO-1 style)

Every rule degrades to replication when the dim is not divisible by the
mesh axis — correctness never depends on divisibility.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes
from repro.models import transformer as tf
from repro.models.config import ModelConfig

Pytree = Any

_COLUMN = ("wq", "wk", "wv", "gate", "up", "in_proj", "w_uk", "w_uv",
           "w_kr", "shared_gate", "shared_up", "frontend")
_ROW = ("wo", "down", "out_proj", "shared_down", "w_dkv")
_EXPERT = ("w_gate", "w_up", "w_down")
_MODEL_VEC = ("out_norm",)       # 1D activations sharded on model (d_inner)


def _divides(dim: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.shape and dim % mesh.shape[axis] == 0 and dim > 0


def _leaf_spec(name: str, parent: str, shape: tuple[int, ...],
               mesh: Mesh) -> P:
    nd = len(shape)
    none = [None] * nd

    def with_axis(pos: int) -> P:
        if 0 <= pos < nd and _divides(shape[pos], mesh, "model"):
            s = list(none)
            s[pos] = "model"
            return P(*s)
        return P(*none)

    if name == "embed":
        # vocab-shard when divisible (Megatron vocab-parallel head: logits
        # stay vocab-sharded through the loss — the big-vocab memory fix);
        # fall back to d-sharding (e.g. minicpm's 122753 vocab).
        if _divides(shape[nd - 2], mesh, "model"):
            return with_axis(nd - 2)
        return with_axis(nd - 1)
    if name == "lm_head":
        if _divides(shape[nd - 1], mesh, "model"):
            return with_axis(nd - 1)       # column (vocab) parallel
        return with_axis(nd - 2)           # row parallel fallback
    if parent == "moe" and name in _EXPERT:
        # 2D expert-parallel sharding: experts over "data" (EP — tokens
        # all-to-all across the DP axis) AND the ffn dim over "model" (TP).
        # Needed so 235B-scale MoE weights fit per-device HBM.
        s = list(none)
        if _divides(shape[nd - 3], mesh, "data"):
            s[nd - 3] = "data"
        ffn_axis = nd - 1 if name in ("w_gate", "w_up") else nd - 2
        if _divides(shape[ffn_axis], mesh, "model"):
            s[ffn_axis] = "model"
        return P(*s)
    if name in _COLUMN:
        return with_axis(nd - 1)
    if name in _ROW:
        return with_axis(nd - 2)
    if name == "conv_w" or name == "conv_b":
        return with_axis(nd - 1)              # conv_dim (model-sharded)
    if name in _MODEL_VEC:
        return with_axis(nd - 1)
    return P(*none)


def param_specs(cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = False
                ) -> Pytree:
    """PartitionSpec pytree matching ``init_params(cfg, key)``.

    ``fsdp``: additionally shard each >=2D weight over "data" on its first
    free axis (ZeRO-3 style) — required for the biggest dense archs to fit
    per-device HBM in training; XLA all-gathers weights per layer."""
    shapes = jax.eval_shape(
        lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = []
    for path, leaf in flat:
        keys = [k.key for k in path if hasattr(k, "key")]
        name = keys[-1] if keys else ""
        parent = keys[-2] if len(keys) > 1 else ""
        spec = _leaf_spec(name, parent, leaf.shape, mesh)
        if fsdp and len(leaf.shape) >= 2:
            spec = _zero1_spec(spec, leaf.shape, mesh)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = False
                    ) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, mesh, fsdp=fsdp), is_leaf=lambda x:
                        isinstance(x, P))


def _zero1_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Add 'data' on the first axis the param spec leaves free (ZeRO-1).
    Skipped when the param spec already consumes 'data' (2D-sharded MoE)."""
    s = list(spec) + [None] * (len(shape) - len(spec))
    if "data" in s:
        return P(*s)
    for i, (dim, cur) in enumerate(zip(shape, s)):
        if cur is None and _divides(dim, mesh, "data"):
            s[i] = "data"
            break
    return P(*s)


def opt_state_specs(cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = False
                    ) -> Pytree:
    """Specs for AdamW (mu, nu) — param spec + ZeRO-1 data sharding."""
    pspecs = param_specs(cfg, mesh, fsdp=fsdp)
    shapes = jax.eval_shape(
        lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    return jax.tree.map(
        lambda sp, sh: _zero1_spec(sp, sh.shape, mesh), pspecs, shapes,
        is_leaf=lambda x: isinstance(x, P))


def batch_dp_spec(global_batch: int, mesh: Mesh) -> tuple:
    """Leading batch axis over (pod, data) when divisible, else fewer
    axes, else replicated (long_500k has batch 1)."""
    axes = dp_axes(mesh)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if global_batch % size == 0:
        return axes
    if "data" in axes and global_batch % mesh.shape["data"] == 0:
        return ("data",)
    return None


def batch_specs(cfg: ModelConfig, global_batch: int, mesh: Mesh) -> dict:
    dp = batch_dp_spec(global_batch, mesh)
    specs = {}
    if cfg.family == "audio":
        specs["frames"] = P(dp, None, None)
        specs["labels"] = P(dp, None)
    else:
        specs["tokens"] = P(dp, None)
        specs["labels"] = P(dp, None)
        if cfg.family == "vlm":
            specs["patches"] = P(dp, None, None)
    return specs


def decode_cache_specs(cfg: ModelConfig, global_batch: int, mesh: Mesh
                       ) -> tf.DecodeCache:
    """Serve-phase cache sharding: batch over DP axes, KV sequence over
    "model" (the PAMattention distributed layout: each model-axis device
    is one PIM site holding a KV shard), SSM heads over "model"."""
    dp = batch_dp_spec(global_batch, mesh)

    def seq_kv(ndim, seq_axis):
        s = [None] * ndim
        s[1] = dp
        s[seq_axis] = "model"
        return P(*s)

    def ssm_spec(ndim, h_axis, h_dim):
        s = [None] * ndim
        s[1] = dp
        if _divides(h_dim, mesh, "model"):
            s[h_axis] = "model"
        return P(*s)

    zero = P()
    k = v = ckv = krope = conv = state = zero
    if cfg.family in ("dense", "vlm") or (cfg.family == "moe"
                                          and cfg.mla is None):
        k = v = seq_kv(5, 3)                  # (L, B, Hkv, S, dh)
    elif cfg.family == "moe":
        ckv = seq_kv(4, 2)                    # (L, B, S, r)
        krope = seq_kv(4, 2)
    if cfg.family in ("ssm", "hybrid"):
        di, H, conv_dim = (cfg.ssm.d_inner(cfg.d_model),
                           cfg.ssm.n_heads(cfg.d_model),
                           cfg.ssm.d_inner(cfg.d_model)
                           + 2 * cfg.ssm.n_groups * cfg.ssm.d_state)
        conv = P(None, dp, None, "model") if _divides(
            conv_dim, mesh, "model") else P(None, dp, None, None)
        state = ssm_spec(5, 2, H)             # (L, B, H, N, P)
    if cfg.family == "hybrid":
        k = v = seq_kv(5, 3)
    # paged pools are a single-host serving-engine feature: size-0 in
    # distributed caches, replicated spec
    return tf.DecodeCache(k=k, v=v, ckv=ckv, krope=krope, conv=conv,
                          state=state, pk=zero, pv=zero, lengths=P(dp))


def serving_cache_shardings(mesh: Mesh, cache: tf.DecodeCache, *,
                            axis: str = "model") -> tf.DecodeCache:
    """Shardings of the SERVING engine's decode cache (PR 10).

    Unlike ``decode_cache_specs`` (the training/dry-run layout, which
    sequence-shards the dense cache and keeps pools host-local), the
    serving fast path shards the two axes whose sizes are independent
    of batch and divisible by construction (``EngineSpec.validate``):

      * the hot RING's slot axis — ``k``/``v`` (L, B, Hkv, W, dh) split
        on W, so each device is one PIM site holding a contiguous range
        of ring slots (absolute position p lives on the device owning
        slot ``p % W``);
      * the paged pool's physical-BLOCK axis — ``pk``/``pv``
        (L, NB+1, bs, Hkv, dh) split on NB+1, so each device owns a
        contiguous range of physical blocks while the per-request block
        tables stay replicated host-side ids (tables survive
        distribution unchanged).

    Everything else (lengths, and the unused family fields) is
    replicated. Returns a ``DecodeCache`` of ``NamedSharding``s — pass
    to ``jax.device_put`` and as ``out_shardings`` of the fused step.
    """
    n = mesh.shape[axis]
    rep = NamedSharding(mesh, P())

    def shd(name: str, x) -> NamedSharding:
        if x.size == 0:
            return rep
        if name in ("k", "v") and x.ndim == 5 and x.shape[3] % n == 0:
            return NamedSharding(mesh, P(None, None, None, axis, None))
        if name in ("pk", "pv") and x.ndim == 5 and x.shape[1] % n == 0:
            return NamedSharding(mesh, P(None, axis, None, None, None))
        return rep

    return tf.DecodeCache(*[shd(f, x)
                            for f, x in zip(cache._fields, cache)])


def make_sharded_zeros(spec_tree: Pytree, shape_tree: Pytree,
                       mesh: Mesh) -> Pytree:
    """Materialize zero arrays with the given specs (used by launchers)."""
    def one(spec, sds):
        sh = NamedSharding(mesh, spec)
        return jax.make_array_from_callback(
            sds.shape, sh, lambda idx: jnp.zeros(
                [s.stop - s.start if s.start is not None else d
                 for s, d in zip(idx, sds.shape)], sds.dtype))
    return jax.tree.map(one, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))
