"""Fused prefill/train attention kernel (FlashAttention-2 style, TPU Pallas).

Serves the NPU-side prefill path of PAM (§4.3: "During prefill, NPUs run all
operators"). Tiled for the TPU memory hierarchy: q/k/v blocks staged
HBM->VMEM via BlockSpec, MXU-shaped (multiples of 128) matmuls, fp32
accumulation in VMEM scratch carried across the sequential kv-block grid
axis — the same online-softmax algebra as PAMattention's local stage.

Grid: (batch*heads, q_blocks, kv_blocks) with kv innermost & sequential
("arbitrary"), so the (m, l, acc) scratch implements the running rescale.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat  # noqa: F401  (backfills pltpu.CompilerParams on 0.4)

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = float(-1e30)  # large-negative instead of -inf: keeps exp() exact-0
                        # without NaN from (-inf) - (-inf)


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, block_q: int, block_k: int,
                 kv_len: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)        # (block_q, d)
    k = k_ref[0, 0].astype(jnp.float32)        # (block_k, d)
    v = v_ref[0, 0].astype(jnp.float32)        # (block_k, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # mask: causal + kv-length padding
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = kpos < kv_len
    if causal:
        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)

    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        l_safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / l_safe[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """Fused attention. q: (B, H, S, d); k, v: (B, H_kv, S, d) (GQA ok).

    Returns (B, H, S, d) in q.dtype. Sequence is padded internally to block
    multiples; padding keys are masked, padding queries produce zeros that
    are sliced off.
    """
    B, H, Sq, d = q.shape
    _, H_kv, Sk, _ = k.shape
    assert H % H_kv == 0, (H, H_kv)
    rep = H // H_kv
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    sq_pad = (block_q - Sq % block_q) % block_q
    sk_pad = (block_k - Sk % block_k) % block_k
    if sq_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad), (0, 0)))
    if sk_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_pad), (0, 0)))
    Sq_p, Sk_p = Sq + sq_pad, Sk + sk_pad
    nq, nk = Sq_p // block_q, Sk_p // block_k

    q4 = q.reshape(B * H, 1, Sq_p, d)
    k4 = k.reshape(B * H_kv, 1, Sk_p, d)
    v4 = v.reshape(B * H_kv, 1, Sk_p, d)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, kv_len=Sk)

    def _kv_row(bh, iq, ik):
        # bh = b*H + h  ->  kv row = b*H_kv + h//rep
        return ((bh // H) * H_kv + (bh % H) // rep, 0, ik, 0)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bh, iq, ik: (bh, 0, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), _kv_row),
            pl.BlockSpec((1, 1, block_k, d), _kv_row),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bh, iq, ik: (bh, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, 1, Sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q4, k4, v4)

    out = out.reshape(B, H, Sq_p, d)
    if sq_pad:
        out = out[:, :, :Sq, :]
    return out
