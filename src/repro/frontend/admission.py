"""SLO-aware admission control (PR 8).

The router's default policy serves every queued request eventually;
under sustained overload that drives everyone's TTFT unbounded. This
controller enforces per-request deadlines instead, with two levers the
router exposes:

- **load shedding** (``ClusterRouter.shed``): a queued request whose
  TTFT deadline is PROVABLY unmeetable — time already waited plus a
  lower bound on its cheapest possible prefill anywhere in the fleet
  already exceeds the budget — is rejected now (a ``rejected``
  ``TokenEvent``), spending zero capacity on a lost cause and keeping
  the survivors' deadlines reachable. The lower bound uses the fleet's
  best modeled per-token prefill time; in wall-clock mode there is no
  model, the bound is vacuous, and shedding disarms rather than guess.
- **starvation preemption** (``ClusterRouter.force_preempt``): when the
  queue head has burned more than ``starvation_frac`` of its TTFT
  budget waiting, the controller preempts the fleet's lowest-importance
  running request immediately (PR 6's preemption-by-demotion, bypassing
  the tick-based fuse), trading the cheapest accuracy stake for the
  head's deadline. A tick cooldown stops preemption thrash.

``control(router)`` runs once per server pump iteration, before the
router tick (it sees the queue as of the previous tick's dispatch).
"""

from __future__ import annotations

import dataclasses

from repro.obs import metrics as obs_metrics


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Per-request latency contract + controller tuning."""

    ttft_s: float = 0.5               # time-to-first-token budget
    tpot_s: float = 0.1               # per-output-token budget (scoring)
    starvation_frac: float = 0.5      # head preempts past this TTFT frac
    preempt_cooldown_ticks: int = 50  # min ticks between forced preempts

    def __post_init__(self):
        if self.ttft_s <= 0 or self.tpot_s <= 0:
            raise ValueError("SLO budgets must be positive")
        if not 0 < self.starvation_frac < 1:
            raise ValueError("starvation_frac must be in (0, 1)")


class SLOAdmission:
    """Deadline-driven shed/preempt controller over a ``ClusterRouter``."""

    def __init__(self, slo: SLOSpec = SLOSpec()):
        self.slo = slo
        self.shed = 0
        self.forced_preemptions = 0
        self._last_force = None
        reg = obs_metrics.get_registry()
        self._m_shed = reg.counter(
            "pam_frontend_shed_total",
            "queued requests shed by SLO admission (deadline "
            "provably unmeetable)")
        self._m_force = reg.counter(
            "pam_frontend_force_preempt_total",
            "forced preemptions triggered by queue-head starvation")

    # ------------------------------------------------------------ signals
    def _prefill_floor(self, router) -> float:
        """Cheapest modeled seconds-per-prefill-token on any healthy
        device — a lower bound on remaining TTFT for a queued request.
        0.0 (wall-clock mode / no priors) disarms shedding: with no
        provable bound nothing is provably unmeetable."""
        priors = [d.prefill_tok_prior for d in router._up()
                  if d.prefill_tok_prior > 0]
        return min(priors) if priors else 0.0

    def ttft_lower_bound(self, router, rid: int, now: float) -> float:
        """Provable minimum TTFT if the request were admitted on the
        fleet's fastest device RIGHT NOW (waited so far + cheapest
        possible prefill). Infeasible > budget ==> shed is sound."""
        req = router._requests[rid]
        plen, _ = router._shape[rid]
        return (now - req.arrival) + plen * self._prefill_floor(router)

    # ------------------------------------------------------------ control
    def control(self, router) -> None:
        if not router.queue:
            return
        now = router.now()
        if self._prefill_floor(router) > 0:
            for req in list(router.queue):
                if (self.ttft_lower_bound(router, req.id, now)
                        > self.slo.ttft_s):
                    if router.shed(req.id):
                        self.shed += 1
                        self._m_shed.inc()
        if not router.queue:
            return
        head = router.queue[0]
        waited = now - head.arrival
        if waited <= self.slo.starvation_frac * self.slo.ttft_s:
            return
        if (self._last_force is not None
                and router.ticks - self._last_force
                < self.slo.preempt_cooldown_ticks):
            return
        if router.force_preempt(head.id):
            self.forced_preemptions += 1
            self._m_force.inc()
            self._last_force = router.ticks

    def summary(self) -> dict:
        return {"shed": self.shed,
                "forced_preemptions": self.forced_preemptions,
                "ttft_slo_s": self.slo.ttft_s,
                "tpot_slo_s": self.slo.tpot_s}
