"""Mamba-2 SSD (state-space duality) chunked scan kernel (TPU Pallas).

Needed by the assigned ``mamba2-780m`` / ``zamba2-7b`` architectures: the
selective-state recurrence

    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * outer(B_t, x_t)     [N, P]
    y_t = C_t @ h_t + D_h * x_t                                  [P]

is computed chunk-parallel (SSD form): within a chunk of Q tokens the
contribution is an attention-like masked matmul (MXU-friendly), and a
single (N, P) state carries across chunks through the sequential grid axis
— the TPU-native replacement for a length-L serial scan.

Layouts are head-major inside the kernel ((B, H, L, P) etc.) so every
BlockSpec tiles its trailing (sequence, feature) dims in (8k, 128k)-aligned
VMEM tiles; the public API keeps the conventional (B, L, H, P).

Grid: (B, H, n_chunks) with chunks sequential; VMEM scratch carries the
running state. All accumulation fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat  # noqa: F401  (backfills pltpu.CompilerParams on 0.4)

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, dskip_ref, y_ref,
                state_scr, *, chunk: int, seq_len: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)             # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)        # (Q,)
    a = a_ref[0].astype(jnp.float32)                # scalar A_h (negative)
    bmat = b_ref[0, 0].astype(jnp.float32)          # (Q, N)
    cmat = c_ref[0, 0].astype(jnp.float32)          # (Q, N)
    dskip = dskip_ref[0].astype(jnp.float32)        # scalar D_h

    pos = ic * chunk + jax.lax.iota(jnp.int32, chunk)
    live = pos < seq_len
    dt = jnp.where(live, dt, 0.0)                   # dead tokens: identity

    logdecay = dt * a                                # (Q,) = log a_t
    seg = jnp.cumsum(logdecay)                       # s_t = sum_{u<=t} log a_u

    # --- inter-chunk: y_t += exp(s_t) * C_t @ h_in --------------------------
    h_in = state_scr[...]                            # (N, P)
    y_inter = jnp.exp(seg)[:, None] * jax.lax.dot_general(
        cmat, h_in, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (Q, P)

    # --- intra-chunk: masked attention-like form ---------------------------
    # M[t, u] = exp(s_t - s_u) * dt_u  for u <= t else 0
    gap = seg[:, None] - seg[None, :]                # (Q, Q)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    # mask before exp: upper-triangle gaps are positive and would overflow
    decay = jnp.exp(jnp.where(tri, gap, -1e30)) * dt[None, :]
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_intra = jax.lax.dot_general(scores * decay, x,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y = y_inter + y_intra + dskip * x
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # --- state update: h_out = exp(s_Q) h_in + sum_u exp(s_Q - s_u) dt_u B_u x_u^T
    tail = jnp.exp(seg[-1] - seg) * dt               # (Q,)
    dstate = jax.lax.dot_general(bmat * tail[:, None], x,
                                 (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (N, P)
    state_scr[...] = jnp.exp(seg[-1]) * h_in + dstate


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, d_skip: jax.Array, *,
             chunk: int = DEFAULT_CHUNK,
             interpret: bool = False) -> jax.Array:
    """Chunked SSD scan.

    x: (B, L, H, P) inputs; dt: (B, L, H) post-softplus step sizes;
    a: (H,) negative decay rates; b, c: (B, L, G, N) input/output
    projections (G groups, H % G == 0); d_skip: (H,) skip gains.
    Returns y: (B, L, H, P) in x.dtype.
    """
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G

    chunk = min(chunk, max(L, 8))
    pad = (chunk - L % chunk) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    L_p = L + pad
    nchunk = L_p // chunk

    # head-major kernel layouts
    xk = jnp.transpose(x, (0, 2, 1, 3))              # (B, H, L, P)
    dtk = jnp.transpose(dt, (0, 2, 1))[:, :, None, :]  # (B, H, 1, L)
    bk = jnp.transpose(b, (0, 2, 1, 3))              # (B, G, L, N)
    ck = jnp.transpose(c, (0, 2, 1, 3))

    kernel = functools.partial(_ssd_kernel, chunk=chunk, seq_len=L)

    y = pl.pallas_call(
        kernel,
        grid=(B, H, nchunk),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, h, icc: (bi, h, icc, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda bi, h, icc: (bi, h, 0, icc)),
            pl.BlockSpec((1,), lambda bi, h, icc: (h,)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda bi, h, icc: (bi, h // rep, icc, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda bi, h, icc: (bi, h // rep, icc, 0)),
            pl.BlockSpec((1,), lambda bi, h, icc: (h,)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P),
                               lambda bi, h, icc: (bi, h, icc, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, L_p, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(xk, dtk, a, bk, ck, d_skip)

    y = jnp.transpose(y, (0, 2, 1, 3))               # back to (B, L, H, P)
    if pad:
        y = y[:, :L]
    return y
