"""The paper's own evaluation family (LLaMA-2-7B-like, §7.1) — used by the
perfmodel benchmarks to reproduce Figs. 9-13 at familiar scale."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pam-llama-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=32000, d_head=128,
    rope_theta=1e4,
))
