"""Chunked prefill planning (PR 8, vLLM-style).

A long prompt is admitted in bounded slices interleaved with decode
steps instead of one monolithic prefill: the engine claims the slot and
pool blocks up front, then each engine step advances the admission by
ONE slice — a fused dispatch that gathers the already-filled prefix
from the pool, runs the suffix prefill over just the slice, and
scatters the slice's KV into the request's pool blocks. The final
slice rides the ordinary suffix-commit path (hot-row rebuild, first
token sample, PAM placement), so from that point on the request is
indistinguishable from a single-shot admission — which is why chunked
streams are bit-identical to their single-shot twins (the same
causality argument as prefix-cache suffix prefill, applied
inductively slice by slice).

Everything here is pure host-side planning; the device work lives in
``repro.serving.engine`` (``_chunk_fill_fn`` / the suffix commit).
"""

from __future__ import annotations

import dataclasses


def validate_budget(budget: int) -> None:
    """A chunk budget must be a positive power of two: intermediate
    slices are always exactly ``budget`` tokens (one jit trace), and
    the final slice buckets to a power of two like any prefill."""
    if budget <= 0 or budget & (budget - 1):
        raise ValueError(f"need a positive power-of-two chunk, got {budget}")


def plan_slices(start: int, total: int, budget: int) -> list[tuple[int, int]]:
    """Slice schedule for a prompt of ``total`` tokens whose first
    ``start`` are already cache-resident (prefix-cache hit): a list of
    ``(begin, length)`` pairs covering ``[start, total)``. Every slice
    is exactly ``budget`` tokens except the last, which is the
    remainder in ``(0, budget]`` — the final slice always exists (it
    produces the first-token logits)."""
    validate_budget(budget)
    if not 0 <= start < total:
        raise ValueError(f"need 0 <= start < total, got {start}, {total}")
    out = []
    begin = start
    while begin < total:
        t = min(budget, total - begin)
        out.append((begin, t))
        begin += t
    return out


@dataclasses.dataclass
class ChunkPlan:
    """Host state of one in-flight chunked admission. The slot and the
    full block window are claimed at admission; ``done`` novel tokens
    have been filled so far; ``cow_src`` is the still-pinned shared
    tail block to copy-on-write in the FIRST slice (-1 = none)."""

    rid: int
    slot: int
    start: int  # cache-resident prefix tokens at admission
    total: int  # full prompt length
    budget: int
    done: int = 0  # novel tokens filled so far
    cow_src: int = -1
    slices: int = 0

    def next_slice(self) -> tuple[int, int]:
        begin = self.start + self.done
        return begin, min(self.budget, self.total - begin)

    @property
    def finished(self) -> bool:
        return self.start + self.done >= self.total
