"""Retrieval-based KV sparsity (paper §2.3.1 / §7.1).

The paper runs all systems with a state-of-the-art retrieval sparsity
algorithm (Double Sparsity [Yang et al. 2024]) at 8x compression: the full
KV set stays cached, but each decode step only *loads* the top-(S/8) most
relevant tokens. PAM's contribution is orthogonal ("PAM's KV management is
algorithm-agnostic") — this module provides the selection machinery that
produces the per-step performance scores S_i(j) feeding eq. (7).

Double-Sparsity-style approximation: relevance is estimated from a small
subset of "label" channels (the highest-magnitude key channels, chosen
offline), so the scoring pass reads r << d channels per token.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    compression: int = 8          # paper: 8x
    label_channels: int = 16      # r channels used for approximate scoring
    recency_window: int = 32      # always keep the most recent tokens (local attn sink)


def choose_label_channels(k_sample: jax.Array, r: int) -> jax.Array:
    """Offline channel selection: top-r channels by mean |K| magnitude.

    k_sample: (S, d) calibration keys. Returns (r,) int32 channel ids.
    """
    mag = jnp.mean(jnp.abs(k_sample.astype(jnp.float32)), axis=0)
    _, idx = jax.lax.top_k(mag, r)
    return idx


def approx_scores(q: jax.Array, k_label: jax.Array,
                  label_idx: jax.Array) -> jax.Array:
    """Approximate attention logits from label channels only.

    q: (H, d) query;  k_label: (S, r) label-channel cache;
    label_idx: (r,) channels. Returns (S,) head-mean |logit| estimate.
    """
    d = q.shape[-1]
    ql = q[..., label_idx].astype(jnp.float32)          # (H, r)
    s = jnp.einsum("hr,sr->hs", ql, k_label.astype(jnp.float32))
    s = s / math.sqrt(d)
    return jnp.mean(s, axis=0)                          # (S,)


def select_topk(scores: jax.Array, valid: jax.Array, k: int,
                num_tokens: jax.Array | None = None,
                recency_window: int = 0) -> tuple[jax.Array, jax.Array]:
    """Pick the k tokens to load this step.

    Recent tokens inside ``recency_window`` of the sequence tail are pinned
    (context locality: the paper's Fig. 3 shows criticals cluster at the
    tail). Returns (indices (k,), mask (S,) bool).
    """
    s = jnp.where(valid, scores, -jnp.inf)
    if recency_window and num_tokens is not None:
        pos = jnp.arange(s.shape[0])
        recent = (pos >= num_tokens - recency_window) & valid
        s = jnp.where(recent, jnp.inf, s)
    _, idx = jax.lax.top_k(s, k)
    mask = jnp.zeros(s.shape, bool).at[idx].set(True) & valid
    return idx, mask


def sparse_step_scores(weights_mean: jax.Array, selected: jax.Array
                       ) -> jax.Array:
    """Per-step S_i(j) for eq. (7): attention mass for selected tokens,
    0 for unselected (they were not loaded, hence contributed nothing)."""
    return jnp.where(selected, weights_mean, 0.0)
