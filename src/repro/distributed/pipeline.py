"""GPipe-style pipeline parallelism via shard_map + ppermute.

Layers are grouped into S stages stacked on a ``stage`` mesh axis; M
microbatches stream through with the classic (M + S - 1)-tick schedule.
Each tick every device applies its stage to its current activation and
ppermutes it to the next stage — compute on tick t overlaps the transfer
issued on tick t-1 (the overlap trick the launcher exposes for deep models
like deepseek-67b where pure TP over 16 devices under-utilizes).

This module is self-contained (used by tests and the scalability
benchmark); the dry-run meshes use DP x TP + sequence-sharded PAMattention,
with PP offered as a launcher option — see DESIGN.md §6.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro import compat  # noqa: F401  (backfills jax.shard_map on 0.4)

from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(mesh: Mesh, stage_fn: Callable, n_stages: int,
                   axis: str = "stage"):
    """Build a pipelined apply.

    stage_fn(stage_params, x) -> x : applies ONE stage's layers.
    Returns f(stacked_params, x_microbatched) where stacked_params has a
    leading (n_stages,) axis sharded on ``axis`` and x_microbatched is
    (M, mb, ...) replicated. Output matches x_microbatched.
    """

    def pipelined(stage_params, xs):
        # the stage axis is sharded to size 1 per device — strip it
        stage_params = jax.tree.map(lambda x: x[0], stage_params)
        M = xs.shape[0]
        ticks = M + n_stages - 1
        my_stage = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        state = jnp.zeros_like(xs[0])            # activation in flight
        outputs = jnp.zeros_like(xs)

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t (when available)
            mb_idx = jnp.clip(t, 0, M - 1)
            fresh = xs[mb_idx]
            inp = jnp.where(my_stage == 0, fresh, state)
            out = stage_fn(stage_params, inp)
            # last stage emits microbatch (t - (S-1))
            emit_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            valid_emit = (t >= n_stages - 1) & (my_stage == n_stages - 1)
            outputs = jax.lax.cond(
                valid_emit,
                lambda o: o.at[emit_idx].set(out),
                lambda o: o, outputs)
            # rotate activations stage i -> i+1
            state = jax.lax.ppermute(out, axis, perm)
            return (state, outputs), None

        (state, outputs), _ = jax.lax.scan(
            tick, (state, outputs), jnp.arange(ticks))
        # outputs live on the last stage; broadcast to all for the caller
        outputs = jax.lax.psum(
            jnp.where(my_stage == n_stages - 1, outputs, 0.0), axis)
        return outputs

    def run(stacked_params, xs):
        pp = jax.tree.map(lambda _: P(axis), stacked_params)
        return jax.shard_map(
            pipelined, mesh=mesh,
            in_specs=(pp, P()),
            out_specs=P(),
            check_vma=False,
        )(stacked_params, xs)

    return run


def stages_from_layers(layer_params, n_stages: int):
    """Regroup scan-stacked per-layer params (L, ...) into
    (n_stages, L//n_stages, ...)."""
    def regroup(x):
        L = x.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return x.reshape((n_stages, L // n_stages) + x.shape[1:])
    return jax.tree.map(regroup, layer_params)
