"""Training example: a ~100M-param MiniCPM-family model for a few hundred
steps with the WSD schedule, checkpoint + resume mid-run.

    PYTHONPATH=src python examples/train_minicpm.py [--steps 300]
"""

import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.models.config import get_config
from repro.training import optim
from repro.training.train_step import (TrainConfig, build_train_step,
                                       init_train_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    # ~100M-param member of the minicpm family (same topology, narrower)
    base = get_config("minicpm-2b")
    cfg = dataclasses.replace(
        base, name="minicpm-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=8, d_ff=1408, vocab=32768, d_head=64, dtype="float32")
    n = cfg.param_count()
    print(f"model: {cfg.name}  params={n/1e6:.0f}M")

    tcfg = TrainConfig(adamw=optim.AdamWConfig(
        lr=optim.wsd_schedule(3e-3, warmup=20, stable=args.steps // 2,
                              decay=args.steps // 3),
        weight_decay=0.01))
    step_fn = jax.jit(build_train_step(cfg, tcfg), donate_argnums=(0,))
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=128, batch=8, seed=3)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))

    ckdir = tempfile.mkdtemp(prefix="minicpm_ck_")
    mgr = CheckpointManager(ckdir, keep=2)
    losses = []
    for s in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        if s % 25 == 0:
            print(f"step {s:4d}  loss {losses[-1]:.4f}")
        if (s + 1) % 100 == 0:
            mgr.save(s + 1, state)

    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"loss: {first:.3f} -> {last:.3f}  "
          f"(improved {first-last:.3f} nats)")
    assert last < first - 0.2, "training must reduce loss"

    step0, _ = mgr.restore_latest(state)
    print(f"checkpoint restore OK from step {step0} ({ckdir})")
    print("train example OK")


if __name__ == "__main__":
    main()
