"""hubert-xlarge [arXiv:2106.07447; unverified] — encoder-only audio
transformer (w2v2 arch); conv feature extractor is a STUB: input_specs
provides precomputed frame embeddings (frontend_dim=512)."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, d_head=80, causal=False,
    frontend_dim=512,
))
