"""Multi-device cluster router (paper §4.3): one request stream served
across N heterogeneous ``ServingEngine`` instances.

The router owns a SHARED arrival queue and binds requests to devices as
late as possible: a queued request is dispatched only when some device
can admit it *right now*, to the device with the lowest admission cost

    cost = (queue + running + 1) * modeled_step_latency
           + occupancy_weight * pool_occupancy

— modeled load plus pool pressure, the paper's inter-device cost signal.
Each device keeps its own simulated clock (its perfmodel latency model
charges every step); the router advances the fleet EVENT-DRIVEN, always
stepping the busy device whose clock is furthest behind, so fast devices
take more steps per simulated second exactly as real hardware would.
Completed tokens stream out through ``drain_events`` as they are
emitted, and an attached ``KVBalancer`` periodically migrates running
requests off overloaded devices (``repro.cluster.migration``).

Fault tolerance (``repro.cluster.{faults,recovery}``): with a
``RecoveryManager`` attached the router runs a watchdog every tick —
alive devices heartbeat the sim-clock frontier into a
``HeartbeatLedger``; a killed device goes silent and is declared dead
after ``heartbeat_timeout_s``, upon which its lost in-flight requests
REPLAY from scratch on survivors (exact: per-request sampling keys +
router-side event dedup against the already-streamed prefix). Stalled
devices are flagged by a prior-normalized ``StragglerMonitor`` and
DRAINED gracefully: running requests move to survivors as checksummed
``KVSnapshot`` transfers with bounded retry. Overload degrades instead
of failing: unserviceable submissions emit rejection ``TokenEvent``s,
and a starving queue head preempts the fleet's lowest-importance
running request into a host-held snapshot (resumed after a cooldown).

The router's recovery decisions use only information a real control
plane has: its own submit-time request registry (``_requests``), its
streamed-token history (``_history``) and the detection verdicts.
Engine internals of a dead device are read only to enumerate which
requests were placed there (placement the router itself performed).
"""

from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Any, Callable, Iterable, Iterator, Optional

import numpy as np

from repro.cluster.balancer import BalancerConfig, KVBalancer
from repro.cluster.faults import TRANSFER_KINDS, FaultEvent, FaultInjector
from repro.cluster.migration import KVSnapshot
from repro.cluster.recovery import RecoveryConfig, RecoveryManager
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.perfmodel.devices import DeviceClass
from repro.serving.engine import (DONE, RUNNING, Request, ServingConfig,
                                  ServingEngine)
from repro.serving.events import ServeEvent

# The router's streamed-token type IS the unified serving event (PR 10);
# the old name stays as the canonical alias cluster-side code imports.
TokenEvent = ServeEvent


@dataclasses.dataclass
class ClusterDevice:
    """One engine + its device class inside the router."""
    name: str
    cls: DeviceClass
    engine: ServingEngine
    step_prior: float = 0.0      # a-priori step latency (cost signal seed)
    prefill_tok_prior: float = 0.0   # modeled seconds per prefill token
    tokens_emitted: int = 0
    steps: int = 0
    # fault-tolerance state. ``state`` is the ROUTER'S BELIEF ("up",
    # "dead", "drained"); ``killed`` is sim ground truth the fault
    # injector sets — the router never reads it for decisions, it only
    # makes a killed engine unsteppable/silent so the watchdog has
    # something real to detect.
    state: str = "up"
    killed: bool = False
    stall_factor: float = 1.0
    base_latency: Optional[Callable[[dict], float]] = None
    hog_rid: Optional[int] = None    # exhaust-fault pool hog

    def has_work(self) -> bool:
        eng = self.engine
        return bool(eng.waiting) or any(s is not None for s in eng.slots)


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    occupancy_weight: float = 1e-3   # pool-pressure term in the cost
    max_ticks: int = 200_000


class ClusterRouter:
    """Route one request stream over heterogeneous serving engines."""

    def __init__(self, devices: list[ClusterDevice],
                 balancer: Optional[KVBalancer] = None,
                 rcfg: RouterConfig = RouterConfig(),
                 recovery: Optional[RecoveryManager] = None,
                 faults: Optional[FaultInjector] = None):
        if not devices:
            raise ValueError("cluster needs at least one device")
        names = [d.name for d in devices]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate device names: {names}")
        self.devices = devices
        self.balancer = balancer
        self.rcfg = rcfg
        self.recovery = recovery
        self.faults = faults
        self.arrivals: collections.deque[Request] = collections.deque()
        self.queue: collections.deque[Request] = collections.deque()
        self.ticks = 0
        self.finished: dict[int, Any] = {}       # rid -> RequestState
        self.rejected = 0
        self._events: list[TokenEvent] = []
        self._seen_tokens: dict[int, int] = {}   # rid -> emitted count
        self._shape: dict[int, tuple[int, int]] = {}  # rid -> (prompt, gen)
        self._requests: dict[int, Request] = {}  # submit-time registry
        self._history: dict[int, list[int]] = {}  # rid -> streamed tokens
        self._replaying: set[int] = set()        # rids re-serving a prefix
        self._kill_clock: dict[str, float] = {}  # device -> sim kill time
        self._head_since: Optional[tuple[int, int]] = None  # (rid, tick)
        self._wait_clock = 0.0           # router-side watchdog clock: the
        # control plane's own notion of time, which keeps advancing even
        # when EVERY device is silent (otherwise a whole-fleet kill
        # would freeze the frontier and silence could never time out)
        self._bind_obs()

    def _bind_obs(self) -> None:
        """Bind the router's instruments against the currently installed
        registry (see ``ServingEngine._bind_obs``; canonical names in
        docs/ARCHITECTURE.md). Balancer work is metered by diffing its
        cumulative counters once per rebalance tick."""
        reg = obs_metrics.get_registry()
        self._mreg = reg
        self._m_ticks = reg.counter(
            "pam_cluster_ticks_total", "router scheduling iterations")
        self._m_queue = reg.gauge(
            "pam_cluster_queue_depth",
            "requests in the shared (unbound) queue")
        self._m_rejected = reg.counter(
            "pam_cluster_rejected_total",
            "streams ended with a rejection event")
        self._m_sheds = reg.counter(
            "pam_cluster_sheds_total",
            "queued requests shed by admission control")
        self._m_force_preempts = reg.counter(
            "pam_cluster_force_preempts_total",
            "SLO-driven immediate preemptions")
        self._m_faults = reg.counter(
            "pam_cluster_faults_total", "chaos faults applied, by kind",
            ("kind",))
        self._m_verdicts = reg.counter(
            "pam_cluster_watchdog_verdicts_total",
            "watchdog verdicts, by outcome", ("verdict",))
        self._m_bal_migrations = reg.counter(
            "pam_cluster_balancer_migrations_total",
            "requests moved by the online balancer")
        self._m_bal_bytes = reg.counter(
            "pam_cluster_balancer_migrated_bytes_total",
            "KV bytes moved by the online balancer")
        self._m_mig_bytes_h = reg.histogram(
            "pam_cluster_migration_bytes",
            "bytes per balancer rebalance burst",
            buckets=obs_metrics.BYTES_BUCKETS)
        self._bal_seen = (0, 0)          # (migrations, bytes) last diffed

    def _observe_balancer(self) -> None:
        """Fold the balancer's cumulative counters into the registry
        (called right after each rebalance)."""
        if self.balancer is None or not self._mreg.enabled:
            return
        m, b = self.balancer.migrations, self.balancer.moved_bytes
        dm, db = m - self._bal_seen[0], b - self._bal_seen[1]
        self._bal_seen = (m, b)
        if dm:
            self._m_bal_migrations.inc(dm)
        if db:
            self._m_bal_bytes.inc(db)
            self._m_mig_bytes_h.observe(db)

    # -------------------------------------------------------- device views
    def _steppable(self) -> list[ClusterDevice]:
        """Devices the router can actually advance: alive (a killed
        engine never answers a step RPC) and holding work. Drained
        devices still finish their residual batch — they just get no
        new dispatches."""
        return [d for d in self.devices
                if not d.killed and d.state != "dead" and d.has_work()]

    def _alive(self) -> list[ClusterDevice]:
        return [d for d in self.devices
                if not d.killed and d.state != "dead"]

    def _up(self) -> list[ClusterDevice]:
        """Dispatch targets: devices the router believes healthy."""
        return [d for d in self.devices if d.state == "up"]

    def _failed_pending(self) -> list[ClusterDevice]:
        """Killed-but-undetected devices still holding work — the
        watchdog must burn timeout time to discover them."""
        return [d for d in self.devices
                if d.killed and d.state == "up" and d.has_work()]

    # ------------------------------------------------------------- intake
    def _reject(self, req: Request) -> None:
        """Graceful degradation: end the request's stream with a
        rejection event (done=True, no token) instead of raising —
        one lost request must never kill the whole stream."""
        self.rejected += 1
        self._m_rejected.inc()
        t = max(self.now(), req.arrival)
        self._events.append(TokenEvent(
            time=t, request_id=req.id,
            token=-1, index=self._seen_tokens.get(req.id, 0), device="",
            done=True, rejected=True))
        tr = obs_trace.COLLECTOR
        if tr is not None:
            tr.mark(req.id, "reject", t)
            phase = tr.open_phase(req.id)
            if phase is not None:
                tr.end(req.id, phase, t)

    def submit(self, req: Request) -> None:
        """Add a request to the shared stream (``req.arrival`` is its
        simulated arrival time; submissions must be time-ordered).
        A request no healthy device can ever serve is REJECTED (a
        ``rejected`` ``TokenEvent``), not raised."""
        if self.arrivals and req.arrival < self.arrivals[-1].arrival:
            raise ValueError("submit arrivals in nondecreasing time order")
        window = len(req.prompt) + req.max_new_tokens
        self._requests[req.id] = req
        self._shape[req.id] = (len(req.prompt), req.max_new_tokens)
        if not any(d.engine.serviceable(window) for d in self._up()):
            self._reject(req)
            return
        self.arrivals.append(req)
        tr = obs_trace.COLLECTOR
        if tr is not None:
            # the span opens at ARRIVAL; engine-side submit re-begins
            # the same phase idempotently when the request is bound
            tr.begin(req.id, "queued", req.arrival,
                     prompt=len(req.prompt))

    def submit_to(self, req: Request, device_name: str) -> None:
        """Pin a request to one device, bypassing cost-based dispatch
        (tests/demos use this to pre-load a device; real traffic should
        go through ``submit``). Registers the router bookkeeping so
        completions, events and migrations track the request normally.
        An unserviceable window rejects (event) instead of raising."""
        dev = self._by_name(device_name)
        window = len(req.prompt) + req.max_new_tokens
        self._requests[req.id] = req
        self._shape[req.id] = (len(req.prompt), req.max_new_tokens)
        if dev.state != "up" or not dev.engine.serviceable(window):
            self._reject(req)
            return
        dev.engine.submit(req)

    # ------------------------------------------------------------ signals
    def now(self) -> float:
        """Cluster frontier: the slowest steppable device's clock
        (none in flight: the max healthy clock — nothing is in flight
        before it)."""
        busy = [d.engine.clock for d in self._steppable()]
        if busy:
            return min(busy)
        pool = [d.engine.clock for d in self._up()
                if not d.killed] or [d.engine.clock for d in self.devices]
        return max(pool)

    def admission_cost(self, dev: ClusterDevice, prompt_len: int,
                       gen_len: int, pending: int = 0) -> float:
        """Expected completion cost of placing one request on ``dev``:
        its full service time there (modeled prefill of the prompt +
        ``gen_len`` modeled decode steps), multiplied by the admission
        waves already ahead of it (device queue, ``pending`` shared-queue
        requests deferred toward it this round, and half the mid-flight
        running batch), plus pool pressure. Pricing the *whole* service
        — prefill included — is what stops bursts from sinking onto a
        slow device whose queue-free slots look temptingly open."""
        sig = dev.engine.load_signal()
        step = sig["step_time_s"] or dev.step_prior
        service = prompt_len * dev.prefill_tok_prior + gen_len * step
        ahead = (sig["queue_depth"] + pending + 0.5 * sig["running"])
        waves = -(-int(ahead + 1) // max(dev.engine.scfg.max_batch, 1))
        return (waves * service
                + self.rcfg.occupancy_weight * sig["pool_occupancy"])

    # ----------------------------------------------------------- dispatch
    def _release_arrivals(self) -> None:
        horizon = self.now()
        while self.arrivals and self.arrivals[0].arrival <= horizon:
            self.queue.append(self.arrivals.popleft())

    def _dispatch(self) -> None:
        """Cost-based late binding. Each queued request is priced on
        every serviceable healthy device — including busy ones it would
        have to WAIT for — and bound to the cheapest. If the winner
        cannot admit it right now the request stays in the shared queue
        (deferred: queueing for a fast device beats sinking a burst onto
        a slow one), with a virtual-depth mark so the rest of the round
        prices that device as one deeper. A request whose window no
        healthy device can serve anymore (device loss) is rejected."""
        still: collections.deque[Request] = collections.deque()
        virtual = {d.name: 0 for d in self.devices}
        while self.queue:
            req = self.queue.popleft()
            prompt_len, gen_len = self._shape[req.id]
            window = prompt_len + gen_len
            cands = [d for d in self._up()
                     if d.engine.serviceable(window)]
            if not cands:
                self._reject(req)
                continue
            best = min(cands, key=lambda d: self.admission_cost(
                d, prompt_len, gen_len, pending=virtual[d.name]))
            # can_accept nets out the device's own waiting queue, so one
            # dispatch round cannot over-assign a device
            if best.engine.can_accept(window):
                # an idle device may have an old clock; it cannot serve
                # a request before the request exists
                best.engine.clock = max(best.engine.clock, req.arrival)
                best.engine.submit(req)
            else:
                virtual[best.name] += 1
                still.append(req)
        self.queue = still

    # ------------------------------------------------------------ stepping
    def _collect(self, dev: ClusterDevice) -> None:
        """Diff the device's request states into stream events and pick
        up completions. Replayed requests first REGENERATE their
        already-streamed prefix: those tokens are verified against the
        router's history and suppressed (never re-emitted), so a
        client's stream stays gapless and duplicate-free across a
        device loss."""
        eng = dev.engine
        done_rids = []
        for rid, rs in eng.requests.items():
            seen = self._seen_tokens.get(rid, 0)
            if rid in self._replaying:
                hist = self._history.get(rid, [])
                n = min(seen, len(rs.outputs))
                if rs.outputs[:n] != hist[:n]:
                    raise RuntimeError(
                        f"replay diverged for request {rid}: regenerated "
                        f"prefix does not match the streamed history")
                if len(rs.outputs) >= seen:
                    self._replaying.discard(rid)
            for i in range(seen, len(rs.outputs)):
                t = (rs.token_times[i] if i < len(rs.token_times)
                     else eng.clock)
                self._events.append(TokenEvent(
                    time=t, request_id=rid, token=rs.outputs[i], index=i,
                    device=dev.name,
                    done=(rs.status == DONE and i == len(rs.outputs) - 1)))
                self._history.setdefault(rid, []).append(rs.outputs[i])
                dev.tokens_emitted += 1
            self._seen_tokens[rid] = max(seen, len(rs.outputs))
            if rs.status == DONE:
                done_rids.append(rid)
        for rid in done_rids:
            self.finished[rid] = eng.requests.pop(rid)

    # ---------------------------------------------------------- fault path
    def _apply_fault(self, ev: FaultEvent) -> None:
        """Apply one injected fault (``FaultInjector`` ground truth)."""
        self._m_faults.labels(kind=ev.kind).inc()
        tr = obs_trace.COLLECTOR
        if tr is not None:
            tr.instant(ev.device, f"fault:{ev.kind}", self.now())
        if ev.kind in TRANSFER_KINDS:
            return                       # armed inside the injector
        dev = self._by_name(ev.device)
        eng = dev.engine
        if ev.kind == "kill":
            dev.killed = True
            # the injection moment is fleet sim time, not the victim's
            # own clock (an idle victim's clock lags the frontier, which
            # would overstate the measured recovery latency)
            self._kill_clock[dev.name] = max(
                (d.engine.clock for d in self.devices), default=eng.clock)
        elif ev.kind == "stall":
            dev.stall_factor = ev.factor
            if dev.base_latency is None:
                dev.base_latency = eng.latency_model
            if dev.base_latency is not None:
                base, f = dev.base_latency, ev.factor
                eng.latency_model = lambda s: f * float(base(s))
        elif ev.kind == "unstall":
            dev.stall_factor = 1.0
            if dev.base_latency is not None:
                eng.latency_model = dev.base_latency
        elif ev.kind == "exhaust":
            alloc = eng.allocator
            if (alloc is not None and dev.hog_rid is None
                    and alloc.free_blocks > 0):
                dev.hog_rid = (1 << 40) + self.devices.index(dev)
                alloc.allocate(dev.hog_rid,
                               alloc.free_blocks * alloc.block_size)
        elif ev.kind == "release":
            if eng.allocator is not None and dev.hog_rid is not None:
                eng.allocator.free(dev.hog_rid)
                dev.hog_rid = None

    def _charge(self, dev: ClusterDevice, seconds: float) -> None:
        dev.engine.clock += seconds

    def _rescue_target(self, snap: KVSnapshot,
                       exclude: str) -> Optional[ClusterDevice]:
        window = (len(snap.request.prompt)
                  + snap.request.max_new_tokens)
        cands = [d for d in self._up()
                 if d.name != exclude and d.engine.serviceable(window)
                 and d.engine.can_accept(window, reserve_queued=False)]
        if not cands:
            return None
        plen, glen = self._shape.get(snap.request.id,
                                     (len(snap.request.prompt),
                                      snap.request.max_new_tokens))
        remaining = glen - len(snap.outputs)
        return min(cands, key=lambda d: self.admission_cost(
            d, 0, max(remaining, 1)))

    def _declare_dead(self, dev: ClusterDevice) -> None:
        """Watchdog verdict: the device is gone and its KV with it.
        Every request the router had placed there goes back to the
        shared queue for REPLAY on a survivor — exact, because
        recomputation is deterministic per (seed, rid, position) and
        ``_collect`` dedupes the regenerated prefix."""
        rec = self.recovery
        dev.state = "dead"
        rec.stats["kills_detected"] += 1
        self._m_verdicts.labels(verdict="dead").inc()
        tr = obs_trace.COLLECTOR
        if tr is not None:
            tr.instant(dev.name, "watchdog:dead", self.now())
        t_kill = self._kill_clock.get(dev.name, dev.engine.clock)
        alive = self._alive()
        t_now = (max(d.engine.clock for d in alive) if alive
                 else dev.engine.clock)
        rec.note_recovery(max(t_now - t_kill, 0.0))
        eng = dev.engine
        for rid in list(eng.requests):
            rs = eng.requests.pop(rid)
            if rs.status == DONE:        # already collected upstream
                self.finished.setdefault(rid, rs)
                continue
            req = self._requests.get(rid, rs.request)
            if self._seen_tokens.get(rid, 0):
                self._replaying.add(rid)
            if rs.status == RUNNING:
                rec.stats["replays"] += 1
                if tr is not None:
                    tr.mark(rid, "replay", self.now(), lost=dev.name)
                    tr.begin(rid, "queued", self.now(), replay=True)
            self.queue.append(req)
        # the dead engine's host bookkeeping is gone with it
        eng.waiting.clear()
        eng.slots = [None] * len(eng.slots)

    def _drain(self, dev: ClusterDevice) -> None:
        """Graceful drain of a flagged (alive but degraded) device:
        queued work returns to the shared queue; running requests export
        as checksummed snapshots and transfer to survivors (bounded
        retry on drop/corruption, rollback here on terminal failure —
        this device is slow, not dead). No new work is dispatched to a
        drained device, but it finishes whatever could not move."""
        rec = self.recovery
        dev.state = "drained"
        self._m_verdicts.labels(verdict="drained").inc()
        tr = obs_trace.COLLECTOR
        if tr is not None:
            tr.instant(dev.name, "watchdog:drain", self.now())
        eng = dev.engine
        for rid in list(eng.waiting):
            eng.requests.pop(rid, None)
            self.queue.append(self._requests[rid])
        eng.waiting.clear()
        # only RUNNING requests have exportable KV; a mid-chunked-prefill
        # (PREFILLING) request has no hot row or sampled token yet — it
        # finishes filling and decodes on the drained device (slow, not
        # dead), exactly like residual work the transfer path rejects
        running = [rid for rid in eng.slots
                   if rid is not None
                   and eng.requests[rid].status == RUNNING]
        for rid in running:
            snap = KVSnapshot.export(eng, rid)
            dst = self._rescue_target(snap, exclude=dev.name)
            if dst is None:
                # no capacity anywhere right now: hold it host-side and
                # resume via the suspension path when capacity frees
                rec.suspended.append((snap, self.ticks))
                continue
            if not any(s is not None for s in dst.engine.slots):
                dst.engine.clock = max(dst.engine.clock, eng.clock)
            if rec.transfer(snap, dst.engine,
                            lambda s, d=dst: self._charge(d, s)):
                rec.stats["drains"] += 1
            else:
                snap.commit(eng)         # pristine copy back home
        self._head_since = None

    def _watchdog(self) -> None:
        """Heartbeats + verdicts, once per tick. Alive devices beat the
        fleet frontier (a live host answers its control plane no matter
        how stale its own work clock is); a killed device's beat
        freezes, and once the frontier moves ``heartbeat_timeout_s``
        past it the device is declared dead."""
        rec = self.recovery
        alive = self._alive()
        pool = alive or self.devices
        t = max(max(d.engine.clock for d in pool), self._wait_clock)
        for i, d in enumerate(self.devices):
            if not d.killed and d.state != "dead":
                rec.heartbeat(i, t)
        rec.advance(t)
        for i in rec.dead_indices():
            d = self.devices[i]
            if d.killed and d.state == "up":
                self._declare_dead(d)
        for i in rec.straggler_indices():
            d = self.devices[i]
            if d.state == "up" and not d.killed:
                self._drain(d)

    # ------------------------------------------------- degradation policies
    def shed(self, rid: int) -> bool:
        """Admission-control hook (PR 8): drop a QUEUED request and end
        its stream with a rejection event — load shedding for a request
        whose deadline is provably unmeetable (``repro.frontend.
        admission``). Only the shared queue is sheddable: a request
        already placed on a device is past admission. Returns True if
        the request was found and shed."""
        for req in self.queue:
            if req.id == rid:
                self.queue.remove(req)
                self._m_sheds.inc()
                tr = obs_trace.COLLECTOR
                if tr is not None:
                    tr.mark(rid, "shed", self.now())
                self._reject(req)
                return True
        return False

    def _preempt_victim(self, window: int,
                        exclude_rid: Optional[int] = None) -> bool:
        """Suspend the fleet's lowest-importance running request — the
        cheapest accuracy stake, Alg. 2's rule at cluster scope — into a
        host-held snapshot, freeing its slot and blocks for a ``window``
        -token admission. Returns True if a victim was suspended."""
        rec = self.recovery
        best = None
        for d in self._up():
            if d.killed or not d.engine.serviceable(window):
                continue
            for rid, mass in d.engine.slot_importance_mass().items():
                if rid == exclude_rid:
                    continue
                rs = d.engine.requests[rid]
                left = rs.request.max_new_tokens - len(rs.outputs)
                if left < rec.cfg.min_preempt_remaining:
                    continue
                if best is None or mass < best[0]:
                    best = (mass, d, rid)
        if best is None:
            return False
        _, dev, rid = best
        rec.suspend(dev.engine, rid, self.ticks)
        return True

    def force_preempt(self, rid: int) -> bool:
        """SLO-admission hook (PR 8): preempt on behalf of queued
        request ``rid`` NOW, bypassing the tick-based starvation fuse —
        the deadline-aware front end decides a queue head has burned too
        much of its TTFT budget and frees capacity immediately. Requires
        an attached ``RecoveryManager`` (the suspension machinery).
        Returns True if a victim was suspended."""
        if self.recovery is None:
            return False
        shape = self._shape.get(rid)
        if shape is None:
            return False
        if self._preempt_victim(shape[0] + shape[1]):
            self._head_since = (rid, self.ticks)   # re-arm the fuse
            self._m_force_preempts.inc()
            return True
        return False

    def _maybe_preempt(self) -> None:
        """Preemption-by-demotion: when the shared queue's head has
        starved for ``preempt_after_ticks`` (pool exhaustion, capacity
        loss), suspend the fleet's lowest-importance running request
        (``_preempt_victim``)."""
        rec = self.recovery
        if not self.queue:
            self._head_since = None
            return
        head = self.queue[0]
        if self._head_since is None or self._head_since[0] != head.id:
            self._head_since = (head.id, self.ticks)
            return
        if (self.ticks - self._head_since[1]
                < rec.cfg.preempt_after_ticks):
            return
        plen, glen = self._shape[head.id]
        if self._preempt_victim(plen + glen):
            self._head_since = (head.id, self.ticks)   # re-arm the fuse

    def _maybe_resume(self) -> None:
        """Resume cooled-down suspended snapshots wherever capacity has
        freed (checksummed transfer, retry on faults). A snapshot whose
        window no healthy device can ever host again falls back to
        replay — and if even replay is unserviceable, the stream ends
        with a rejection event rather than hanging the cluster."""
        rec = self.recovery
        for snap in rec.resumable(self.ticks):
            req = snap.request
            window = len(req.prompt) + req.max_new_tokens
            dst = self._rescue_target(snap, exclude="")
            if dst is not None:
                if not any(s is not None for s in dst.engine.slots):
                    dst.engine.clock = max(dst.engine.clock, self.now())
                if rec.transfer(snap, dst.engine,
                                lambda s, d=dst: self._charge(d, s)):
                    rec.drop_suspended(snap)
                    rec.stats["resumes"] += 1
                continue                 # transfer failed: retry later
            if any(d.engine.serviceable(window) for d in self._up()):
                continue                 # capacity will free; wait
            rec.drop_suspended(snap)
            if self._seen_tokens.get(req.id, 0):
                self._replaying.add(req.id)
            rec.stats["abandoned"] += 1
            self._reject(req)

    # ---------------------------------------------------------------- tick
    def tick(self) -> bool:
        """One router iteration. Returns False when the stream is fully
        served (no arrivals, no queue, no running or suspended work)."""
        if self.faults is not None:
            for ev in self.faults.due(self.ticks):
                self._apply_fault(ev)
        # idle fleet + future arrivals: jump the fleet to the next event
        if (self.arrivals and not self.queue and not self._steppable()
                and not self._failed_pending()
                and not (self.recovery and self.recovery.suspended)):
            t = self.arrivals[0].arrival
            for d in self._alive():
                d.engine.clock = max(d.engine.clock, t)
        self._release_arrivals()
        self._dispatch()
        if self.recovery is not None:
            self._maybe_resume()
            self._maybe_preempt()
        steppable = self._steppable()
        if steppable:
            # event-driven: advance the furthest-behind steppable device
            dev = min(steppable, key=lambda d: d.engine.clock)
            dev.engine.step()
            dev.steps += 1
            if self.recovery is not None:
                self.recovery.observe_step(self.devices.index(dev), dev,
                                           dev.engine.last_step_time)
            self._collect(dev)
        elif self._failed_pending() and self.recovery is not None:
            # nothing steppable but a silent device still holds work:
            # the watchdog WAITS — detection costs real simulated time
            alive = self._alive()
            pool = alive or self.devices
            t = (max(max(d.engine.clock for d in pool), self._wait_clock)
                 + self.recovery.cfg.heartbeat_timeout_s)
            self._wait_clock = t
            for d in alive:
                d.engine.clock = max(d.engine.clock, t)
        self.ticks += 1
        if self._mreg.enabled:
            self._m_ticks.inc()
            self._m_queue.set(len(self.queue))
        tr = obs_trace.COLLECTOR
        if tr is not None:
            tr.counter("router", "shared_queue", self.now(),
                       depth=len(self.queue))
        if self.recovery is not None:
            self._watchdog()
        if (self.balancer is not None
                and self.ticks % self.balancer.cfg.rebalance_interval == 0):
            # migrated requests carry their outputs with them; pending
            # tokens surface at the destination's next _collect
            self.balancer.rebalance(
                [d for d in self._up() if not d.killed], self.ticks)
            self._observe_balancer()
        return bool(self.arrivals or self.queue or self._steppable()
                    or self._failed_pending()
                    or (self.recovery and self.recovery.suspended))

    def run(self, max_ticks: Optional[int] = None) -> dict[str, Any]:
        limit = max_ticks if max_ticks is not None else self.rcfg.max_ticks
        for _ in range(limit):
            if not self.tick():
                break
        else:
            raise RuntimeError(f"cluster did not drain in {limit} ticks")
        return self.summary()

    def _by_name(self, name: str) -> ClusterDevice:
        return next(d for d in self.devices if d.name == name)

    # ----------------------------------------------------------- streaming
    def drain_events(self) -> list[TokenEvent]:
        """Streaming completion API: token events emitted since the last
        drain, in emission order."""
        out, self._events = self._events, []
        return out

    def as_router(self) -> "ClusterRouter":
        """Unified-backend hook (PR 10): a router is already a router.
        ``ServingEngine.as_router`` wraps a bare engine the same way, so
        front ends duck-type one backend shape."""
        return self

    def serve(self, requests: Optional[Iterable[Request]] = None, *,
              max_ticks: Optional[int] = None) -> Iterator[TokenEvent]:
        """Unified streaming surface (PR 10): submit ``requests`` (if
        given), then tick until the stream fully drains, yielding each
        ``ServeEvent`` in emission order. The single generator both the
        CLI batch path and the cluster path consume; the async front end
        (``frontend.AsyncServer``) remains the per-request-stream view
        over the same events."""
        if requests is not None:
            for req in requests:
                self.submit(req)
        yield from self.drain_events()
        limit = max_ticks if max_ticks is not None else self.rcfg.max_ticks
        for _ in range(limit):
            live = self.tick()
            yield from self.drain_events()
            if not live:
                return
        raise RuntimeError(f"cluster did not drain in {limit} ticks")

    @classmethod
    def for_engine(cls, engine: ServingEngine, *,
                   name: Optional[str] = None,
                   rcfg: RouterConfig = RouterConfig(),
                   preemptible: bool = False) -> "ClusterRouter":
        """Wrap one engine as a 1-device cluster so every front end
        speaks a single backend dialect. ``preemptible`` attaches a
        default ``RecoveryManager`` (the suspension machinery SLO
        admission's force-preempt needs); with one honest device the
        watchdog is inert."""
        dc = DeviceClass(name="local", max_batch=engine.scfg.max_batch)
        dev = ClusterDevice(name=name or engine.name or "local0", cls=dc,
                            engine=engine)
        if engine.latency_model is not None:
            dev.prefill_tok_prior = float(
                engine.latency_model({"prefill_tokens": 1, "active": 0}))
            dev.base_latency = engine.latency_model
        recovery = (RecoveryManager(RecoveryConfig()) if preemptible
                    else None)
        return cls([dev], rcfg=rcfg, recovery=recovery)

    # ------------------------------------------------------------- metrics
    def summary(self) -> dict[str, Any]:
        makespan = max(d.engine.clock for d in self.devices)
        total_tokens = sum(len(rs.outputs) for rs in self.finished.values())
        per_device = {}
        for d in self.devices:
            per_device[d.name] = {
                "class": d.cls.name,
                "state": d.state,
                "steps": d.steps,
                "tokens_emitted": d.tokens_emitted,
                "busy_time_s": d.engine.busy_time,
                "utilization": (d.engine.busy_time / makespan
                                if makespan > 0 else 0.0),
                "decode_dispatches": d.engine.decode_dispatches,
                "decode_device_steps": d.engine.decode_device_steps,
                "migrations_in": d.engine.migrations_in,
                "migrations_out": d.engine.migrations_out,
            }
        out = {
            "finished": len(self.finished),
            "rejected": self.rejected,
            "total_tokens": total_tokens,
            "makespan_s": makespan,
            "throughput_tok_s": (total_tokens / makespan
                                 if makespan > 0 else 0.0),
            # canonical names (PR 9): balancer_* is the online
            # balancer's own work; migrations_in/out are the fleet-wide
            # engine-level sums (balancing + drain + suspend/resume)
            "balancer_migrations": (self.balancer.migrations
                                    if self.balancer is not None else 0),
            "migrated_bytes": (self.balancer.moved_bytes
                               if self.balancer is not None else 0),
            "migrations_in": sum(d.engine.migrations_in
                                 for d in self.devices),
            "migrations_out": sum(d.engine.migrations_out
                                  for d in self.devices),
            "ticks": self.ticks,
            "devices": per_device,
        }
        if self.recovery is not None:
            lat = self.recovery.recovery_latencies
            out["fault_tolerance"] = dict(
                self.recovery.stats,
                suspended_now=len(self.recovery.suspended),
                recovery_latency_mean_s=(float(np.mean(lat)) if lat
                                         else 0.0),
                recovery_latency_max_s=(float(np.max(lat)) if lat
                                        else 0.0))
        return out

    def slo_attainment(self, slo_s: float) -> float:
        """Fraction of decode-token gaps within the SLO, fleet-wide
        (migration seams clamp at 0 — clocks resync on transfer)."""
        gaps: list[float] = []
        for rs in self.finished.values():
            if len(rs.token_times) > 1:
                gaps.extend(np.maximum(np.diff(rs.token_times), 0.0)
                            .tolist())
        if not gaps:
            return 1.0
        return float(np.mean(np.asarray(gaps) <= slo_s))


# ------------------------------------------------------------ construction
def build_cluster(cfg, params, device_classes: Iterable[DeviceClass], *,
                  scfg: ServingConfig, model_desc=None,
                  balancer: Optional[KVBalancer] = None,
                  bcfg: Optional[BalancerConfig] = None,
                  rcfg: RouterConfig = RouterConfig(),
                  faults: Optional[FaultInjector] = None,
                  recovery=None,
                  wallclock: bool = False) -> ClusterRouter:
    """Build a heterogeneous cluster serving one model.

    ``scfg`` is the per-engine template; each device class overrides
    ``max_batch``/``pool_blocks`` from its own capacity profile and gets
    its own perfmodel latency model (``wallclock=True`` disables modeled
    timing — used by wall-clock benches). Engines share ``params`` (one
    replica per device, as on real fleets).

    ``faults`` attaches a chaos trace; ``recovery`` a
    ``RecoveryManager`` or ``RecoveryConfig`` (a bare injector implies
    a default recovery manager — injected faults without a watchdog
    would hang the stream).

    DEPRECATED (PR 10): construction is declarative now — build a
    ``repro.cluster.spec.ClusterSpec`` and call ``.build(params, ...)``.
    This shim forwards and warns."""
    warnings.warn(
        "build_cluster(...) is deprecated; use ClusterSpec.of(cfg, "
        "device_classes, serving=scfg, ...).build(params, ...) from "
        "repro.cluster.spec", DeprecationWarning, stacklevel=2)
    from repro.cluster.spec import ClusterSpec
    spec = ClusterSpec.of(
        cfg, device_classes, serving=scfg, model_desc=model_desc,
        balancer=bcfg, router=rcfg,
        recovery=recovery if isinstance(recovery, RecoveryConfig)
        else None, wallclock=wallclock)
    return spec.build(
        params, balancer=balancer, faults=faults,
        recovery=None if isinstance(recovery, RecoveryConfig)
        else recovery)
