"""Chaos benchmark (PR 6): goodput under injected faults.

One bursty request stream served by the heterogeneous 3-device cluster
(1x HBM + 2x CXL) three ways: fault-free, with one hard device kill
mid-decode, and under a mixed kill+stall+corruption trace. Every run is
scored against the failure-free twin streams (per-request sampling keys
make the canonical stream a pure function of the request), so "tokens
lost" is measured token-by-token, not inferred from counters.

The PR-6 trajectory point (``benchmarks/run.py --section chaos --out
BENCH_pr6.json``): zero lost tokens in every scenario and 1-kill
goodput >= 0.8x the fault-free run.
"""

from __future__ import annotations

from typing import Optional

from benchmarks.cluster_bench import bursty_trace

# Watchdog tuned to the modeled device step time (~ms): a silent device
# is declared dead after 20 ms of sim time, so detection latency stays
# small next to the makespan of a bursty 48-request trace.
_HEARTBEAT_S = 0.02


def _run_chaos(cfg, params, classes, scfg, trace, twin, chaos, slo_s,
               chaos_seed=0):
    from repro.cluster import (BalancerConfig, ClusterSpec, FaultInjector,
                               KVBalancer, RecoveryConfig)
    faults = (FaultInjector.from_spec(chaos, seed=chaos_seed)
              if chaos else None)
    recovery = RecoveryConfig(heartbeat_timeout_s=_HEARTBEAT_S)
    bal = KVBalancer(BalancerConfig(rebalance_interval=4, hysteresis=1.2,
                                    cooldown_ticks=8))
    router = ClusterSpec.of(cfg, classes, serving=scfg,
                            recovery=recovery).build(
        params, balancer=bal, faults=faults)
    for req in trace:
        router.submit(req)
    summary = router.run()
    summary["slo_attainment"] = router.slo_attainment(slo_s)

    # token-exact scoring vs the failure-free twin streams
    ref_total = sum(len(v) for v in twin.values())
    good = 0
    for rid, ref in twin.items():
        rs = router.finished.get(rid)
        out = rs.outputs if rs is not None else []
        good += sum(1 for a, b in zip(out, ref) if a == b)
    summary["ref_tokens"] = ref_total
    summary["good_tokens"] = good
    summary["tokens_lost"] = ref_total - good
    summary["goodput_tok_s"] = (good / summary["makespan_s"]
                                if summary["makespan_s"] > 0 else 0.0)
    return summary


def bench_chaos(n_requests: int = 48, slo_s: float = 0.05,
                seed: int = 3) -> dict:
    """Fault-free vs 1-kill vs mixed-fault runs of the same trace.

    Returns the machine-readable comparison: ``tokens_lost`` must be 0
    in every scenario (twin exactness through recovery) and the 1-kill
    goodput ratio must hold >= 0.8 (the PR-6 acceptance point)."""
    import jax
    from repro.models import transformer as tf
    from repro.models.config import get_config, reduced
    from repro.perfmodel.devices import CXL_CLASS, HBM_CLASS
    from repro.serving import (EngineSpec, PAMManagerConfig, Request,
                               ServingConfig)

    cfg = reduced(get_config("pam-llama-7b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    pam = PAMManagerConfig(max_tokens=64, hot_capacity=4, warm_capacity=8,
                           compression=4, recency_window=2,
                           schedule_interval=2)
    scfg = ServingConfig(max_batch=4, max_len=64, pam=pam, block_size=8,
                         temperature=1.0, sample_seed=13)
    classes = [HBM_CLASS, CXL_CLASS, CXL_CLASS]
    trace = lambda: bursty_trace(n_requests, cfg.vocab, seed=seed)

    # canonical per-request streams: one plain engine, arrivals ignored
    # (streams are batch/slot/phase-independent by construction)
    eng = EngineSpec(model=cfg, serving=scfg).build(params)
    for r in trace():
        eng.submit(Request(id=r.id, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens))
    eng.run()
    twin = {rid: rs.outputs for rid, rs in eng.requests.items()}

    chaos_1kill = "kill:cxl1@40"
    chaos_mixed = "stall:cxl0@25x6, kill:cxl1@40, corrupt@30*1"
    out = {
        "config": {
            "model": cfg.name, "n_requests": n_requests,
            "prompt_len": 16, "max_new_tokens": 16, "burst": 16,
            "block_size": 8, "max_len": 64,
            "temperature": 1.0, "sample_seed": 13,
            "devices": "hbm:1,cxl:2",
            "heartbeat_timeout_s": _HEARTBEAT_S,
            "chaos_1kill": chaos_1kill, "chaos_mixed": chaos_mixed,
            "seed": seed,
        },
        "fault_free": _run_chaos(cfg, params, classes, scfg, trace(),
                                 twin, None, slo_s),
        "chaos_1kill": _run_chaos(cfg, params, classes, scfg, trace(),
                                  twin, chaos_1kill, slo_s),
        "chaos_mixed": _run_chaos(cfg, params, classes, scfg, trace(),
                                  twin, chaos_mixed, slo_s),
    }
    base = out["fault_free"]["goodput_tok_s"]
    out["fault_free_goodput_tok_s"] = base
    out["kill_goodput_tok_s"] = out["chaos_1kill"]["goodput_tok_s"]
    out["kill_goodput_ratio"] = (
        out["kill_goodput_tok_s"] / max(base, 1e-9))
    out["mixed_goodput_ratio"] = (
        out["chaos_mixed"]["goodput_tok_s"] / max(base, 1e-9))
    out["tokens_lost_total"] = (
        out["fault_free"]["tokens_lost"]
        + out["chaos_1kill"]["tokens_lost"]
        + out["chaos_mixed"]["tokens_lost"])
    ft = out["chaos_1kill"].get("fault_tolerance", {})
    out["kill_recovery_latency_mean_s"] = ft.get(
        "recovery_latency_mean_s", 0.0)
    out["kill_recovery_latency_max_s"] = ft.get(
        "recovery_latency_max_s", 0.0)
    return out


def chaos_rows(result: Optional[dict] = None) -> tuple[dict, list]:
    """CSV rows for the harness (+ the computed result)."""
    res = result if result is not None else bench_chaos()
    rows = []
    for name in ("fault_free", "chaos_1kill", "chaos_mixed"):
        s = res[name]
        ft = s.get("fault_tolerance", {})
        rows.append((f"chaos/{name}", s["makespan_s"] * 1e6,
                     f"goodput={s['goodput_tok_s']:.1f} "
                     f"lost={s['tokens_lost']} "
                     f"kills={ft.get('kills_detected', 0)} "
                     f"replays={ft.get('replays', 0)} "
                     f"drains={ft.get('drains', 0)} "
                     f"retries={ft.get('transfer_retries', 0)} "
                     f"slo={s['slo_attainment']:.3f}"))
    rows.append(("chaos/kill_goodput_ratio", 0.0,
                 f"{res['kill_goodput_ratio']:.3f}x "
                 f"recovery_mean_s="
                 f"{res['kill_recovery_latency_mean_s']:.4f} "
                 f"lost_total={res['tokens_lost_total']}"))
    return res, rows
