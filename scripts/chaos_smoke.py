"""Chaos smoke for scripts/verify.sh: a 2-device cluster, one injected
device kill mid-decode, sampled decoding. The watchdog must detect the
kill, replay the lost requests on the survivor, and finish every stream
BIT-IDENTICAL to its failure-free twin with a gapless event stream —
zero lost tokens.

    PYTHONPATH=src python scripts/chaos_smoke.py
"""

import jax
import numpy as np

jax.config.update("jax_platform_name", "cpu")

from repro.cluster import (ClusterSpec, FaultEvent,                   # noqa: E402
                           FaultInjector, RecoveryConfig)
from repro.models import transformer as tf                            # noqa: E402
from repro.models.config import get_config, reduced                   # noqa: E402
from repro.perfmodel.devices import HBM_CLASS                         # noqa: E402
from repro.serving import (EngineSpec, PAMManagerConfig,              # noqa: E402
                           Request, ServingConfig)


def main():
    cfg = reduced(get_config("qwen3-0.6b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    pam = PAMManagerConfig(max_tokens=64, hot_capacity=4, warm_capacity=8,
                           compression=4, recency_window=2,
                           schedule_interval=2)
    scfg = ServingConfig(max_batch=4, max_len=64, pam=pam, block_size=8,
                         temperature=1.0, sample_seed=5)
    rng = np.random.default_rng(0)
    reqs = [Request(id=i, prompt=rng.integers(0, cfg.vocab, 16),
                    max_new_tokens=12, arrival=0.0) for i in range(4)]

    inj = FaultInjector([FaultEvent(tick=6, kind="kill", device="hbm1")])
    router = ClusterSpec.of(
        cfg, [HBM_CLASS, HBM_CLASS], serving=scfg,
        recovery=RecoveryConfig(
            heartbeat_timeout_s=0.01)).build(params, faults=inj)
    for i, req in enumerate(reqs):       # pin 2 per device
        router.submit_to(req, f"hbm{i % 2}")
    summary = router.run()

    assert summary["finished"] == 4 and summary["rejected"] == 0, summary
    ft = summary["fault_tolerance"]
    assert ft["kills_detected"] == 1, ft
    assert ft["replays"] >= 1, ft
    assert summary["devices"]["hbm1"]["state"] == "dead", summary

    # zero lost tokens: every stream equals a failure-free twin's, and
    # the client-visible event stream is gapless and duplicate-free
    twin = EngineSpec(model=cfg, serving=scfg).build(params)
    for req in reqs:
        twin.submit(Request(id=req.id, prompt=req.prompt,
                            max_new_tokens=req.max_new_tokens))
    twin.run()
    events = router.drain_events()
    for req in reqs:
        assert router.finished[req.id].outputs == \
            twin.requests[req.id].outputs, req.id
        mine = [e for e in events
                if e.request_id == req.id and not e.rejected]
        assert [e.index for e in mine] == list(range(len(mine))), req.id
        assert [e.token for e in mine] == \
            router.finished[req.id].outputs, req.id
        assert sum(e.done for e in mine) == 1 and mine[-1].done, req.id

    print(f"chaos smoke OK: kill detected in "
          f"{ft['recovery_latency_mean_s'] * 1e3:.1f} ms sim, "
          f"{ft['replays']} replays, {summary['finished']} requests, "
          f"streams exact, zero lost tokens")


if __name__ == "__main__":
    main()
