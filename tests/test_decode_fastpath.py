"""Fused on-device decode fast path: repeat-free GQA equivalence against
the seed ``jnp.repeat`` reference, donated single-dispatch engine steps,
token-stream invariance of the multi-step micro-loop, and prefill-length
bucketing."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest


from repro.core import importance as imp_mod
from repro.core import online_softmax as osm
from repro.core.pam_attention import PAMAttentionConfig, pam_attention_step
from repro.core.tiers import COLD, HOT, WARM
from repro.models import transformer as tf
from repro.models.attention import grouped_decode_attn
from repro.models.config import get_config, reduced
from repro.serving import (EngineSpec, PAMManagerConfig, Request,
                           ServingConfig)

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------- seed (jnp.repeat) oracles
def _repeat_decode_attn_ref(q, k_cache, v_cache, live):
    """The seed engine's masked decode attention, verbatim: repeat-expanded
    KV + per-query-head QK^T."""
    B, H, dh = q.shape
    Hkv = k_cache.shape[1]
    rep = H // Hkv
    scale = 1.0 / math.sqrt(dh)
    kh = jnp.repeat(k_cache, rep, axis=1)
    vh = jnp.repeat(v_cache, rep, axis=1)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) * scale
    s = jnp.where(live[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhs,bhsd->bhd", p, vh.astype(jnp.float32))
    n_live = jnp.sum(live, axis=-1, keepdims=True).astype(jnp.float32)
    return out.astype(q.dtype), jnp.mean(p, axis=1) * n_live


def _repeat_pam_step_ref(q, k, v, tier, valid, importance, cfg):
    """The seed ``pam_attention_step``: repeat-expanded KV, per-tier
    ``local_attention`` partials, tree merge, and a second QK^T for the
    importance mass."""
    S, H_kv, d = k.shape
    H = q.shape[0]
    rep = H // H_kv
    participate = valid
    if cfg.use_sparsity:
        n_valid = jnp.sum(valid)
        k_keep = jnp.maximum(n_valid // cfg.compression, 1)
        k_static = max(S // cfg.compression, 1)
        scores = jnp.where(valid, importance, -jnp.inf)
        _, idx = jax.lax.top_k(scores, k_static)
        sel = jnp.zeros((S,), bool).at[idx].set(True) & valid
        ranks = jnp.argsort(jnp.argsort(-scores))
        participate = sel & (ranks < k_keep)
    kh = jnp.repeat(k, rep, axis=1)
    vh = jnp.repeat(v, rep, axis=1)
    partials = []
    for t in (HOT, WARM, COLD)[: cfg.num_tiers]:
        mask = participate & (tier == t)
        partials.append(osm.local_attention(
            q, jnp.moveaxis(kh, 0, 1), jnp.moveaxis(vh, 0, 1),
            mask=mask[None, :]))
    stacked = osm.AttnPartial(o=jnp.stack([p.o for p in partials]),
                              m=jnp.stack([p.m for p in partials]),
                              l=jnp.stack([p.l for p in partials]))
    merged = osm.tree_merge(stacked)
    out = osm.finalize(merged, out_dtype=q.dtype)
    sc = 1.0 / jnp.sqrt(jnp.float32(d))
    s = jnp.einsum("hd,shd->hs", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) * sc
    s = jnp.where(participate[None, :], s, -jnp.inf)
    m_safe = jnp.where(jnp.isfinite(merged.m), merged.m, 0.0)
    p = jnp.exp(s - m_safe[:, None]) / jnp.maximum(merged.l, 1e-30)[:, None]
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    mass = imp_mod.step_score_from_attn_weights(p, head_axis=0)
    return out, mass, imp_mod.update_importance(importance, mass,
                                                lam=cfg.lam)


# --------------------------------------------- repeat-free GQA equivalence
@pytest.mark.parametrize("rep", [1, 4, 8])
@pytest.mark.parametrize("S", [7, 37])          # odd lengths on purpose
def test_grouped_decode_attn_matches_repeat_reference(rep, S):
    B, Hkv, d = 3, 2, 8
    H = Hkv * rep
    key = jax.random.PRNGKey(rep * 100 + S)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d))
    live = jax.random.uniform(jax.random.fold_in(key, 3), (B, S)) < 0.6
    live = live.at[:, 0].set(True)          # never fully masked
    out, mass = grouped_decode_attn(q, k, v, live)
    ref_out, ref_mass = _repeat_decode_attn_ref(q, k, v, live)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mass), np.asarray(ref_mass),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("rep", [1, 4, 8])
@pytest.mark.parametrize("S", [9, 41])          # odd lengths on purpose
@pytest.mark.parametrize("sparsity", [False, True])
def test_pam_attention_step_matches_repeat_reference(rep, S, sparsity):
    """The grouped-einsum ``pam_attention_step`` (scores computed once,
    reused across tier partials and the importance mass) is bitwise-close
    to the seed jnp.repeat formulation."""
    Hkv, d = 2, 8
    H = Hkv * rep
    key = jax.random.PRNGKey(7 * rep + S)
    q = jax.random.normal(jax.random.fold_in(key, 0), (H, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (S, Hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (S, Hkv, d))
    tier = jax.random.randint(jax.random.fold_in(key, 3), (S,), 0, 3)
    imp = jax.random.uniform(jax.random.fold_in(key, 4), (S,))
    valid = jnp.arange(S) < (S - 2)
    cfg = PAMAttentionConfig(use_sparsity=sparsity, compression=4)
    got = pam_attention_step(q, k, v, tier.astype(jnp.int32), valid, imp,
                             cfg)
    ref_out, ref_mass, ref_imp = _repeat_pam_step_ref(
        q, k, v, tier, valid, imp, cfg)
    np.testing.assert_allclose(np.asarray(got.out), np.asarray(ref_out),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got.step_scores),
                               np.asarray(ref_mass), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got.new_importance),
                               np.asarray(ref_imp), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- engine fast path
def _engine(pam=True, max_batch=3, max_len=64, micro_steps=1, seed=0,
            bucket=True):
    cfg = reduced(get_config("qwen3-0.6b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(seed))
    pam_cfg = PAMManagerConfig(
        max_tokens=max_len, hot_capacity=16, warm_capacity=24,
        compression=4, recency_window=4, schedule_interval=2) if pam else None
    scfg = ServingConfig(max_batch=max_batch, max_len=max_len, pam=pam_cfg,
                         micro_steps=micro_steps, bucket_prefill=bucket)
    return cfg, EngineSpec(model=cfg, serving=scfg).build(params)


def _submit_all(cfg, eng, n=5, seed=0, plen=6, max_new=8):
    rng = np.random.default_rng(seed)
    for i in range(n):
        eng.submit(Request(id=i, prompt=rng.integers(0, cfg.vocab, plen),
                           max_new_tokens=max_new))


def test_fastpath_tokens_identical_to_stepwise():
    """Greedy token streams are identical between the synchronous step()
    loop and the pipelined multi-step micro-loop (fusion/donation change
    dispatch structure, not math)."""
    cfg, eng_sync = _engine(micro_steps=1)
    _submit_all(cfg, eng_sync)
    eng_sync.run()

    cfg2, eng_fast = _engine(micro_steps=4)
    _submit_all(cfg2, eng_fast)
    summary = eng_fast.run()

    for rid in eng_sync.requests:
        assert (eng_sync.requests[rid].outputs
                == eng_fast.requests[rid].outputs), rid
    # micro-loop actually batched steps into fewer dispatches
    assert summary["decode_dispatches"] < summary["decode_device_steps"]


def test_fastpath_dense_identical_to_stepwise():
    cfg, eng_sync = _engine(pam=False, micro_steps=1)
    _submit_all(cfg, eng_sync, n=4)
    eng_sync.run()
    cfg2, eng_fast = _engine(pam=False, micro_steps=8)
    _submit_all(cfg2, eng_fast, n=4)
    eng_fast.run()
    for rid in eng_sync.requests:
        assert (eng_sync.requests[rid].outputs
                == eng_fast.requests[rid].outputs), rid


def test_single_dispatch_per_decode_step():
    """Steady-state decode makes exactly ONE jitted call per engine step:
    the fused (participation + decode + observe + sample) dispatch."""
    cfg, eng = _engine(max_batch=2, max_len=64)
    _submit_all(cfg, eng, n=2, max_new=8)

    calls = {"decode": 0, "prefill": 0, "admit": 0}
    fused_real = eng._get_micro(1)
    eng._micro_jits[1] = (
        lambda *a, **k: (calls.__setitem__("decode", calls["decode"] + 1),
                         fused_real(*a, **k))[1])
    admit_real = eng._admit_jit
    eng._admit_jit = (
        lambda *a, **k: (calls.__setitem__("admit", calls["admit"] + 1),
                         admit_real(*a, **k))[1])

    eng.step()                         # admission step: prefill + decode
    admit_calls = calls["admit"]
    assert calls["decode"] == 1
    for _ in range(4):                 # steady state: no admission left
        eng.step()
    assert calls["decode"] == 5
    assert calls["admit"] == admit_calls       # no extra dispatches
    assert eng.decode_dispatches == 5
    assert eng.decode_device_steps == 5


def test_cache_and_state_donated():
    """The fused step donates the KV cache and PAM state: the previous
    step's buffers are consumed in place, never copied."""
    cfg, eng = _engine(max_batch=2)
    _submit_all(cfg, eng, n=2)
    eng.step()
    k_buf = eng.cache.k
    imp_buf = eng.pam_state.importance
    tok_buf = eng.tokens_dev
    eng.step()
    assert k_buf.is_deleted()
    assert imp_buf.is_deleted()
    assert tok_buf.is_deleted()


def test_prefill_bucketing_single_compile_and_same_tokens():
    """Prompt lengths 5/6/7 share one pow-2 prefill bucket and produce the
    same tokens as exact-length prefill."""
    cfg, eng = _engine(max_batch=3, micro_steps=1, bucket=True)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, n) for n in (5, 6, 7)]
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p, max_new_tokens=6))
    eng.run()
    assert list(eng._prefill_jit) == [8]       # one bucket for all three

    cfg2, eng_exact = _engine(max_batch=3, micro_steps=1, bucket=False)
    for i, p in enumerate(prompts):
        eng_exact.submit(Request(id=i, prompt=p, max_new_tokens=6))
    eng_exact.run()
    assert len(eng_exact._prefill_jit) == 3    # one compile per length
    for rid in eng.requests:
        assert (eng.requests[rid].outputs
                == eng_exact.requests[rid].outputs), rid


def test_fastpath_midstream_admission():
    """Slots freed mid-run are refilled by waiting requests on the fast
    path too (continuous batching survives the micro-loop)."""
    cfg, eng = _engine(max_batch=2, micro_steps=4)
    rng = np.random.default_rng(1)
    eng.submit(Request(id=0, prompt=rng.integers(0, cfg.vocab, 4),
                       max_new_tokens=12))
    eng.submit(Request(id=1, prompt=rng.integers(0, cfg.vocab, 4),
                       max_new_tokens=3))
    eng.submit(Request(id=2, prompt=rng.integers(0, cfg.vocab, 4),
                       max_new_tokens=3))   # waits for a slot
    out = eng.run()
    assert out["finished"] == 3
    for rid, rs in eng.requests.items():
        assert len(rs.outputs) == rs.request.max_new_tokens, rid


def test_micro_loop_serves_eos_token_stream():
    """EOS detection is folded into the fused dispatch (slots that
    sample EOS freeze on device), so micro_steps > 1 now serves
    eos_token >= 0 traffic with streams identical to the synchronous
    step() loop — and stops early at the EOS."""
    cfg = reduced(get_config("qwen3-0.6b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, 6) for _ in range(3)]

    # probe run: pick an actually-emitted mid-stream token as EOS
    probe = EngineSpec(model=cfg, serving=ServingConfig(
        max_batch=3, max_len=64)).build(params)
    for i, p in enumerate(prompts):
        probe.submit(Request(id=i, prompt=p, max_new_tokens=12))
    probe.run()
    eos = probe.requests[0].outputs[4]

    outs = []
    for micro in (1, 4):
        eng = EngineSpec(model=cfg, serving=ServingConfig(
            max_batch=3, max_len=64, eos_token=int(eos),
            micro_steps=micro)).build(params)
        for i, p in enumerate(prompts):
            eng.submit(Request(id=i, prompt=p, max_new_tokens=12))
        eng.run()
        outs.append({rid: rs.outputs for rid, rs in eng.requests.items()})
    assert outs[0] == outs[1]
    assert all(rs.status == "done"
               for rs in eng.requests.values())
    # the EOS actually cut request 0 short on both paths
    assert outs[0][0][-1] == eos
    assert len(outs[0][0]) < 12
