"""Request-lifecycle tracing on the sim-clock, exported as Chrome
trace-event JSON (loadable at https://ui.perfetto.dev).

Event model
-----------
- **Request spans** are async events (``ph`` "b"/"e") keyed by request
  id: one track per request showing its lifecycle phases — ``queued``
  → ``prefill`` (chunked admissions; slice fills show on the device
  track) → ``decode`` → ``suspended`` → ``decode`` ... — with instant
  markers for ``migrate_out``/``migrate_in``, ``replay``, ``shed``,
  ``reject`` and ``finish``. Spans survive migration because the id,
  not the device, names the track.
- **Device events** are complete slices (``ph`` "X") on a per-device
  track: ``step`` (one per engine step, duration = the step's modeled
  or measured latency), ``admit``/``prefill_slice``/``import``, fault
  and watchdog markers.
- **Counter tracks** (``ph`` "C") carry occupancy timelines: pool
  occupancy and active slots per device, cluster queue depth per tick.

Timestamps are sim-clock seconds converted to integer microseconds.
The collector CLAMPS each track's timestamps monotone (device clocks
resync on migration; Perfetto rejects time travel inside a track), and
begin/end bookkeeping is idempotent per (id, phase) — a second ``b``
for an open span or an ``e`` with no open span is dropped — so every
exported span is balanced by construction. Both properties are pinned
by the schema-validation tests.

The ring is bounded (``capacity`` events, default 64k): old events
drop first and ``dropped`` counts them. When no collector is installed
every hook is a module-global load + ``None`` check — zero allocation
on the serving fast path.
"""

from __future__ import annotations

import collections
import contextlib
import json
from typing import Optional

REQUEST_CAT = "request"
_REQUEST_PID = 1
_DEVICE_PID0 = 10


class TraceCollector:
    """Bounded ring of Chrome trace events on the sim-clock."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be positive: {capacity}")
        self.capacity = capacity
        self.events: collections.deque[dict] = collections.deque(
            maxlen=capacity)
        self.dropped = 0
        self._pids: dict[str, int] = {}          # device name -> pid
        self._last_ts: dict[tuple, int] = {}     # track key -> last us
        self._open: dict[tuple, str] = {}        # (cat, id) -> open phase

    # ---------------------------------------------------------- low level
    def _push(self, ev: dict) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append(ev)

    def _ts(self, key: tuple, t: float) -> int:
        """Sim seconds -> integer us, clamped monotone per track."""
        us = int(round(t * 1e6))
        last = self._last_ts.get(key, 0)
        if us < last:
            us = last
        self._last_ts[key] = us
        return us

    def _pid(self, device: str) -> int:
        pid = self._pids.get(device)
        if pid is None:
            pid = _DEVICE_PID0 + len(self._pids)
            self._pids[device] = pid
        return pid

    # ------------------------------------------------------ request spans
    def begin(self, rid: int, phase: str, t: float, **args) -> None:
        """Open lifecycle phase ``phase`` for request ``rid`` (async
        span). Any phase already open for the request is closed first —
        lifecycle phases are sequential by definition, so this keeps
        every span balanced even across replay/suspension seams."""
        key = (REQUEST_CAT, rid)
        if key in self._open:
            if self._open[key] == phase:
                return                       # idempotent re-begin
            self.end(rid, self._open[key], t)
        ts = self._ts(key, t)
        self._open[key] = phase
        self._push({"ph": "b", "cat": REQUEST_CAT, "id": rid,
                    "name": phase, "pid": _REQUEST_PID, "tid": 0,
                    "ts": ts, "args": args or {}})

    def end(self, rid: int, phase: str, t: float, **args) -> None:
        key = (REQUEST_CAT, rid)
        if self._open.get(key) != phase:
            return                           # never emit unbalanced "e"
        ts = self._ts(key, t)
        del self._open[key]
        self._push({"ph": "e", "cat": REQUEST_CAT, "id": rid,
                    "name": phase, "pid": _REQUEST_PID, "tid": 0,
                    "ts": ts, "args": args or {}})

    def mark(self, rid: int, name: str, t: float, **args) -> None:
        """Instant lifecycle marker on the request's track."""
        key = (REQUEST_CAT, rid)
        self._push({"ph": "n", "cat": REQUEST_CAT, "id": rid,
                    "name": name, "pid": _REQUEST_PID, "tid": 0,
                    "ts": self._ts(key, t), "args": args or {}})

    def open_phase(self, rid: int) -> Optional[str]:
        return self._open.get((REQUEST_CAT, rid))

    # ------------------------------------------------------ device events
    def slice(self, device: str, name: str, t0: float, dur: float,
              **args) -> None:
        """Complete slice (``ph`` "X") on the device track."""
        pid = self._pid(device)
        key = ("dev", device)
        ts = self._ts(key, t0)
        # keep the track monotone through the slice's end too
        self._last_ts[key] = max(self._last_ts[key],
                                 ts + int(round(max(dur, 0.0) * 1e6)))
        self._push({"ph": "X", "cat": "device", "name": name,
                    "pid": pid, "tid": 0, "ts": ts,
                    "dur": int(round(max(dur, 0.0) * 1e6)),
                    "args": args or {}})

    def instant(self, device: str, name: str, t: float, **args) -> None:
        self._push({"ph": "i", "cat": "device", "name": name, "s": "t",
                    "pid": self._pid(device), "tid": 0,
                    "ts": self._ts(("dev", device), t),
                    "args": args or {}})

    def counter(self, device: str, name: str, t: float, **values
                ) -> None:
        """Counter sample (``ph`` "C") — occupancy/queue timelines."""
        self._push({"ph": "C", "cat": "device", "name": name,
                    "pid": self._pid(device), "tid": 0,
                    "ts": self._ts(("ctr", device, name), t),
                    "args": {k: float(v) for k, v in values.items()}})

    # ------------------------------------------------------------- export
    def last_time(self) -> float:
        """Latest timestamp seen on any track, in sim seconds."""
        return max(self._last_ts.values(), default=0) / 1e6

    def close_open(self, t: Optional[float] = None) -> None:
        """Close every still-open request span at time ``t`` (default:
        the latest timestamp on any track — end of a run that left work
        in flight) so the export stays balanced."""
        if t is None:
            t = self.last_time()
        for (_, rid), phase in list(self._open.items()):
            self.end(rid, phase, t)

    def export(self) -> dict:
        """Chrome trace-event JSON object (``traceEvents`` +
        process-name metadata). Does NOT implicitly close open spans —
        call ``close_open`` first if the run was abandoned mid-flight.
        """
        meta = [{"ph": "M", "name": "process_name", "pid": _REQUEST_PID,
                 "tid": 0, "args": {"name": "requests"}}]
        for device, pid in sorted(self._pids.items(),
                                  key=lambda kv: kv[1]):
            meta.append({"ph": "M", "name": "process_name", "pid": pid,
                         "tid": 0, "args": {"name": device}})
        return {"traceEvents": meta + list(self.events),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped,
                              "clock": "sim_seconds_as_us"}}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f)


# --------------------------------------------------- process-wide default
COLLECTOR: Optional[TraceCollector] = None


def active() -> Optional[TraceCollector]:
    """The installed collector, or None (tracing off). Hooks read the
    module global directly on hot paths; this accessor is for tests
    and export code."""
    return COLLECTOR


def install(coll: Optional[TraceCollector] = None) -> TraceCollector:
    """Install ``coll`` (default: a fresh collector) process-wide and
    return it. Unlike metrics, trace hooks look the collector up per
    event, so installing mid-run starts recording immediately."""
    global COLLECTOR
    COLLECTOR = coll if coll is not None else TraceCollector()
    return COLLECTOR


def uninstall() -> None:
    global COLLECTOR
    COLLECTOR = None


@contextlib.contextmanager
def use(coll: Optional[TraceCollector] = None):
    """Scoped ``install`` — restores the previous collector on exit."""
    global COLLECTOR
    prev = COLLECTOR
    COLLECTOR = coll if coll is not None else TraceCollector()
    try:
        yield COLLECTOR
    finally:
        COLLECTOR = prev


# ------------------------------------------------------ schema validation
def validate(trace: dict) -> dict:
    """Validate an exported trace against the PR 9 schema contract:
    every async request span balanced ("b" and "e" match pairwise per
    request id, phases properly sequenced), timestamps monotone per
    track, durations nonnegative, all events JSON-plain. Returns
    summary stats; raises ``ValueError`` on violation. Used by the
    trace-export tests and ``scripts/trace_smoke.py``."""
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("traceEvents missing or not a list")
    open_spans: dict = {}
    last_ts: dict = {}
    counts = {"spans": 0, "slices": 0, "instants": 0, "counters": 0}
    per_request: dict = collections.defaultdict(set)
    devices = set()
    for ev in events:
        ph = ev.get("ph")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, int) or ts < 0:
            raise ValueError(f"non-integer/negative ts: {ev}")
        if ph in ("b", "e", "n"):
            key = ("req", ev["id"])
            if ts < last_ts.get(key, 0):
                raise ValueError(f"time travel on request track: {ev}")
            last_ts[key] = ts
            if ph == "b":
                if key in open_spans:
                    raise ValueError(f"nested request phase: {ev}")
                open_spans[key] = ev["name"]
            elif ph == "e":
                if open_spans.get(key) != ev["name"]:
                    raise ValueError(f"unbalanced span end: {ev}")
                del open_spans[key]
                counts["spans"] += 1
                per_request[ev["id"]].add(ev["name"])
            else:
                counts["instants"] += 1
                per_request[ev["id"]].add(ev["name"])
        elif ph == "X":
            key = ("pid", ev["pid"])
            if ts < last_ts.get(key, 0):
                raise ValueError(f"time travel on device track: {ev}")
            if not isinstance(ev.get("dur"), int) or ev["dur"] < 0:
                raise ValueError(f"bad slice duration: {ev}")
            last_ts[key] = ts + ev["dur"]
            counts["slices"] += 1
            devices.add(ev["pid"])
        elif ph == "i":
            counts["instants"] += 1
            devices.add(ev["pid"])
        elif ph == "C":
            counts["counters"] += 1
        else:
            raise ValueError(f"unknown event phase {ph!r}: {ev}")
    if open_spans:
        raise ValueError(f"unclosed request spans: {open_spans}")
    json.dumps(events)       # must be JSON-plain end to end
    counts["requests"] = len(per_request)
    counts["devices"] = len(devices)
    counts["phases_per_request"] = {
        str(rid): sorted(names) for rid, names in per_request.items()}
    return counts
