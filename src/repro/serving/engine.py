"""The PAM serving engine (paper §4): request pool, continuous batching
with prefill priority, PAM-managed decode loop, SLO accounting.

Control flow is real (host Python over jit'd device steps, like vLLM's
scheduler over CUDA graphs); *hardware timing* is injectable — pass a
``latency_model`` (see ``repro.perfmodel``) to account each step at the
modeled speed of a PAM / L-PIM / vLLM-offloading system, which is exactly
the paper's simulator methodology. Without one, wall-clock is used.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.serving.pam_manager import (PAMManager, PAMManagerConfig,
                                       PAMState, init_pam_state,
                                       make_masked_decode_attn,
                                       make_masked_latent_attn)

WAITING, RUNNING, DONE = "waiting", "running", "done"


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int
    arrival: float = 0.0


@dataclasses.dataclass
class RequestState:
    request: Request
    status: str = WAITING
    slot: int = -1
    outputs: list[int] = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: list[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    max_batch: int = 4
    max_len: int = 256
    eos_token: int = -1                # -1: run to max_new_tokens
    pam: Optional[PAMManagerConfig] = None   # None -> dense baseline


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServingConfig,
                 latency_model: Optional[Callable[[dict], float]] = None):
        assert cfg.has_decode, f"{cfg.name} is encoder-only"
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.latency_model = latency_model
        self.clock = 0.0                       # simulated seconds

        B, Smax = scfg.max_batch, scfg.max_len
        self.cache = tf.init_decode_cache(cfg, B, Smax)
        self.pam_cfg = scfg.pam
        self.mgr = PAMManager(scfg.pam) if scfg.pam else None
        self.pam_state = init_pam_state(B, Smax)

        self.requests: dict[int, RequestState] = {}
        self.waiting: collections.deque[int] = collections.deque()
        self.slots: list[Optional[int]] = [None] * B
        self.last_token = np.zeros((B,), np.int32)
        self.steps = 0

        self._decode_jit = self._build_decode()
        self._prefill_jit: dict[int, Any] = {}   # keyed by prompt length

    # ------------------------------------------------------------ builders
    def _build_decode(self):
        cfg = self.cfg

        @jax.jit
        def step(params, tokens, cache, participate, active):
            d_fn = make_masked_decode_attn(participate)
            l_fn = make_masked_latent_attn(participate)
            old_lens = cache.lengths
            logits, cache, scores = tf.decode_step(
                cfg, params, tokens, cache, decode_attn_fn=d_fn,
                latent_attn_fn=l_fn)
            # inactive slots: freeze their lengths
            cache = cache._replace(
                lengths=jnp.where(active, cache.lengths, old_lens))
            return logits, cache, scores

        return step

    def _prefill_for_len(self, s_len: int):
        if s_len not in self._prefill_jit:
            cfg, smax = self.cfg, self.scfg.max_len

            @jax.jit
            def pre(params, tokens):
                return tf.prefill(cfg, params, tokens, smax)

            self._prefill_jit[s_len] = pre
        return self._prefill_jit[s_len]

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request) -> None:
        self.requests[req.id] = RequestState(request=req)
        self.waiting.append(req.id)

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _scatter_cache(self, sub: tf.DecodeCache, slot: int) -> None:
        def put(full, one):
            if full.ndim == 0 or full.size == 0:
                return full
            if full.ndim == 1:                     # lengths (B,)
                return full.at[slot].set(one[0])
            return full.at[:, slot].set(one[:, 0])  # (L, B, ...)
        self.cache = jax.tree.map(put, self.cache, sub)

    def _admit(self) -> int:
        """Prefill-priority admission (paper §4.2.3). Returns prompt tokens
        processed (for the latency model)."""
        admitted_tokens = 0
        free = self._free_slots()
        while self.waiting and free:
            rid = self.waiting.popleft()
            rs = self.requests[rid]
            prompt = np.asarray(rs.request.prompt, np.int32)
            s_len = len(prompt)
            if s_len + rs.request.max_new_tokens > self.scfg.max_len:
                raise ValueError(f"request {rid} exceeds max_len")
            slot = free.pop(0)
            pre = self._prefill_for_len(s_len)
            logits, sub = pre(self.params, jnp.asarray(prompt[None]))
            self._scatter_cache(sub, slot)
            first = int(jnp.argmax(logits[0]))
            rs.status, rs.slot = RUNNING, slot
            rs.outputs.append(first)
            rs.first_token_time = None     # stamped after latency charge
            self.slots[slot] = rid
            self.last_token[slot] = first
            if self.mgr:
                self.pam_state = self.mgr.place_prefill(
                    self.pam_state, jnp.int32(slot), jnp.int32(s_len))
            admitted_tokens += s_len
        return admitted_tokens

    # ------------------------------------------------------------ stepping
    def step(self) -> dict[str, Any]:
        """One engine iteration: admission (prefill) + one decode step for
        all running sequences. Returns step stats."""
        t0 = time.perf_counter()
        prefill_tokens = self._admit()

        active_np = np.array([s is not None for s in self.slots])
        stats: dict[str, Any] = {"prefill_tokens": prefill_tokens,
                                 "active": int(active_np.sum()),
                                 "tier_reads": np.zeros(3, np.int64),
                                 "moved_tokens": 0}
        if active_np.any():
            # post-append lengths: the step writes the new token at
            # position ``lengths`` before attending, so it must participate
            lengths = self.cache.lengths + jnp.asarray(active_np, jnp.int32)
            if self.mgr:
                participate = self.mgr.participation(self.pam_state, lengths)
            else:
                Smax = self.scfg.max_len
                participate = (jnp.arange(Smax)[None, :]
                               < lengths[:, None])
            active = jnp.asarray(active_np)
            tokens = jnp.asarray(self.last_token)
            logits, self.cache, scores = self._decode_jit(
                self.params, tokens, self.cache, participate, active)

            if self.mgr:
                stats["tier_reads"] = np.asarray(self.mgr.tier_read_counts(
                    self.pam_state, participate & active[:, None]))
                stats["hit_rate"] = float(self.mgr.hit_rate(
                    self.pam_state, participate))
                before_moved = int(self.pam_state.moved_tokens)
                if scores is None:     # attention-free: recency-only scores
                    Smax = self.scfg.max_len
                    scores = (jnp.arange(Smax)[None, :]
                              == (self.cache.lengths - 1)[:, None]
                              ).astype(jnp.float32)
                self.pam_state = self.mgr.observe(
                    self.pam_state, scores, self.cache.lengths, participate)
                stats["moved_tokens"] = \
                    int(self.pam_state.moved_tokens) - before_moved

            nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
            self._emit_tokens(nxt, active_np)

        # --- timing: modeled or wall-clock --------------------------------
        stats["batch_lengths"] = np.asarray(self.cache.lengths)
        if self.latency_model is not None:
            dt = float(self.latency_model(stats))
        else:
            dt = time.perf_counter() - t0
        self.clock += dt
        stats["step_time"] = dt
        self._stamp_times()
        self.steps += 1
        return stats

    def _emit_tokens(self, nxt: np.ndarray, active: np.ndarray) -> None:
        for slot, rid in enumerate(self.slots):
            if rid is None or not active[slot]:
                continue
            rs = self.requests[rid]
            tok = int(nxt[slot])
            rs.outputs.append(tok)
            self.last_token[slot] = tok
            done = (len(rs.outputs) >= rs.request.max_new_tokens
                    or tok == self.scfg.eos_token)
            if done:
                rs.status = DONE
                rs.finish_time = None  # stamped in _stamp_times
                self.slots[slot] = None

    def _stamp_times(self) -> None:
        for rs in self.requests.values():
            if rs.status in (RUNNING, DONE):
                if rs.first_token_time is None:
                    rs.first_token_time = self.clock
                if len(rs.token_times) < len(rs.outputs):
                    rs.token_times += [self.clock] * (
                        len(rs.outputs) - len(rs.token_times))
                if rs.status == DONE and rs.finish_time is None:
                    rs.finish_time = self.clock

    def run(self, max_steps: int = 10_000) -> dict[str, Any]:
        """Run until all submitted requests finish. Returns summary."""
        for _ in range(max_steps):
            if not self.waiting and all(s is None for s in self.slots):
                break
            self.step()
        return self.summary()

    # ------------------------------------------------------------ metrics
    def summary(self) -> dict[str, Any]:
        done = [r for r in self.requests.values() if r.status == DONE]
        total_tokens = sum(len(r.outputs) for r in done)
        tpots = []
        for r in done:
            if len(r.token_times) > 1:
                gaps = np.diff(r.token_times)
                tpots.extend(gaps.tolist())
        return {
            "finished": len(done),
            "total_tokens": total_tokens,
            "sim_time_s": self.clock,
            "throughput_tok_s": total_tokens / max(self.clock, 1e-9),
            "p50_tpot_s": float(np.percentile(tpots, 50)) if tpots else 0.0,
            "p99_tpot_s": float(np.percentile(tpots, 99)) if tpots else 0.0,
            "steps": self.steps,
        }

    def slo_attainment(self, slo_s: float) -> float:
        """Fraction of decode-token gaps within the SLO (paper Fig. 9)."""
        gaps = []
        for r in self.requests.values():
            if len(r.token_times) > 1:
                gaps.extend(np.diff(r.token_times).tolist())
        if not gaps:
            return 1.0
        return float(np.mean(np.asarray(gaps) <= slo_s))
