"""Telemetry overhead benchmark (PR 9): REAL wall-clock decode
throughput of the fused fast path with collectors OFF vs ON (metrics
registry + trace collector both active, recording every step).

The hooks are host-side counter increments behind a single enabled
check, so the two runs must land in the same performance class: the
acceptance floor (``scripts/check_bench.py``) is telemetry-on decode
tok/s >= 0.95x telemetry-off, recorded in ``BENCH_pr9.json``. Token
streams are asserted identical — telemetry observes, never perturbs.
"""

from __future__ import annotations

import time
from typing import Optional

import numpy as np


def bench_obs_overhead(micro_steps: int = 8, repeats: int = 3) -> dict:
    """Best-of-``repeats`` wall-clock decode run per telemetry mode
    (same engine config as ``engine_bench.bench_decode_wallclock``)."""
    import jax
    from repro.models import transformer as tf
    from repro.models.config import get_config, reduced
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    from repro.serving import (EngineSpec, PAMManagerConfig, Request,
                               ServingConfig)

    cfg = reduced(get_config("pam-llama-7b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    pam = PAMManagerConfig(max_tokens=96, hot_capacity=16,
                           warm_capacity=32, compression=4,
                           recency_window=4, schedule_interval=2)

    def one_run() -> tuple[float, dict, dict]:
        rng = np.random.default_rng(0)
        eng = EngineSpec(model=cfg, serving=ServingConfig(
            max_batch=4, max_len=96, pam=pam,
            micro_steps=micro_steps)).build(params)
        for i in range(8):
            eng.submit(Request(id=i,
                               prompt=rng.integers(0, cfg.vocab, 24),
                               max_new_tokens=16))
        t0 = time.perf_counter()
        summary = eng.run()
        wall = time.perf_counter() - t0
        streams = {rid: rs.outputs for rid, rs in eng.requests.items()}
        return wall, summary, streams

    def measure(telemetry: bool) -> tuple[dict, dict]:
        best: Optional[dict] = None
        streams: dict = {}
        for _ in range(repeats):
            if telemetry:
                with obs_metrics.use(), obs_trace.use() as tr:
                    wall, summary, streams = one_run()
                    extra = {"trace_events": len(tr.events),
                             "trace_dropped": tr.dropped}
            else:
                wall, summary, streams = one_run()
                extra = {}
            point = {"wall_s": wall,
                     "decode_tok_s": summary["total_tokens"] / wall,
                     "total_tokens": summary["total_tokens"], **extra}
            if best is None or point["wall_s"] < best["wall_s"]:
                best = point
        return best, streams

    one_run()                                  # warm the jit caches
    disabled, streams_off = measure(telemetry=False)
    enabled, streams_on = measure(telemetry=True)
    assert streams_on == streams_off, \
        "telemetry changed the token streams"
    return {
        "config": {"model": cfg.name, "micro_steps": micro_steps,
                   "repeats": repeats, "n_requests": 8,
                   "prompt_len": 24, "max_new_tokens": 16},
        "disabled": disabled,
        "enabled": enabled,
        "overhead_ratio": (enabled["decode_tok_s"]
                           / disabled["decode_tok_s"]),
        "streams_identical": True,
    }


def obs_rows(result: Optional[dict] = None) -> tuple[dict, list]:
    """CSV rows for the harness (+ the computed result)."""
    res = result if result is not None else bench_obs_overhead()
    ratio = res["overhead_ratio"]
    rows = [
        ("obs/telemetry_off", res["disabled"]["wall_s"] * 1e6,
         f"tok_s={res['disabled']['decode_tok_s']:.0f}"),
        ("obs/telemetry_on", res["enabled"]["wall_s"] * 1e6,
         f"tok_s={res['enabled']['decode_tok_s']:.0f} "
         f"events={res['enabled']['trace_events']}"),
        ("obs/overhead_ratio", 0.0,
         f"{ratio:.3f}x (floor 0.95) streams_identical="
         f"{res['streams_identical']}"),
    ]
    return res, rows
