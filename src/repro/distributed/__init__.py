"""Distribution: sharding rules (DP/TP/SP/EP), distributed PAMattention,
pipeline parallelism, elastic scaling, fault tolerance."""
