"""Cluster smoke for scripts/verify.sh: two heterogeneous device
classes, 8 requests, must perform >= 1 balancer migration and keep
migrated token streams identical to unmigrated twins.

    PYTHONPATH=src python scripts/cluster_smoke.py
"""

import jax
import numpy as np

jax.config.update("jax_platform_name", "cpu")

from repro.cluster import BalancerConfig, ClusterSpec, KVBalancer   # noqa: E402
from repro.models import transformer as tf                           # noqa: E402
from repro.models.config import get_config, reduced                  # noqa: E402
from repro.perfmodel.devices import CXL_CLASS, HBM_CLASS             # noqa: E402
from repro.serving import (EngineSpec, PAMManagerConfig,            # noqa: E402
                           Request, ServingConfig)


def main():
    cfg = reduced(get_config("qwen3-0.6b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    pam = PAMManagerConfig(max_tokens=64, hot_capacity=4, warm_capacity=8,
                           compression=4, recency_window=2,
                           schedule_interval=2)
    scfg = ServingConfig(max_batch=4, max_len=64, pam=pam, block_size=8)
    rng = np.random.default_rng(0)
    reqs = [Request(id=i, prompt=rng.integers(0, cfg.vocab, 16),
                    max_new_tokens=12, arrival=0.0) for i in range(8)]

    router = ClusterSpec.of(
        cfg, [HBM_CLASS, CXL_CLASS], serving=scfg).build(
        params,
        balancer=KVBalancer(BalancerConfig(rebalance_interval=2,
                                           hysteresis=1.1,
                                           cooldown_ticks=4,
                                           min_remaining=2)))
    # load the SLOW device directly so the balancer has work to do
    for req in reqs[:4]:
        router.submit_to(req, "cxl0")
    for req in reqs[4:]:
        router.submit(req)
    summary = router.run()

    assert summary["finished"] == 8, summary
    assert summary["balancer_migrations"] >= 1, \
        f"no migrations: {summary['balancer_migrations']}"

    # exactness: every stream equals an unmigrated twin's
    twin = EngineSpec(model=cfg, serving=scfg).build(params)
    for req in reqs:
        twin.submit(Request(id=req.id, prompt=req.prompt,
                            max_new_tokens=req.max_new_tokens))
    twin.run()
    for rid, rs in router.finished.items():
        assert rs.outputs == twin.requests[rid].outputs, rid

    moved = [d for d, v in summary["devices"].items()
             if v["migrations_in"] or v["migrations_out"]]
    print(f"cluster smoke OK: {summary['finished']} requests, "
          f"{summary['balancer_migrations']} migrations across {moved}, "
          f"{summary['throughput_tok_s']:.0f} tok/s aggregate, "
          f"streams exact")


if __name__ == "__main__":
    main()
