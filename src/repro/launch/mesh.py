"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required because the dry-run
launcher must set XLA_FLAGS before any jax initialization.

Single pod : (16, 16)      axes (data, model)   = 256 chips (v5e pod)
Multi-pod  : (2, 16, 16)   axes (pod, data, model) = 512 chips; the "pod"
axis composes with "data" for data parallelism and is the fault-isolation /
gradient-compression boundary (cross-pod links are the slow DCN/ICI hops).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_from_devices(devices, model_parallel: int
                           ) -> jax.sharding.Mesh:
    """Elastic-scaling path: build the best (data, model) mesh from an
    explicit device list (e.g. survivors after a failure)."""
    n = len(devices)
    while n % model_parallel and model_parallel > 1:
        model_parallel //= 2
    data = n // model_parallel
    import numpy as np
    arr = np.asarray(devices)[: data * model_parallel].reshape(
        data, model_parallel)
    return jax.sharding.Mesh(arr, ("data", "model"))


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The data-parallel axes of a production mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh: jax.sharding.Mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
