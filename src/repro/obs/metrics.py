"""Process-wide metrics registry: labeled Counters, Gauges and
fixed-log-bucket Histograms with structured ``snapshot()`` export and
Prometheus-style text exposition.

Design constraints (PR 9):

- **Zero allocation when disabled.** The default installed registry is
  disabled; every mutator (``inc``/``set``/``observe``) is a single
  attribute load + boolean check before returning. Instrument objects
  themselves are allocated once, at engine/router/server construction.
- **Deterministic.** All state is plain Python ints/floats updated from
  host-side values the serving stack already computes (sim-clock
  latencies, counter readbacks). Two runs of the same seeded trace with
  modeled latency produce byte-identical snapshots — pinned by
  ``tests/test_obs.py``.
- **Fixed buckets.** Histograms use immutable log-spaced bucket bounds
  chosen at creation (default: 0, then 1e-6 .. 1e2 seconds at 32
  buckets per decade), so observation cost is one bisect + one int
  increment and snapshots from different runs/devices are mergeable.
  Percentile estimates interpolate geometrically inside a bucket and
  clamp to the observed min/max.

Registration is idempotent per (name, type): a second engine asking for
``pam_engine_steps_total`` gets the same instrument, and labeled
children (``counter.labels(device="hbm0")``) are cached per label
value. The canonical metric-name table lives in
``docs/ARCHITECTURE.md`` (observability section).
"""

from __future__ import annotations

import bisect
import contextlib
import math
import threading
from typing import Iterator, Optional


def log_buckets(lo: float = 1e-6, hi: float = 1e2,
                per_decade: int = 32) -> tuple[float, ...]:
    """Log-spaced histogram bucket upper bounds, prefixed with an exact
    0.0 bucket (sim-clock gaps clamp at zero across migration seams, so
    zero is a real observed value, not an error)."""
    if not lo > 0 or not hi > lo or per_decade < 1:
        raise ValueError(f"bad bucket spec lo={lo} hi={hi}/{per_decade}")
    n = int(round(math.log10(hi / lo) * per_decade))
    bounds = [0.0]
    bounds += [lo * 10 ** (i / per_decade) for i in range(n + 1)]
    return tuple(bounds)


LATENCY_BUCKETS = log_buckets()                  # seconds: 0, 1e-6..1e2
BYTES_BUCKETS = log_buckets(1.0, 1e12, 4)        # bytes: 0, 1..1e12
TOKENS_BUCKETS = log_buckets(1.0, 1e6, 8)        # counts: 0, 1..1e6


class _Instrument:
    """Shared parent for the three metric types: holds the registry
    reference (for the enabled check), the name/help text and the
    labeled-children cache."""

    kind = "untyped"

    def __init__(self, reg: "MetricsRegistry", name: str, help_: str,
                 labelnames: tuple[str, ...]):
        self._reg = reg
        self.name = name
        self.help = help_
        self.labelnames = labelnames
        self._children: dict[tuple, "_Instrument"] = {}

    def labels(self, **kv) -> "_Instrument":
        """The child instrument for one label assignment (cached); the
        child mutates independently and renders as
        ``name{k="v",...}``."""
        if tuple(sorted(kv)) != tuple(sorted(self.labelnames)):
            raise ValueError(f"{self.name} wants labels "
                             f"{self.labelnames}, got {tuple(kv)}")
        key = tuple(kv[k] for k in self.labelnames)
        child = self._children.get(key)
        if child is None:
            child = self._make_child()
            self._children[key] = child
        return child

    def _make_child(self) -> "_Instrument":
        raise NotImplementedError

    def _series(self) -> Iterator[tuple[tuple, "_Instrument"]]:
        """(label values, leaf instrument) pairs — the unlabeled parent
        itself when it has no labelnames."""
        if self.labelnames:
            yield from sorted(self._children.items())
        else:
            yield (), self


class Counter(_Instrument):
    """Monotonically nondecreasing count."""

    kind = "counter"

    def __init__(self, reg, name, help_="", labelnames=()):
        super().__init__(reg, name, help_, labelnames)
        self.value = 0.0

    def _make_child(self) -> "Counter":
        return Counter(self._reg, self.name, self.help)

    def inc(self, v: float = 1.0) -> None:
        if not self._reg.enabled:
            return
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self.value += v


class Gauge(_Instrument):
    """Point-in-time value (occupancy, queue depth, clock)."""

    kind = "gauge"

    def __init__(self, reg, name, help_="", labelnames=()):
        super().__init__(reg, name, help_, labelnames)
        self.value = 0.0

    def _make_child(self) -> "Gauge":
        return Gauge(self._reg, self.name, self.help)

    def set(self, v: float) -> None:
        if not self._reg.enabled:
            return
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        if not self._reg.enabled:
            return
        self.value += v


class Histogram(_Instrument):
    """Fixed-bucket log histogram with quantile estimation.

    ``observe`` is bisect + increment; ``percentile`` walks the
    cumulative counts and interpolates geometrically inside the hit
    bucket, clamped to the exact observed [min, max] so tight
    distributions don't get smeared to a whole bucket's width.

    Standalone use (no registry) is supported for offline scoring
    (``repro.frontend.loadgen.score``): ``Histogram.standalone()``."""

    kind = "histogram"

    def __init__(self, reg, name, help_="", labelnames=(),
                 buckets: tuple[float, ...] = LATENCY_BUCKETS):
        super().__init__(reg, name, help_, labelnames)
        if list(buckets) != sorted(set(buckets)):
            raise ValueError(f"{name}: buckets must strictly increase")
        self.bounds = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.bounds) + 1)   # +inf overflow
        self.total = 0
        self.sum = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    @classmethod
    def standalone(cls, name: str = "h",
                   buckets: tuple[float, ...] = LATENCY_BUCKETS
                   ) -> "Histogram":
        return cls(_ALWAYS_ON, name, buckets=buckets)

    def _make_child(self) -> "Histogram":
        return Histogram(self._reg, self.name, self.help,
                         buckets=self.bounds)

    def observe(self, v: float) -> None:
        if not self._reg.enabled:
            return
        v = float(v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.total += 1
        self.sum += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    @property
    def count(self) -> int:
        return self.total

    def percentile(self, q: float) -> float:
        """Estimated ``q``-th percentile (0..100); 0.0 when empty."""
        if self.total == 0:
            return 0.0
        rank = q / 100.0 * self.total
        cum = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            prev, cum = cum, cum + c
            if cum >= rank:
                frac = min(max((rank - prev) / c, 0.0), 1.0)
                est = self._interp(i, frac)
                return float(min(max(est, self.vmin), self.vmax))
        return float(self.vmax)

    def _interp(self, i: int, frac: float) -> float:
        if i >= len(self.bounds):            # overflow bucket
            return self.vmax
        hi = self.bounds[i]
        if i == 0 or hi <= 0.0:
            return hi                        # the exact-zero bucket
        lo = self.bounds[i - 1]
        if lo <= 0.0:                        # first positive bucket
            lo = hi / 10.0
        return lo * (hi / lo) ** frac        # geometric interpolation

    def summary(self) -> dict:
        """{"p50", "p95", "p99", "n", ...}: the NaN-safe scorecard shape
        (``n == 0`` marks an empty histogram explicitly — zeros then
        mean "no samples", never "zero latency")."""
        if self.total == 0:
            return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "n": 0,
                    "mean": 0.0, "max": 0.0}
        return {"p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99), "n": self.total,
                "mean": self.sum / self.total, "max": self.vmax}


class MetricsRegistry:
    """Instrument namespace + enable switch. ``install()`` makes one
    the process default; engines/routers/servers bind their instruments
    against the default at construction."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._instruments: dict[str, _Instrument] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------- registration
    def _get(self, cls, name: str, help_: str, labelnames, **kw):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(self, name, help_, tuple(labelnames), **kw)
                self._instruments[name] = inst
            elif type(inst) is not cls:
                raise ValueError(f"{name} already registered as "
                                 f"{inst.kind}")
            return inst

    def counter(self, name: str, help_: str = "",
                labelnames=()) -> Counter:
        return self._get(Counter, name, help_, labelnames)

    def gauge(self, name: str, help_: str = "", labelnames=()) -> Gauge:
        return self._get(Gauge, name, help_, labelnames)

    def histogram(self, name: str, help_: str = "", labelnames=(),
                  buckets: tuple[float, ...] = LATENCY_BUCKETS
                  ) -> Histogram:
        return self._get(Histogram, name, help_, labelnames,
                         buckets=buckets)

    # ------------------------------------------------------------- export
    @staticmethod
    def _series_key(name: str, labelnames, values) -> str:
        if not labelnames:
            return name
        inner = ",".join(f'{k}="{v}"'
                         for k, v in zip(labelnames, values))
        return f"{name}{{{inner}}}"

    def snapshot(self) -> dict:
        """Structured, JSON-serializable view of every series:
        counters/gauges as ``{series: value}``, histograms as
        ``{series: {count, sum, p50, p95, p99, max}}``. Deterministic
        ordering (sorted by series key)."""
        counters: dict[str, float] = {}
        gauges: dict[str, float] = {}
        hists: dict[str, dict] = {}
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            for values, leaf in inst._series():
                key = self._series_key(name, inst.labelnames, values)
                if inst.kind == "counter":
                    counters[key] = leaf.value
                elif inst.kind == "gauge":
                    gauges[key] = leaf.value
                else:
                    s = leaf.summary()
                    hists[key] = {"count": leaf.total, "sum": leaf.sum,
                                  "p50": s["p50"], "p95": s["p95"],
                                  "p99": s["p99"], "max": s["max"]}
        return {"counters": counters, "gauges": gauges,
                "histograms": hists}

    def render(self) -> str:
        """Prometheus text exposition (counters/gauges as-is,
        histograms as cumulative ``_bucket{le=...}`` + ``_sum`` +
        ``_count`` series)."""
        lines: list[str] = []
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            if inst.help:
                lines.append(f"# HELP {name} {inst.help}")
            lines.append(f"# TYPE {name} {inst.kind}")
            for values, leaf in inst._series():
                pairs = list(zip(inst.labelnames, values))
                if inst.kind in ("counter", "gauge"):
                    lines.append(f"{self._series_key(name, inst.labelnames, values)}"
                                 f" {_fmt(leaf.value)}")
                    continue
                cum = 0
                for bound, c in zip(leaf.bounds, leaf.counts):
                    cum += c
                    lab = pairs + [("le", _fmt(bound))]
                    inner = ",".join(f'{k}="{v}"' for k, v in lab)
                    lines.append(f"{name}_bucket{{{inner}}} {cum}")
                inner = ",".join(f'{k}="{v}"'
                                 for k, v in pairs + [("le", "+Inf")])
                lines.append(f"{name}_bucket{{{inner}}} {leaf.total}")
                suffix = self._series_key("", inst.labelnames, values)
                lines.append(f"{name}_sum{suffix} {_fmt(leaf.sum)}")
                lines.append(f"{name}_count{suffix} {leaf.total}")
        return "\n".join(lines) + "\n"

    def get(self, series: str, default: float = 0.0) -> float:
        """Scalar lookup by snapshot series key (counters/gauges)."""
        snap = self.snapshot()
        if series in snap["counters"]:
            return snap["counters"][series]
        return snap["gauges"].get(series, default)


def _fmt(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


# --------------------------------------------------- process-wide default
_ALWAYS_ON = MetricsRegistry(enabled=True)       # standalone histograms
_DEFAULT = MetricsRegistry(enabled=False)


def get_registry() -> MetricsRegistry:
    """The currently installed process registry (disabled no-op
    registry by default)."""
    return _DEFAULT


def install(reg: Optional[MetricsRegistry] = None) -> MetricsRegistry:
    """Install ``reg`` (default: a fresh enabled registry) as the
    process registry and return it. Instruments bind at construction
    time, so install BEFORE building engines/routers/servers."""
    global _DEFAULT
    _DEFAULT = reg if reg is not None else MetricsRegistry()
    return _DEFAULT


def uninstall() -> None:
    """Restore the disabled default (telemetry off)."""
    global _DEFAULT
    _DEFAULT = MetricsRegistry(enabled=False)


@contextlib.contextmanager
def use(reg: Optional[MetricsRegistry] = None):
    """Scoped ``install`` — restores the previous registry on exit."""
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = reg if reg is not None else MetricsRegistry()
    try:
        yield _DEFAULT
    finally:
        _DEFAULT = prev
