"""Assert the engine-bench trajectory point is sane — perf regressions
fail loudly instead of silently landing.

    python scripts/check_bench.py BENCH.json [tok_s_floor]

Checks (engine section of ``benchmarks.run``):
  * one fused dispatch per decode step (the PR 1 invariant)
  * decode tokens/s above a catastrophic-regression floor
  * paged sparse read: pages touched < dense-window pages (PR 2)
  * hot-tier bytes/slot constant across max_len in {1k, 4k, 16k}
    (PR 5 ring invariant), and the ring within 10% of the full-window
    paged engine's tokens/s

Checks (chaos section, ``BENCH_pr6.json``):
  * zero tokens lost across every fault scenario (twin-exact recovery)
  * 1-kill goodput >= 0.8x the fault-free run of the same trace

Checks (prefix section, ``BENCH_pr7.json``):
  * zero tokens lost at EVERY share ratio (prefix sharing is exact)
  * prefill FLOPs saved > 0 wherever the share ratio >= 0.5
  * peak pool occupancy monotonically helped: occupancy at the highest
    share ratio below the no-sharing ratio's (shared blocks count once)

Checks (obs section, ``BENCH_pr9.json``):
  * telemetry-on decode tok/s >= 0.95x telemetry-off (the PR 9
    zero-allocation-when-disabled / cheap-when-enabled floor)
  * token streams identical with collectors on and off

Checks (shard section, ``BENCH_pr10.json``):
  * zero tokens diverged between shard 1/2/4 engines (sharding is
    bit-exact)
  * one fused dispatch per decode step under shard_map
  * a 2-way-sharded engine holds <= 0.6x the full param copy per
    device (replica groups share one sharded replica)
  * the Alg. 1 (O, m, l) merge's collective bytes are FLAT in context

Checks (serving section, ``BENCH_pr8.json``):
  * zero lost / duplicated streamed tokens across every scenario
  * SLO attainment >= 0.9 on the smoke trace (single-device Poisson)
  * p99 TTFT on the smoke trace below the committed ceiling
  * chunked prefill cuts the pooled p99 token-gap tail on the
    long-prompt trace (ratio vs unchunked <= 0.9) at matched
    throughput (within 5%)
"""

import json
import sys


def check_chaos(d: dict) -> None:
    lost = d["chaos_tokens_lost"]
    ratio = d["chaos_kill_goodput_ratio"]
    assert lost == 0, (
        f"{lost} tokens lost under injected faults — recovery is no "
        f"longer twin-exact")
    assert ratio >= 0.8, (
        f"1-kill goodput ratio {ratio:.3f} below the 0.8 floor")
    print(f"chaos bench OK: 0 tokens lost, 1-kill goodput "
          f"{ratio:.3f}x fault-free (floor 0.8), recovery mean "
          f"{d['chaos_kill_recovery_latency_mean_s'] * 1e3:.1f} ms sim")


def check_prefix(d: dict) -> None:
    lost = d["prefix_tokens_lost"]
    assert lost == 0, (
        f"{lost} tokens diverged from the cache-off twin — prefix "
        f"sharing is no longer exact")
    points = d["prefix"]["points"]
    for p in points.values():
        assert p["tokens_lost"] == 0, p
        if p["share_ratio"] >= 0.5:
            assert p["prefill_flops_saved"] > 0, (
                f"no prefill compute saved at share ratio "
                f"{p['share_ratio']} — the trie stopped matching")
    ordered = sorted(points.values(), key=lambda p: p["share_ratio"])
    lo, hi = ordered[0], ordered[-1]
    assert hi["pool_occupancy_peak"] < lo["pool_occupancy_peak"], (
        f"peak occupancy did not drop with sharing: "
        f"{lo['pool_occupancy_peak']:.3f} @ r={lo['share_ratio']} vs "
        f"{hi['pool_occupancy_peak']:.3f} @ r={hi['share_ratio']}")
    print(f"prefix bench OK: 0 tokens lost over {len(points)} share "
          f"ratios, {hi['prefill_flops_saved']:.3g} prefill FLOPs saved "
          f"at r={hi['share_ratio']}, peak occupancy "
          f"{lo['pool_occupancy_peak']:.3f} -> "
          f"{hi['pool_occupancy_peak']:.3f}")


def check_obs(d: dict) -> None:
    ratio = d["obs_overhead_ratio"]
    assert ratio >= 0.95, (
        f"telemetry overhead ratio {ratio:.3f} below the 0.95 floor — "
        f"the collectors are no longer cheap on the decode fast path")
    assert d["obs"]["streams_identical"] is True, (
        "telemetry changed the token streams")
    assert d["obs"]["enabled"]["trace_events"] > 0, (
        "enabled run recorded no trace events — the collector was not "
        "actually active during the measurement")
    print(f"obs bench OK: telemetry-on decode "
          f"{d['obs_decode_tok_s_enabled']:.0f} tok/s = {ratio:.3f}x "
          f"telemetry-off {d['obs_decode_tok_s_disabled']:.0f} "
          f"(floor 0.95), {d['obs']['enabled']['trace_events']} trace "
          f"events, streams identical")


def check_serving(d: dict) -> None:
    lost = d["serving_tokens_lost"]
    assert lost == 0, (
        f"{lost} streamed tokens lost or duplicated — the server loop "
        f"broke the stream contract")
    att = d["serving_slo_attainment"]
    assert att >= 0.9, (
        f"smoke-trace SLO attainment {att:.3f} below the 0.9 floor")
    smoke = d["serving"]["scenarios"]["single_poisson"]
    p99 = smoke["ttft_s"]["p99"]
    # the sim clock is modeled and seeded, so this is deterministic;
    # the ceiling is ~5x the committed value (0.0037 s)
    assert p99 <= 0.02, (
        f"smoke-trace p99 TTFT {p99:.4f}s above the 0.02s ceiling")
    ratio = d["serving_chunked_p99_tpot_ratio"]
    assert ratio <= 0.9, (
        f"chunked prefill no longer cuts the p99 token-gap tail: "
        f"ratio {ratio:.3f} vs unchunked (floor 0.9)")
    cc = d["serving"]["chunked_prefill"]
    tc = cc["chunked"]["throughput_tok_s"]
    tu = cc["unchunked"]["throughput_tok_s"]
    assert abs(tc - tu) <= 0.05 * tu, (
        f"chunked/unchunked throughput diverged: {tc:.0f} vs {tu:.0f} "
        f"tok/s — the tail comparison is no longer at equal load")
    print(f"serving bench OK: 0 lost/dup tokens, smoke SLO {att:.3f} "
          f"(floor 0.9), p99 TTFT {p99 * 1e3:.2f} ms, chunked p99 "
          f"token-gap {ratio:.3f}x unchunked at {tc:.0f}/{tu:.0f} tok/s")


def check_shard(d: dict) -> None:
    lost = d["shard_tokens_lost"]
    assert lost == 0, (
        f"{lost} tokens diverged between sharded and unsharded "
        f"engines — the shard_map merge is no longer exact")
    disp = d["shard_dispatches_per_step"]
    assert disp == 1.0, (
        f"{disp} dispatches/step — sharding broke the fused-dispatch "
        f"invariant")
    ratio = d["shard_param_bytes_ratio_2way"]
    assert ratio <= 0.6, (
        f"2-way-sharded engine holds {ratio:.2f}x of the full param "
        f"copy per device (floor 0.6x) — replica groups no longer "
        f"share the replica")
    assert d["shard_merge_bytes_flat"] is True, (
        "the (O, m, l) merge's collective bytes grew with context — "
        "the flat-communication claim regressed")
    pts = d["shard"]["points"]
    print(f"shard bench OK: 0 tokens diverged at shard "
          f"{sorted(pts, key=int)}, {disp:.2f} dispatches/step, "
          f"{ratio:.2f}x param bytes/device at shard 2, merge "
          f"{d['shard']['merge_bytes_per_step']} B/step flat in "
          f"context")


def main(path: str, floor: float = 100.0) -> None:
    d = json.load(open(path))
    done = False
    if "prefix_tokens_lost" in d:
        check_prefix(d)
        done = True
    if "shard_tokens_lost" in d:
        check_shard(d)
        done = True
    if "chaos_kill_goodput_ratio" in d:
        check_chaos(d)
        done = True
    if "serving_slo_attainment" in d:
        check_serving(d)
        done = True
    if "obs_overhead_ratio" in d:
        check_obs(d)
        done = True
    if done and "dispatches_per_step" not in d:
        return                           # section-only bench file
    assert d["dispatches_per_step"] == 1.0, d["dispatches_per_step"]
    assert d["decode_tok_s"] > floor, (
        f"decode tok/s {d['decode_tok_s']:.0f} below floor {floor:.0f}")
    assert d["paged_blocks_touched_per_step"] < \
        d["paged_blocks_window_per_step"]
    assert d["hot_bytes_constant_across_smax"] is True, \
        d.get("hot_window_scaling")
    ring, paged = d["ring_decode_tok_s"], d["paged_decode_tok_s"]
    # catastrophic-only guard: single-run wall-clock on shared runners
    # jitters well past 10%, so CI asserts the ring is in the same class
    # as the full-window paged engine; the tighter 10% comparison is the
    # BENCH_pr5.json acceptance check, taken on a quiet machine
    assert ring > 0.5 * paged, (
        f"ring decode {ring:.0f} tok/s collapsed vs the full-window "
        f"paged engine's {paged:.0f}")
    scaling = d["hot_window_scaling"]["points"]
    print(f"bench OK: {d['decode_tok_s']:.0f} tok/s (floor {floor:.0f}), "
          f"{d['dispatches_per_step']:.2f} dispatches/step, paged pages/"
          f"step {d['paged_blocks_touched_per_step']:.1f}"
          f"/{d['paged_blocks_window_per_step']:.1f}, ring "
          f"{ring:.0f} tok/s at {d['hot_bytes_per_slot']} hot bytes/slot "
          f"constant over Smax {sorted(scaling, key=int)}")


if __name__ == "__main__":
    main(sys.argv[1],
         float(sys.argv[2]) if len(sys.argv) > 2 else 100.0)
