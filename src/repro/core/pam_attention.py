"""PAMattention (paper §5, Algorithm 1) — single-host orchestration.

Ties together the pieces:
  1. (optional) retrieval sparsity picks the tokens that participate,
  2. tokens are partitioned by tier residency (HBM / DDR / SSD),
  3. each partition runs Local_Attention -> (O_t, m_t, l_t),
  4. hierarchical Reduction merges partials exactly,
  5. importance scores are updated (eq. 7) from the step's attention mass.

The distributed (shard_map) form lives in ``repro.distributed.pam_shard``;
the Pallas kernel form of step 3 in ``repro.kernels.flash_decode``. All
three are interchangeable and agree numerically (tested).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import importance as imp_mod
from repro.core import online_softmax as osm
from repro.core.tiers import COLD, HOT, WARM


@dataclasses.dataclass(frozen=True)
class PAMAttentionConfig:
    num_tiers: int = 3
    use_sparsity: bool = True
    compression: int = 8          # keep S/compression tokens per step
    lam: float = imp_mod.DEFAULT_LAMBDA


class PAMAttentionOutput(NamedTuple):
    out: jax.Array           # (H, d) attention output
    step_scores: jax.Array   # (S,) per-token attention mass S_i(j)
    new_importance: jax.Array


def pam_attention_step(q: jax.Array, k: jax.Array, v: jax.Array,
                       tier_of_token: jax.Array, valid: jax.Array,
                       importance: jax.Array,
                       cfg: PAMAttentionConfig = PAMAttentionConfig(),
                       scale: float | None = None) -> PAMAttentionOutput:
    """One decode-step attention for one sequence.

    q: (H, d) current query; k, v: (S, H_kv, d) full cached KV (GQA allowed:
    H must be a multiple of H_kv); tier_of_token/valid/importance: (S,).

    Partitions by tier, computes local partials per tier, merges exactly.
    With ``use_sparsity``, only the top-(S_valid/compression) tokens by
    current importance participate (retrieval sparsity; importance carries
    the context-locality signal).
    """
    S, H_kv, d = k.shape
    H = q.shape[0]
    rep = H // H_kv

    participate = valid
    if cfg.use_sparsity:
        n_valid = jnp.sum(valid)
        k_keep = jnp.maximum(n_valid // cfg.compression, 1)
        # static top-k size: S // compression rounded up, clamped by mask
        k_static = max(S // cfg.compression, 1)
        scores = jnp.where(valid, importance, -jnp.inf)
        _, idx = jax.lax.top_k(scores, k_static)
        sel = jnp.zeros((S,), bool).at[idx].set(True) & valid
        # honor dynamic budget: drop selected tokens ranked past k_keep
        ranks = jnp.argsort(jnp.argsort(-scores))
        sel = sel & (ranks < k_keep)
        participate = sel

    kh = jnp.repeat(k, rep, axis=1)    # (S, H, d)
    vh = jnp.repeat(v, rep, axis=1)

    # Per-tier local attention (Alg. 1 lines 3-4) — masks select residency.
    partials = []
    for tier in (HOT, WARM, COLD)[: cfg.num_tiers]:
        mask = participate & (tier_of_token == tier)      # (S,)
        part = osm.local_attention(
            q,                                             # (H, d)
            jnp.moveaxis(kh, 0, 1),                        # (H, S, d)
            jnp.moveaxis(vh, 0, 1),
            scale=scale,
            mask=mask[None, :],
        )
        partials.append(part)

    stacked = osm.AttnPartial(
        o=jnp.stack([p.o for p in partials]),
        m=jnp.stack([p.m for p in partials]),
        l=jnp.stack([p.l for p in partials]),
    )
    merged = osm.tree_merge(stacked)                      # hierarchical RU
    out = osm.finalize(merged, out_dtype=q.dtype)

    # Step scores for eq. (7): exact attention mass per token this step.
    step_scores = _attention_mass(q, kh, participate, merged, scale)
    new_imp = imp_mod.update_importance(importance, step_scores, lam=cfg.lam)
    return PAMAttentionOutput(out=out, step_scores=step_scores,
                              new_importance=new_imp)


def _attention_mass(q, kh, participate, merged: osm.AttnPartial, scale):
    """Per-token softmax mass (head-mean, count-scaled) for importance."""
    d = q.shape[-1]
    sc = scale if scale is not None else 1.0 / jnp.sqrt(jnp.float32(d))
    s = jnp.einsum("hd,shd->hs", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) * sc
    s = jnp.where(participate[None, :], s, -jnp.inf)
    m_safe = jnp.where(jnp.isfinite(merged.m), merged.m, 0.0)
    p = jnp.exp(s - m_safe[:, None]) / jnp.maximum(merged.l, 1e-30)[:, None]
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    return imp_mod.step_score_from_attn_weights(p, head_axis=0)
