"""Online inter-device KV scheduling (paper §4.3): keep a heterogeneous
fleet balanced by migrating running requests off overloaded devices.

Every ``rebalance_interval`` router ticks the balancer scores each
device with the *modeled load* signal

    load = (running + queued) * modeled_step_latency

(the step latency comes from the device's perfmodel latency model —
its last charged step, or the device-class prior before first dispatch)
and, when the slowest device's load exceeds the fastest candidate's by
the ``hysteresis`` factor, migrates the slowest device's
LOWEST-importance-mass request (the cheapest accuracy stake, mirroring
Alg. 2's move-the-least-important-first rule at inter-device scope) to
the fastest device with blocks and a slot free. Hysteresis plus a
per-request ``cooldown`` window keep requests from ping-ponging between
devices under oscillating load.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.cluster import migration


@dataclasses.dataclass(frozen=True)
class BalancerConfig:
    rebalance_interval: int = 8    # router ticks between balancer runs
    hysteresis: float = 1.5        # min slow/fast load ratio to act
    cooldown_ticks: int = 24       # per-request immunity after a move
    max_moves_per_round: int = 1
    min_remaining: int = 4         # don't move nearly-finished requests
    link_bw: float = 64e9          # migration interconnect bytes/s


class KVBalancer:
    """Stateful balancer driven by the router (see ``ClusterRouter``)."""

    def __init__(self, cfg: BalancerConfig = BalancerConfig()):
        self.cfg = cfg
        self.migrations = 0
        self.moved_bytes = 0
        self.token_bytes = 0.0     # modeled KV bytes per engine token;
        # 0 -> charge the snapshot's raw array bytes (wall-clock runs).
        # build_cluster sets the model's kv_bytes_per_token here.
        self.log: list[dict[str, Any]] = []
        self._last_moved: dict[int, int] = {}    # rid -> router tick

    # ------------------------------------------------------------ signals
    def device_load(self, dev) -> float:
        """Modeled load of one ``ClusterDevice``: occupancy-weighted
        step latency. Idle devices score 0 (always a migration target,
        never a source)."""
        eng = dev.engine
        n = sum(s is not None for s in eng.slots) + len(eng.waiting)
        if n == 0:
            return 0.0
        step = eng.last_step_time or dev.step_prior
        return n * step

    # ---------------------------------------------------------- rebalance
    def rebalance(self, devices: list, tick: int) -> list[dict[str, Any]]:
        """One balancing round over the router's devices. Returns the
        migration records performed (possibly empty). Devices that are
        not healthy ("up" and alive) are excluded outright: a dead or
        draining device is neither a migration source the balancer may
        raid (its KV belongs to the recovery path) nor a target that
        could strand a request."""
        devices = [d for d in devices
                   if getattr(d, "state", "up") == "up"
                   and not getattr(d, "killed", False)]
        if len(devices) < 2:
            return []
        moves: list[dict[str, Any]] = []
        for _ in range(self.cfg.max_moves_per_round):
            rec = self._one_move(devices, tick)
            if rec is None:
                break
            moves.append(rec)
        return moves

    def _one_move(self, devices: list, tick: int
                  ) -> Optional[dict[str, Any]]:
        ranked = sorted(devices, key=self.device_load)
        slow = ranked[-1]
        slow_load = self.device_load(slow)
        if slow_load <= 0.0:
            return None
        victim_mass = slow.engine.slot_importance_mass()

        def eligible(rid: int) -> bool:
            if (tick - self._last_moved.get(rid, -10**9)
                    < self.cfg.cooldown_ticks):
                return False
            rs = slow.engine.requests[rid]
            remaining = rs.request.max_new_tokens - len(rs.outputs)
            return remaining >= self.cfg.min_remaining

        # lowest importance mass first (cheapest accuracy stake)
        victims = sorted(filter(eligible, victim_mass),
                         key=lambda rid: victim_mass[rid])
        for dst in ranked[:-1]:
            dst_load = self.device_load(dst)
            # hysteresis: act only on a decisive imbalance; compare
            # against the destination as if it took one more request
            step = dst.engine.last_step_time or dst.step_prior
            if slow_load < self.cfg.hysteresis * (dst_load + step):
                continue
            for rid in victims:
                if not migration.can_migrate(slow.engine, dst.engine, rid):
                    continue
                # idleness must be sampled BEFORE the commit occupies a
                # destination slot
                dst_idle = not any(s is not None
                                   for s in dst.engine.slots)
                rec = migration.migrate(slow.engine, dst.engine, rid)
                if self.token_bytes:
                    rec["bytes"] = int(rec["tokens"] * self.token_bytes)
                rec["transfer_s"] = rec["bytes"] / self.cfg.link_bw
                # an IDLE target skips ahead to the export time (the
                # request cannot resume before it was exported); a busy
                # target keeps its own timeline — it catches up on its
                # next steps — and always pays the transfer
                if dst_idle:
                    dst.engine.clock = max(dst.engine.clock,
                                           slow.engine.clock)
                dst.engine.clock += rec["transfer_s"]
                self._last_moved[rid] = tick
                self.migrations += 1
                self.moved_bytes += rec["bytes"]
                rec["tick"] = tick
                self.log.append(rec)
                return rec
        return None
