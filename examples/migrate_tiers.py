"""Tier-migration example: watch context locality move KV tokens across
the HBM/DDR/SSD hierarchy during decoding (paper Figs. 3 + §6.3).

    PYTHONPATH=src python examples/migrate_tiers.py
"""

import jax
import numpy as np

from repro.models import transformer as tfm
from repro.models.config import get_config, reduced
from repro.serving import (EngineSpec, PAMManagerConfig, Request,
                           ServingConfig)
from repro.core.tiers import HOT, WARM, COLD

cfg = reduced(get_config("qwen3-14b"))
params = tfm.init_params(cfg, jax.random.PRNGKey(0))
eng = EngineSpec(model=cfg, serving=ServingConfig(
    max_batch=1, max_len=160,
    pam=PAMManagerConfig(max_tokens=160, hot_capacity=12, warm_capacity=36,
                         compression=4, recency_window=4,
                         schedule_interval=1))).build(params)

rng = np.random.default_rng(0)
eng.submit(Request(id=0, prompt=rng.integers(0, cfg.vocab, 96),
                   max_new_tokens=32))

print("step | hot warm cold | reads(H/D/S) | hit-rate | moved")
for step in range(32):
    stats = eng.step()
    st = eng.pam_state
    tier = np.asarray(st.tier[0])
    n = int(eng.cache.lengths[0])
    t = tier[:n]
    reads = stats["tier_reads"]
    print(f"{step:4d} | {np.sum(t==HOT):3d} {np.sum(t==WARM):4d} "
          f"{np.sum(t==COLD):4d} | {reads[0]:3d}/{reads[1]:3d}/{reads[2]:3d}"
          f" | {stats.get('hit_rate', 0.0):.2f}    | "
          f"{stats['moved_tokens']}")
    if all(s is None for s in eng.slots):
        break

imp = np.asarray(eng.pam_state.importance[0])[:n]
tier = np.asarray(eng.pam_state.tier[0])[:n]
print(f"\nmean importance by tier:  hot={imp[tier==HOT].mean():.4f}  "
      f"warm={imp[tier==WARM].mean():.4f}  cold={imp[tier==COLD].mean():.4f}")
assert imp[tier == HOT].mean() > imp[tier == COLD].mean()
print("context locality concentrated importance in the fast tier — OK")
