"""Analytical performance/energy model of PAM and its baselines —
the reproduction of the paper's simulator methodology (§7.1)."""

from repro.perfmodel.model import (SystemModel, SystemKind, StepWorkload,
                                   make_system, simulate_decode_step,
                                   simulate_offline, simulate_online)
from repro.perfmodel.latency import make_latency_model

__all__ = ["SystemModel", "SystemKind", "StepWorkload", "make_system",
           "simulate_decode_step", "simulate_offline", "simulate_online",
           "make_latency_model"]
