"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family; hf] — GQA w/ qk-norm,
128 experts top-8."""
from repro.models.config import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936, d_head=128, qk_norm=True,
    rope_theta=1e6,
    moe=MoEConfig(num_experts=128, top_k=8, num_shared=0, d_expert=1536),
))
