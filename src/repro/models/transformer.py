"""Model assembly: init / train-forward / decode-step for every family.

Layer parameters are stacked on a leading axis and iterated with
``jax.lax.scan`` so the lowered HLO is layer-count-independent (critical for
the 40-cell x 512-device dry-run compile budget). Decode threads per-layer
caches through the same scan.

Families:
  dense           pre-norm GQA attention + SwiGLU
  moe             pre-norm attention (GQA or MLA) + routed MoE
  ssm             Mamba-2 blocks only
  hybrid          Zamba2-style: groups of mamba layers + one *shared*
                  attention/MLP block applied between groups
  audio           bidirectional encoder (frame embeddings in, CTC-ish head)
  vlm             patch-embedding prefix + causal LM backbone
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import perf_flags
from repro.models.config import ModelConfig
from repro.models.layers import (init_embedding, init_linear, rms_norm,
                                 swiglu)

Params = dict[str, Any]


# ============================================================ init helpers
def _init_dense_layer(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    layer: Params = {
        "ln1": jnp.ones((d,), dtype),
        "ln2": jnp.ones((d,), dtype),
    }
    if cfg.mla is not None:
        layer["mla"] = mla_mod.init_mla(ks[0], d, cfg.n_heads, cfg.mla,
                                        dtype)._asdict()
    else:
        layer["attn"] = attn_mod.init_attn(
            ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            cfg.qk_norm, dtype)._asdict()
        if not cfg.qk_norm:
            # keep pytree structure uniform for scan stacking
            layer["attn"]["q_norm"] = jnp.zeros((0,), dtype)
            layer["attn"]["k_norm"] = jnp.zeros((0,), dtype)
    if cfg.moe is not None:
        mp = moe_mod.init_moe(ks[1], d, cfg.moe, dtype)._asdict()
        if cfg.moe.num_shared == 0:
            mp["shared_gate"] = jnp.zeros((0,), dtype)
            mp["shared_up"] = jnp.zeros((0,), dtype)
            mp["shared_down"] = jnp.zeros((0,), dtype)
        layer["moe"] = mp
    else:
        layer["mlp"] = {
            "gate": init_linear(ks[2], d, cfg.d_ff, dtype),
            "up": init_linear(ks[3], d, cfg.d_ff, dtype),
            "down": init_linear(ks[4], cfg.d_ff, d, dtype),
        }
    return layer


def _init_ssm_layer(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ln": jnp.ones((cfg.d_model,), dtype),
        "ssm": ssm_mod.init_ssm(key, cfg.d_model, cfg.ssm, dtype)._asdict(),
    }


def _stack(trees: list[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 8)
    params: Params = {
        "embed": init_embedding(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(keys[1], cfg.d_model, cfg.vocab,
                                        dtype)

    if cfg.family == "ssm":
        params["layers"] = _stack([
            _init_ssm_layer(keys[2 + i], cfg) for i in range(cfg.n_layers)])
    elif cfg.family == "hybrid":
        hb = cfg.hybrid
        n_mamba = hb.n_groups * hb.mamba_per_group
        mamba = [_init_ssm_layer(keys[2 + i], cfg) for i in range(n_mamba)]
        grouped = [
            _stack(mamba[g * hb.mamba_per_group:(g + 1) * hb.mamba_per_group])
            for g in range(hb.n_groups)]
        params["mamba_groups"] = _stack(grouped)
        params["tail_mamba"] = _stack([
            _init_ssm_layer(keys[2 + n_mamba + i], cfg)
            for i in range(hb.tail_mamba)])
        shared_cfg = dataclasses.replace(cfg, moe=None, mla=None)
        params["shared_attn"] = _init_dense_layer(keys[2 + cfg.n_layers],
                                                  shared_cfg)
    else:
        params["layers"] = _stack([
            _init_dense_layer(keys[2 + i], cfg) for i in range(cfg.n_layers)])

    if cfg.family in ("vlm", "audio"):
        params["frontend"] = init_linear(keys[-1], cfg.frontend_dim,
                                         cfg.d_model, dtype)
    return params


# ============================================================ block applies
def _attn_params(layer: Params) -> attn_mod.AttnParams:
    a = layer["attn"]
    qn = a["q_norm"] if a["q_norm"].size else None
    kn = a["k_norm"] if a["k_norm"].size else None
    return attn_mod.AttnParams(a["wq"], a["wk"], a["wv"], a["wo"], qn, kn)


def _moe_params(layer: Params) -> moe_mod.MoEParams:
    m = layer["moe"]
    return moe_mod.MoEParams(
        m["router"], m["w_gate"], m["w_up"], m["w_down"],
        m["shared_gate"] if m["shared_gate"].size else None,
        m["shared_up"] if m["shared_up"].size else None,
        m["shared_down"] if m["shared_down"].size else None)


def _sp_pin(h: jax.Array) -> jax.Array:
    """§Perf `sp_pin`: keep intra-block activations sequence-sharded so TP
    reductions move S-sharded tensors instead of full activations."""
    if not perf_flags.enabled("sp_pin") or h.ndim != 3:
        return h
    from jax.sharding import PartitionSpec as P
    try:
        mesh = perf_flags.abstract_mesh()
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        return jax.lax.with_sharding_constraint(
            h, P(dp or None, "model", None))
    except Exception:
        return h


def _dense_block(cfg: ModelConfig, layer: Params, x: jax.Array,
                 use_kernel: bool) -> tuple[jax.Array, jax.Array]:
    """Pre-norm attention + FFN/MoE. Returns (x, aux_loss)."""
    h = _sp_pin(rms_norm(x, layer["ln1"], cfg.rms_eps))
    if cfg.mla is not None:
        a = layer["mla"]
        attn_out = mla_mod.mla_train(
            mla_mod.MLAParams(**a), h, cfg.mla, n_heads=cfg.n_heads,
            rope_theta=cfg.rope_theta, rms_eps=cfg.rms_eps,
            causal=cfg.causal)
    else:
        attn_out = attn_mod.attention_train(
            _attn_params(layer), h, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, d_head=cfg.head_dim, causal=cfg.causal,
            rope_theta=cfg.rope_theta, rms_eps=cfg.rms_eps,
            use_kernel=use_kernel)
    x = x + attn_out
    h = _sp_pin(rms_norm(x, layer["ln2"], cfg.rms_eps))
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        ffn_out, aux = moe_mod.moe_forward(_moe_params(layer), h, cfg.moe)
    else:
        m = layer["mlp"]
        ffn_out = swiglu(h, m["gate"], m["up"], m["down"])
    return x + _sp_pin(ffn_out), aux


def _ssm_block(cfg: ModelConfig, layer: Params, x: jax.Array,
               use_kernel: bool) -> jax.Array:
    h = rms_norm(x, layer["ln"], cfg.rms_eps)
    return x + ssm_mod.ssm_forward(
        ssm_mod.SSMParams(**layer["ssm"]), h, cfg.ssm,
        rms_eps=cfg.rms_eps, use_kernel=use_kernel)


# ============================================================ train forward
def forward(cfg: ModelConfig, params: Params, batch: dict[str, jax.Array], *,
            use_kernel: bool = False, remat: bool = False,
            activation_spec=None) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, V), aux_loss scalar).

    ``activation_spec``: optional PartitionSpec pinned onto the residual
    stream between layers (Megatron-style sequence parallelism — shards the
    scan carry that dominates checkpointed-activation memory at 4k+ seq)."""
    def _pin(h):
        if activation_spec is None:
            return h
        return jax.lax.with_sharding_constraint(h, activation_spec)
    if cfg.family == "audio":
        x = jnp.einsum("bsf,fd->bsd", batch["frames"], params["frontend"])
    else:
        x = params["embed"][batch["tokens"]]
        if cfg.family == "vlm":
            patches = jnp.einsum("bpf,fd->bpd", batch["patches"],
                                 params["frontend"])
            x = jnp.concatenate([patches, x], axis=1)

    if remat and perf_flags.enabled("remat_dots"):
        _ckpt = lambda f: jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    else:
        _ckpt = jax.checkpoint
    x = _pin(x)
    if cfg.family == "ssm":
        def body(carry, layer):
            return _pin(_ssm_block(cfg, layer, carry, use_kernel)), None
        if remat:
            body = _ckpt(body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        aux = jnp.zeros((), jnp.float32)
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def mamba_body(carry, layer):
            return _pin(_ssm_block(cfg, layer, carry, use_kernel)), None

        def group_body(carry, group_layers):
            h, _ = jax.lax.scan(mamba_body, carry, group_layers)
            h, _ = _dense_block(cfg, shared, h, use_kernel)
            return _pin(h), None
        if remat:
            group_body = _ckpt(group_body)
        x, _ = jax.lax.scan(group_body, x, params["mamba_groups"])
        x, _ = jax.lax.scan(mamba_body, x, params["tail_mamba"])
        aux = jnp.zeros((), jnp.float32)
    else:
        def body(carry, layer):
            h, aux = _dense_block(cfg, layer, carry, use_kernel)
            return _pin(h), aux
        if remat:
            body = _ckpt(body)
        x, auxes = jax.lax.scan(body, x, params["layers"])
        aux = jnp.sum(auxes)

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    if cfg.family == "vlm":  # strip the image-prefix positions
        logits = logits[:, cfg.num_patches:]
    return logits, aux


def loss_fn(cfg: ModelConfig, params: Params, batch: dict[str, jax.Array],
            *, use_kernel: bool = False, remat: bool = False,
            activation_spec=None) -> jax.Array:
    logits, aux = forward(cfg, params, batch, use_kernel=use_kernel,
                          remat=remat, activation_spec=activation_spec)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.where(labels >= 0, nll, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0) + aux


# ============================================================ prefill
def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            max_len: int, *, patches: jax.Array | None = None,
            true_len: jax.Array | None = None
            ) -> tuple[jax.Array, "DecodeCache"]:
    """Batched prompt processing (the paper's NPU prefill phase, §4.3):
    one parallel pass that returns next-token logits AND a filled decode
    cache (KV / latent / SSM state), padded to ``max_len``.

    tokens: (B, S) right-aligned prompts, all the same length (the serving
    engine buckets; ragged support lives there via per-seq lengths).

    ``true_len``: optional dynamic prompt length (scalar or (B,)) when
    ``tokens`` is right-PADDED to a compile-time bucket (pow-2 padding caps
    the jit-cache to O(log max_len) entries). Causality guarantees the
    first ``true_len`` positions are unaffected by padding; the returned
    logits are taken at position ``true_len - 1`` and cache lengths are set
    to ``true_len``, so stale padded K/V past it is dead and overwritten by
    subsequent decode appends. Only valid for positional-cache families
    (attention); SSM/hybrid running state would absorb the padding."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    n_prefix = 0
    if cfg.family == "vlm" and patches is not None:
        px = jnp.einsum("bpf,fd->bpd", patches, params["frontend"])
        x = jnp.concatenate([px, x], axis=1)
        n_prefix = patches.shape[1]
    Sfull = S + n_prefix
    pad = max_len - Sfull
    assert pad >= 0, (max_len, Sfull)
    if true_len is not None and cfg.family in ("ssm", "hybrid"):
        raise ValueError("bucketed prefill (true_len) requires a "
                         "positional cache; SSM state absorbs padding")
    cache = init_decode_cache(cfg, B, max_len)
    if true_len is None:
        lens = jnp.full((B,), Sfull, jnp.int32)
    else:
        lens = jnp.broadcast_to(jnp.asarray(true_len, jnp.int32),
                                (B,)) + n_prefix

    def pad_seq(arr, axis):
        widths = [(0, 0)] * arr.ndim
        widths[axis] = (0, pad)
        return jnp.pad(arr, widths)

    if cfg.family in ("dense", "vlm") or (cfg.family == "moe"
                                          and cfg.mla is None):
        def body(carry, layer):
            h = carry
            hn = rms_norm(h, layer["ln1"], cfg.rms_eps)
            attn_out, k, v = attn_mod.attention_prefill(
                _attn_params(layer), hn, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, d_head=cfg.head_dim, causal=cfg.causal,
                rope_theta=cfg.rope_theta, rms_eps=cfg.rms_eps)
            h = h + attn_out
            hn = rms_norm(h, layer["ln2"], cfg.rms_eps)
            if cfg.moe is not None:
                ffn, _ = moe_mod.moe_forward(_moe_params(layer), hn, cfg.moe)
            else:
                m = layer["mlp"]
                ffn = swiglu(hn, m["gate"], m["up"], m["down"])
            return h + ffn, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        cache = cache._replace(k=pad_seq(ks, 3), v=pad_seq(vs, 3))

    elif cfg.family == "moe":                      # MLA
        def body(carry, layer):
            h = carry
            hn = rms_norm(h, layer["ln1"], cfg.rms_eps)
            attn_out, ckv, krp = mla_mod.mla_prefill(
                mla_mod.MLAParams(**layer["mla"]), hn, cfg.mla,
                n_heads=cfg.n_heads, rope_theta=cfg.rope_theta,
                rms_eps=cfg.rms_eps, causal=cfg.causal)
            h = h + attn_out
            hn = rms_norm(h, layer["ln2"], cfg.rms_eps)
            ffn, _ = moe_mod.moe_forward(_moe_params(layer), hn, cfg.moe)
            return h + ffn, (ckv, krp)

        x, (ckvs, krps) = jax.lax.scan(body, x, params["layers"])
        cache = cache._replace(ckv=pad_seq(ckvs, 2), krope=pad_seq(krps, 2))

    elif cfg.family == "ssm":
        def body(carry, layer):
            h = carry
            hn = rms_norm(h, layer["ln"], cfg.rms_eps)
            out, c = ssm_mod.ssm_prefill(
                ssm_mod.SSMParams(**layer["ssm"]), hn, cfg.ssm,
                rms_eps=cfg.rms_eps)
            return h + out, (c.conv, c.state)

        x, (convs, states) = jax.lax.scan(body, x, params["layers"])
        cache = cache._replace(conv=convs, state=states)

    elif cfg.family == "hybrid":
        hb = cfg.hybrid
        shared = params["shared_attn"]

        def mamba_body(carry, layer):
            h = carry
            hn = rms_norm(h, layer["ln"], cfg.rms_eps)
            out, c = ssm_mod.ssm_prefill(
                ssm_mod.SSMParams(**layer["ssm"]), hn, cfg.ssm,
                rms_eps=cfg.rms_eps)
            return h + out, (c.conv, c.state)

        def group_body(carry, layers):
            h, caches = jax.lax.scan(mamba_body, carry, layers)
            hn = rms_norm(h, shared["ln1"], cfg.rms_eps)
            attn_out, k, v = attn_mod.attention_prefill(
                _attn_params(shared), hn, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, d_head=cfg.head_dim, causal=True,
                rope_theta=cfg.rope_theta, rms_eps=cfg.rms_eps)
            h = h + attn_out
            hn = rms_norm(h, shared["ln2"], cfg.rms_eps)
            m = shared["mlp"]
            return h + swiglu(hn, m["gate"], m["up"], m["down"]), \
                (caches, k, v)

        x, (gcaches, ks, vs) = jax.lax.scan(group_body, x,
                                            params["mamba_groups"])
        x, tcaches = jax.lax.scan(mamba_body, x, params["tail_mamba"])
        conv = jnp.concatenate(
            [gcaches[0].reshape((-1,) + gcaches[0].shape[2:]), tcaches[0]])
        state = jnp.concatenate(
            [gcaches[1].reshape((-1,) + gcaches[1].shape[2:]), tcaches[1]])
        cache = cache._replace(conv=conv, state=state,
                               k=pad_seq(ks, 3), v=pad_seq(vs, 3))
    else:
        raise ValueError(f"{cfg.name}: prefill unsupported for family "
                         f"{cfg.family}")

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if true_len is None:
        last = x[:, -1]
    else:   # last REAL token of each (possibly bucket-padded) prompt
        last = jnp.take_along_axis(x, (lens - 1)[:, None, None],
                                   axis=1)[:, 0]
    logits = jnp.einsum("bd,dv->bv", last, head)
    return logits, cache._replace(lengths=lens)


def prefill_suffix(cfg: ModelConfig, params: Params, tokens: jax.Array,
                   prefix_k: jax.Array, prefix_v: jax.Array,
                   prefix_len: jax.Array, *,
                   true_len: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Suffix-only prefill for prefix-cache admissions (PR 7).

    Processes only the NOVEL tail of a prompt whose first ``prefix_len``
    tokens already have cache-resident K/V (gathered from the paged pool
    through the sharer's block table). By causality the result is
    exactly what a from-scratch prefill would produce for the suffix
    positions — zero compute for the shared prefix is the whole point.

    tokens: (B, S) suffix tokens, right-padded to a bucket;
    prefix_k/v: (L, B, Hkv, P, dh) logical layout, live below
    ``prefix_len`` (zeros past it — masked inside attention anyway);
    prefix_len: (B,) cached tokens per row; true_len: real suffix
    length per row (``None`` = all of S).

    Returns (logits at the last real suffix token (B, V), suffix K/V
    (L, B, Hkv, S, dh)). GQA-cache families only — the same constraint
    as the paged pool itself.
    """
    if not (cfg.family == "dense"
            or (cfg.family == "moe" and cfg.mla is None)):
        raise ValueError(
            f"suffix prefill needs a token-only GQA cache; family "
            f"{cfg.family} is not supported")
    B, S = tokens.shape
    x = params["embed"][tokens]
    plen = jnp.broadcast_to(jnp.asarray(prefix_len, jnp.int32), (B,))
    if true_len is None:
        slen = jnp.full((B,), S, jnp.int32)
    else:
        slen = jnp.broadcast_to(jnp.asarray(true_len, jnp.int32), (B,))

    def body(carry, inp):
        h = carry
        layer, pk_l, pv_l = inp
        hn = rms_norm(h, layer["ln1"], cfg.rms_eps)
        attn_out, k, v = attn_mod.attention_prefill_with_prefix(
            _attn_params(layer), hn, pk_l, pv_l, plen,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            d_head=cfg.head_dim, rope_theta=cfg.rope_theta,
            rms_eps=cfg.rms_eps)
        h = h + attn_out
        hn = rms_norm(h, layer["ln2"], cfg.rms_eps)
        if cfg.moe is not None:
            ffn, _ = moe_mod.moe_forward(_moe_params(layer), hn, cfg.moe)
        else:
            m = layer["mlp"]
            ffn = swiglu(hn, m["gate"], m["up"], m["down"])
        return h + ffn, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"],
                                         prefix_k, prefix_v))
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    last = jnp.take_along_axis(x, (slen - 1)[:, None, None], axis=1)[:, 0]
    return jnp.einsum("bd,dv->bv", last, head), ks, vs


# ============================================================ decode
class DecodeCache(NamedTuple):
    """Stacked per-layer decode state. Unused fields are size-0 arrays so
    the pytree structure is family-independent under scan.

    ``pk``/``pv`` are the paged warm/cold-tier KV pools of the serving
    fast path (see ``repro.serving.paged_kv``): one shared block pool per
    layer, final physical block a write sentinel. They are size-0 unless
    the cache is created with ``paged_blocks > 0``; when present,
    ``decode_step`` mirrors each appended token into its mapped block
    (``paged_append`` operand) so warm/cold attention reads can go
    through per-request block tables while the dense ``k``/``v`` buffers
    keep serving the hot tier.
    """
    k: jax.Array            # (L, B, Hkv, Smax, dh)  GQA
    v: jax.Array
    ckv: jax.Array          # (L, B, Smax, r)        MLA latent
    krope: jax.Array        # (L, B, Smax, dr)
    conv: jax.Array         # (L, B, ck-1, conv_dim) SSM
    state: jax.Array        # (L, B, H, N, P)
    pk: jax.Array           # (L, NB+1, bs, Hkv, dh) paged KV pool (K)
    pv: jax.Array           # (L, NB+1, bs, Hkv, dh) paged KV pool (V)
    lengths: jax.Array      # (B,) tokens already cached


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                      paged_blocks: int = 0, block_size: int = 0,
                      hot_window: int = 0) -> DecodeCache:
    """Decode cache for ``batch`` sequences of up to ``max_len`` tokens.

    ``paged_blocks``/``block_size`` > 0 additionally allocates the paged
    KV pools (``paged_blocks`` allocatable blocks + 1 sentinel) for the
    serving engine's block-table decode path — GQA-cache families only.

    ``hot_window`` > 0 shrinks the dense ``k``/``v`` buffers to a
    hot-sized RING of that many slots (absolute position p at slot
    ``p % hot_window``): per-slot hot-tier bytes stop scaling with
    ``max_len`` — warm/cold tokens exist only in the paged pools, which
    is why a ring cache requires ``paged_blocks`` (the capacity tier
    backs every evicted token).
    """
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    z = lambda *s: jnp.zeros(s, dtype)
    zf = lambda *s: jnp.zeros(s, jnp.float32)
    # distinct arrays per field: a shared size-0 buffer would be donated
    # twice by the serving engine's donated decode dispatch
    k, v = z(0), z(0)
    ckv, krope = z(0), z(0)
    conv, state = z(0), z(0)
    pk, pv = z(0), z(0)
    if hot_window and not paged_blocks:
        raise ValueError("a hot-window ring cache needs paged pools to "
                         "back evicted tokens (paged_blocks > 0)")
    kv_len = min(hot_window, max_len) if hot_window else max_len
    if paged_blocks:
        if not (cfg.family in ("dense", "vlm")
                or (cfg.family == "moe" and cfg.mla is None)):
            raise ValueError(
                f"paged KV pools require a GQA k/v cache; family "
                f"{cfg.family} stores none")
        pk = z(L, paged_blocks + 1, block_size, cfg.n_kv_heads,
               cfg.head_dim)
        pv = z(L, paged_blocks + 1, block_size, cfg.n_kv_heads,
               cfg.head_dim)
    if cfg.family in ("dense", "vlm"):
        k = z(L, batch, cfg.n_kv_heads, kv_len, cfg.head_dim)
        v = z(L, batch, cfg.n_kv_heads, kv_len, cfg.head_dim)
    elif cfg.family == "moe":
        if cfg.mla is not None:
            ckv = z(L, batch, max_len, cfg.mla.kv_lora_rank)
            krope = z(L, batch, max_len, cfg.mla.qk_rope_head_dim)
        else:
            k = z(L, batch, cfg.n_kv_heads, kv_len, cfg.head_dim)
            v = z(L, batch, cfg.n_kv_heads, kv_len, cfg.head_dim)
    elif cfg.family == "ssm":
        di, H, conv_dim = ssm_mod._dims(cfg.d_model, cfg.ssm)
        conv = z(L, batch, cfg.ssm.conv_kernel - 1, conv_dim)
        state = zf(L, batch, H, cfg.ssm.d_state, cfg.ssm.head_dim)
    elif cfg.family == "hybrid":
        hb = cfg.hybrid
        n_mamba = hb.n_groups * hb.mamba_per_group + hb.tail_mamba
        di, H, conv_dim = ssm_mod._dims(cfg.d_model, cfg.ssm)
        conv = z(n_mamba, batch, cfg.ssm.conv_kernel - 1, conv_dim)
        state = zf(n_mamba, batch, H, cfg.ssm.d_state, cfg.ssm.head_dim)
        # one KV cache per shared-attn application site
        k = z(hb.n_groups, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
        v = z(hb.n_groups, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    else:
        raise ValueError(f"family {cfg.family} has no decode step")
    return DecodeCache(k=k, v=v, ckv=ckv, krope=krope, conv=conv,
                       state=state, pk=pk, pv=pv,
                       lengths=jnp.zeros((batch,), jnp.int32))


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: DecodeCache, *,
                decode_attn_fn: Optional[Callable] = None,
                latent_attn_fn: Optional[Callable] = None,
                paged_append: Optional[tuple] = None
                ) -> tuple[jax.Array, DecodeCache, Optional[jax.Array]]:
    """One autoregressive step. tokens: (B,) int32. Returns
    (logits (B, V), new cache, scores (B, Smax) | None).

    ``decode_attn_fn`` injects the PAM / distributed attention
    implementation. ``scores`` is the layer-mean per-token attention mass
    S_i(j) feeding PAM's importance EMA (None for attention-free archs).

    When the cache carries paged pools (``cache.pk.size > 0``),
    ``paged_append=(dst_block, dst_slot)`` — (B,) physical block + slot
    per sequence, sentinel-routed for inactive rows — must be supplied;
    each layer then mirrors its appended K/V into the pool and
    ``decode_attn_fn`` is called with the per-layer pool slices
    ``(q, k_cache, v_cache, pk, pv, kv_lens)``.
    """
    if not cfg.has_decode:
        raise ValueError(f"{cfg.name} is encoder-only")
    d_fn = decode_attn_fn or attn_mod.dense_decode_attn
    l_fn = latent_attn_fn or mla_mod.mla_latent_decode_attn
    x = params["embed"][tokens]                       # (B, d)
    lens = cache.lengths
    scores: Optional[jax.Array] = None
    use_paged = cache.pk.size > 0
    if use_paged and paged_append is None:
        raise ValueError("cache has paged KV pools; decode_step requires "
                         "paged_append=(dst_block, dst_slot)")

    if cfg.family in ("dense", "vlm") or (cfg.family == "moe"
                                          and cfg.mla is None):
        def body(carry, inp):
            h = carry
            if use_paged:
                layer, kc, vc, pk, pv = inp
                paged = (pk, pv) + tuple(paged_append)
            else:
                layer, kc, vc = inp
                paged = None
            hn = rms_norm(h, layer["ln1"], cfg.rms_eps)
            res = attn_mod.attention_decode(
                _attn_params(layer), hn, kc, vc, lens,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                d_head=cfg.head_dim, rope_theta=cfg.rope_theta,
                rms_eps=cfg.rms_eps, decode_attn_fn=d_fn, paged=paged)
            if use_paged:
                attn_out, mass, kc, vc, pk, pv = res
            else:
                attn_out, mass, kc, vc = res
            h = h + attn_out
            hn = rms_norm(h, layer["ln2"], cfg.rms_eps)
            if cfg.moe is not None:
                ffn, _ = moe_mod.moe_forward(_moe_params(layer),
                                             hn[:, None], cfg.moe)
                ffn = ffn[:, 0]
            else:
                m = layer["mlp"]
                ffn = swiglu(hn, m["gate"], m["up"], m["down"])
            ys = (kc, vc, pk, pv, mass) if use_paged else (kc, vc, mass)
            return h + ffn, ys

        if use_paged:
            x, (k_new, v_new, pk_new, pv_new, masses) = jax.lax.scan(
                body, x, (params["layers"], cache.k, cache.v,
                          cache.pk, cache.pv))
            cache = cache._replace(k=k_new, v=v_new, pk=pk_new, pv=pv_new)
        else:
            x, (k_new, v_new, masses) = jax.lax.scan(
                body, x, (params["layers"], cache.k, cache.v))
            cache = cache._replace(k=k_new, v=v_new)
        scores = jnp.mean(masses, axis=0)

    elif cfg.family == "moe":                          # MLA path
        def body(carry, inp):
            h = carry
            layer, ckv, krp = inp
            hn = rms_norm(h, layer["ln1"], cfg.rms_eps)
            a = layer["mla"]
            attn_out, mass, ckv, krp = mla_mod.mla_decode(
                mla_mod.MLAParams(**a), hn, ckv, krp, lens, cfg.mla,
                n_heads=cfg.n_heads, rope_theta=cfg.rope_theta,
                rms_eps=cfg.rms_eps, latent_attn_fn=l_fn)
            h = h + attn_out
            hn = rms_norm(h, layer["ln2"], cfg.rms_eps)
            ffn, _ = moe_mod.moe_forward(_moe_params(layer), hn[:, None],
                                         cfg.moe)
            return h + ffn[:, 0], (ckv, krp, mass)

        x, (ckv_new, krp_new, masses) = jax.lax.scan(
            body, x, (params["layers"], cache.ckv, cache.krope))
        cache = cache._replace(ckv=ckv_new, krope=krp_new)
        scores = jnp.mean(masses, axis=0)

    elif cfg.family == "ssm":
        def body(carry, inp):
            h = carry
            layer, conv, st = inp
            hn = rms_norm(h, layer["ln"], cfg.rms_eps)
            out, new = ssm_mod.ssm_decode(
                ssm_mod.SSMParams(**layer["ssm"]), hn,
                ssm_mod.SSMCache(conv, st), cfg.ssm, rms_eps=cfg.rms_eps)
            return h + out, (new.conv, new.state)

        x, (conv_new, state_new) = jax.lax.scan(
            body, x, (params["layers"], cache.conv, cache.state))
        cache = cache._replace(conv=conv_new, state=state_new)

    elif cfg.family == "hybrid":
        hb = cfg.hybrid
        npg = hb.mamba_per_group
        shared = params["shared_attn"]

        def mamba_body(carry, inp):
            h = carry
            layer, conv, st = inp
            hn = rms_norm(h, layer["ln"], cfg.rms_eps)
            out, new = ssm_mod.ssm_decode(
                ssm_mod.SSMParams(**layer["ssm"]), hn,
                ssm_mod.SSMCache(conv, st), cfg.ssm, rms_eps=cfg.rms_eps)
            return h + out, (new.conv, new.state)

        n_grp_mamba = hb.n_groups * npg
        conv_g = cache.conv[:n_grp_mamba].reshape(
            (hb.n_groups, npg) + cache.conv.shape[1:])
        state_g = cache.state[:n_grp_mamba].reshape(
            (hb.n_groups, npg) + cache.state.shape[1:])

        def group_body(carry, inp):
            h = carry
            layers, conv, st, kc, vc = inp
            h, (conv, st) = jax.lax.scan(mamba_body, h, (layers, conv, st))
            hn = rms_norm(h, shared["ln1"], cfg.rms_eps)
            attn_out, mass, kc, vc = attn_mod.attention_decode(
                _attn_params(shared), hn, kc, vc, lens,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                d_head=cfg.head_dim, rope_theta=cfg.rope_theta,
                rms_eps=cfg.rms_eps, decode_attn_fn=d_fn)
            h = h + attn_out
            hn = rms_norm(h, shared["ln2"], cfg.rms_eps)
            m = shared["mlp"]
            h = h + swiglu(hn, m["gate"], m["up"], m["down"])
            return h, (conv, st, kc, vc, mass)

        x, (conv_g, state_g, k_new, v_new, masses) = jax.lax.scan(
            group_body, x,
            (params["mamba_groups"], conv_g, state_g, cache.k, cache.v))
        scores = jnp.mean(masses, axis=0)
        x, (conv_t, state_t) = jax.lax.scan(
            mamba_body, x,
            (params["tail_mamba"], cache.conv[n_grp_mamba:],
             cache.state[n_grp_mamba:]))
        cache = cache._replace(
            conv=jnp.concatenate(
                [conv_g.reshape((-1,) + conv_g.shape[2:]), conv_t]),
            state=jnp.concatenate(
                [state_g.reshape((-1,) + state_g.shape[2:]), state_t]),
            k=k_new, v=v_new)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x, head)
    return logits, cache._replace(lengths=lens + 1), scores
