"""End-to-end engine benchmark: the REAL serving engine (control flow,
continuous batching, PAM importance/scheduling state) accounted with the
paper's hardware timing model — the closest analogue of the paper's
simulator runs, with the actual algorithm state (tier reads, hit rates,
migrations) driving the clock."""

from __future__ import annotations

import time

import numpy as np

from repro.perfmodel.model import (PAM_LLAMA_7B, SystemKind, make_system)
from repro.perfmodel.latency import make_latency_model


def bench_engine() -> list[tuple]:
    import jax
    import jax.numpy as jnp  # noqa: F401
    from repro.models import transformer as tf
    from repro.models.config import get_config, reduced
    from repro.serving import (EngineSpec, PAMManagerConfig, Request,
                               ServingConfig)

    cfg = reduced(get_config("pam-llama-7b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    rows = []
    results = {}
    for name, kind, pam_on in (
            ("pam", SystemKind.PAM, True),
            ("ls-pim", SystemKind.LSPIM, True),
            ("vllm-offload", SystemKind.VLLM_OFFLOAD, False)):
        system = make_system(kind)
        pam_cfg = PAMManagerConfig(
            max_tokens=96, hot_capacity=16, warm_capacity=32,
            compression=4, recency_window=4,
            schedule_interval=2,
            use_tiering=(kind == SystemKind.PAM)) if pam_on else None
        eng = EngineSpec(
            model=cfg,
            serving=ServingConfig(max_batch=4, max_len=96,
                                  pam=pam_cfg)).build(
            params,
            # 16384 hardware tokens per engine token: exercises the tiered
            # hierarchy at paper scale (see perfmodel.latency)
            latency_model=make_latency_model(system, PAM_LLAMA_7B,
                                             context_scale=16384))
        for i in range(8):
            eng.submit(Request(id=i,
                               prompt=rng.integers(0, cfg.vocab, 24),
                               max_new_tokens=16))
        summary = eng.run()
        results[name] = summary
        rows.append((f"engine/{name}",
                     summary["p50_tpot_s"] * 1e6,
                     f"sim_tput={summary['throughput_tok_s']:.0f}tok/s "
                     f"p99_tpot_us={summary['p99_tpot_s']*1e6:.0f}"))
    ratio = (results["vllm-offload"]["p50_tpot_s"]
             / max(results["pam"]["p50_tpot_s"], 1e-9))
    rows.append(("engine/pam_vs_vllm", 0.0,
                 f"p50_tpot_speedup={ratio:.2f}x"))
    return rows


def bench_decode_wallclock(micro_steps: int = 8) -> dict:
    """REAL wall-clock decode throughput of the serving engine on the
    current backend (no latency model): the fused-dispatch fast path's
    tokens/s and device dispatches per decode step. PAM config, batch 4.

    Also runs the paged warm/cold configuration (block_size 8) and
    records its sparse-read accounting: pool occupancy and pages touched
    per step vs the dense window — the paged gather's win."""
    import jax
    from repro.models import transformer as tf
    from repro.models.config import get_config, reduced
    from repro.serving import (EngineSpec, PAMManagerConfig, Request,
                               ServingConfig)

    cfg = reduced(get_config("pam-llama-7b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    pam_cfg = PAMManagerConfig(
        max_tokens=96, hot_capacity=16, warm_capacity=32,
        compression=4, recency_window=4, schedule_interval=2)
    # paged runs: hot tier smaller than the participation budget so the
    # working set spills into warm — the block-table gather must engage
    pam_paged = PAMManagerConfig(
        max_tokens=96, hot_capacity=8, warm_capacity=32,
        compression=4, recency_window=4, schedule_interval=2)

    def one_run(micro: int, block_size: int = 0,
                hot_window: int = 0) -> dict:
        rng = np.random.default_rng(0)
        eng = EngineSpec(model=cfg, serving=ServingConfig(
            max_batch=4, max_len=96,
            pam=(pam_paged if block_size else pam_cfg),
            micro_steps=micro, block_size=block_size,
            hot_window=hot_window)).build(params)
        for i in range(8):
            eng.submit(Request(id=i, prompt=rng.integers(0, cfg.vocab, 24),
                               max_new_tokens=16))
        t0 = time.perf_counter()
        summary = eng.run()
        wall = time.perf_counter() - t0
        out = {
            "micro_steps": micro,
            "wall_s": wall,
            "decode_tok_s": summary["total_tokens"] / wall,
            "decode_dispatches": summary["decode_dispatches"],
            "decode_device_steps": summary["decode_device_steps"],
            "dispatches_per_step": (summary["decode_dispatches"]
                                    / max(summary["decode_device_steps"],
                                          1)),
        }
        if block_size:
            out["block_size"] = block_size
            out["blocks_touched_per_step"] = \
                summary["blocks_touched_per_step"]
            out["blocks_window_per_step"] = \
                summary["blocks_window_per_step"]
            out["page_read_fraction"] = (
                summary["blocks_touched_per_step"]
                / max(summary["blocks_window_per_step"], 1e-9))
            out["pool_occupancy_peak"] = summary["pool_occupancy_peak"]
            out["hot_window"] = summary["hot_window"]
            out["hot_bytes_per_slot"] = summary["hot_bytes_per_slot"]
        return out

    variants = ((1, 0, 0), (micro_steps, 0, 0), (1, 8, 0),
                (micro_steps, 8, 0), (1, 8, 32), (micro_steps, 8, 32))
    for micro, bsz, hw in variants:
        one_run(micro, bsz, hw)                # warm the jit caches
    return {"fused": one_run(1), "micro": one_run(micro_steps),
            "paged": one_run(1, block_size=8),
            "paged_micro": one_run(micro_steps, block_size=8),
            "ring": one_run(1, block_size=8, hot_window=32),
            "ring_micro": one_run(micro_steps, block_size=8,
                                  hot_window=32),
            "backend": jax.default_backend()}


def bench_hot_window_scaling(smax_list=(1024, 4096, 16384),
                             hot_window: int = 64,
                             block_size: int = 64) -> dict:
    """The PR 5 capacity headline: hot-tier bytes per batch slot as a
    function of ``max_len``. With the ring the number is CONSTANT (the
    ring holds ``hot_window`` tokens regardless of context budget);
    the pre-ring dense buffer scaled linearly — that line is reported as
    ``dense_equiv_bytes_per_slot`` for the trajectory plot. Each point
    also decodes a short burst for a sanity tokens/s reading."""
    import jax
    from repro.models import transformer as tf
    from repro.models.config import get_config, reduced
    from repro.serving import (EngineSpec, PAMManagerConfig, Request,
                               ServingConfig)

    cfg = reduced(get_config("pam-llama-7b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    points = {}
    for smax in smax_list:
        pam = PAMManagerConfig(
            max_tokens=smax, hot_capacity=16, warm_capacity=64,
            compression=4, recency_window=4, schedule_interval=2)
        eng = EngineSpec(model=cfg, serving=ServingConfig(
            max_batch=2, max_len=smax, pam=pam, block_size=block_size,
            # small pool: each request maps only its own window's blocks
            pool_blocks=8, hot_window=hot_window)).build(params)
        rng = np.random.default_rng(0)
        for i in range(4):
            eng.submit(Request(id=i,
                               prompt=rng.integers(0, cfg.vocab, 24),
                               max_new_tokens=8))
        t0 = time.perf_counter()
        summary = eng.run()
        wall = time.perf_counter() - t0
        kv_elt_bytes = (summary["hot_bytes_per_slot"]
                        // (2 * hot_window))    # k+v, per token per slot
        points[str(smax)] = {
            "hot_bytes_per_slot": summary["hot_bytes_per_slot"],
            "dense_equiv_bytes_per_slot": 2 * kv_elt_bytes * smax,
            "decode_tok_s": summary["total_tokens"] / wall,
            "dispatches_per_step": (summary["decode_dispatches"]
                                    / max(summary["decode_device_steps"],
                                          1)),
        }
    vals = [p["hot_bytes_per_slot"] for p in points.values()]
    return {"hot_window": hot_window, "block_size": block_size,
            "points": points,
            "hot_bytes_per_slot": vals[0],
            "hot_bytes_constant_across_smax": len(set(vals)) == 1}


def wallclock_rows(result: dict) -> list[tuple]:
    rows = []
    for name in ("fused", "micro", "paged", "paged_micro", "ring",
                 "ring_micro"):
        r = result.get(name)
        if r is None:
            continue
        derived = (f"decode_tok_s={r['decode_tok_s']:.0f} "
                   f"dispatches_per_step={r['dispatches_per_step']:.3f}")
        if "blocks_touched_per_step" in r:
            derived += (f" pages_per_step={r['blocks_touched_per_step']:.1f}"
                        f"/{r['blocks_window_per_step']:.1f}"
                        f" pool_occ={r['pool_occupancy_peak']:.2f}")
        if r.get("hot_window"):
            derived += (f" hot_window={r['hot_window']}"
                        f" hot_bytes_per_slot={r['hot_bytes_per_slot']}")
        rows.append((f"engine/wallclock_{name}_k{r['micro_steps']}",
                     r["wall_s"] * 1e6 / max(r["decode_device_steps"], 1),
                     derived))
    return rows


def hot_window_rows(result: dict) -> list[tuple]:
    rows = []
    for smax, p in result["points"].items():
        rows.append((f"engine/hot_bytes_smax{smax}",
                     0.0,
                     f"hot_bytes_per_slot={p['hot_bytes_per_slot']} "
                     f"dense_equiv={p['dense_equiv_bytes_per_slot']} "
                     f"decode_tok_s={p['decode_tok_s']:.0f}"))
    rows.append(("engine/hot_bytes_constant", 0.0,
                 f"constant_across_smax="
                 f"{result['hot_bytes_constant_across_smax']} "
                 f"(ring W={result['hot_window']})"))
    return rows
