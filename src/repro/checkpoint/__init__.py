"""Fault-tolerant checkpointing: atomic sharded save/restore + manager."""

from repro.checkpoint.manager import (CheckpointManager, restore_pytree,
                                      save_pytree)

__all__ = ["CheckpointManager", "restore_pytree", "save_pytree"]
