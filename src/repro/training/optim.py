"""Optimizers + LR schedules (pure-pytree, no external deps).

AdamW with fp32 master moments over bf16 params, global-norm clipping, and
the WSD (warmup-stable-decay) schedule MiniCPM trains with
[arXiv:2404.06395] alongside standard cosine.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Pytree          # fp32
    nu: Pytree          # fp32


def adamw_init(params: Pytree) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads: Pytree, state: AdamWState,
                 params: Pytree) -> tuple[Pytree, AdamWState, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cfg.lr(step) if callable(cfg.lr) else jnp.float32(cfg.lr)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step)
        vhat = v / (1 - cfg.b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), gnorm


# --------------------------------------------------------------- schedules
def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup, warm, cos)
    return lr


def wsd_schedule(peak: float, warmup: int, stable: int, decay: int,
                 floor: float = 0.01):
    """MiniCPM's Warmup-Stable-Decay: linear warmup -> constant plateau ->
    exponential-ish (here: linear-in-log) decay to floor*peak."""
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        t = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = peak * jnp.exp(jnp.log(jnp.maximum(floor, 1e-8)) * t)
        return jnp.where(s < warmup, warm,
                         jnp.where(s < warmup + stable, peak, dec))
    return lr
