#!/usr/bin/env bash
# Repo verification: the tier-1 test suite + a fast benchmark smoke.
# Usage: scripts/verify.sh [--fast]         (--fast skips the bench smoke)
#        scripts/verify.sh --bench-only     (bench smoke only — CI reuses
#                                            it after its own pytest job)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
BENCH_OUT="${BENCH_OUT:-/tmp/BENCH_smoke.json}"
TRACE_OUT="${TRACE_OUT:-/tmp/pam_trace_smoke.json}"

if [[ "${1:-}" != "--bench-only" ]]; then
    echo "== tier-1 tests =="
    python -m pytest -x -q
fi

if [[ "${1:-}" != "--fast" ]]; then
    echo "== bench smoke (engine section) =="
    python -m benchmarks.run --section engine --out "$BENCH_OUT"
    # asserts: 1 fused dispatch/step, decode tok/s floor, paged sparse
    # read, hot-tier bytes/slot constant across Smax (ring invariant)
    python scripts/check_bench.py "$BENCH_OUT" "${TOK_S_FLOOR:-100}"

    echo "== cluster smoke (2 device classes, migration exactness) =="
    python scripts/cluster_smoke.py

    echo "== chaos smoke (1 injected kill, replay exactness) =="
    python scripts/chaos_smoke.py

    echo "== serving smoke (front end: stream exactness, chunked prefill, SLO) =="
    python scripts/serving_smoke.py

    echo "== trace smoke (telemetry: schema-valid chaos trace artifact) =="
    TRACE_OUT="$TRACE_OUT" python scripts/trace_smoke.py
fi
echo "verify OK"
