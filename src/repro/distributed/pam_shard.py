"""Distributed PAMattention (paper Alg. 1 across devices) via shard_map.

Layout: KV caches sequence-sharded on the ``model`` mesh axis — each device
plays the role of one PIM site holding its KV partition. One decode step:

  local stage   : each device attends its own KV shard -> (O, m, l)
  merge stage   : exact online-softmax reduction across the axis —
                  m* = pmax(m);  O = psum(e^{m-m*} O);  l = psum(e^{m-m*} l)

The merge communicates H x (d + 2) floats per device — independent of
context length. A gather-based scheme would move the whole KV shard
(S_local x H_kv x d); this is the paper's "reduce communication" claim,
and the collective-bytes delta shows up directly in the dry-run roofline.

``sequence_sharded_decode_attn`` plugs straight into
``transformer.decode_step(decode_attn_fn=...)``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro import compat  # noqa: F401  (backfills jax.shard_map on 0.4)

from jax.sharding import Mesh, PartitionSpec as P


def make_sequence_sharded_decode_attn(mesh: Mesh, *, axis: str = "model",
                                      dp=None):
    """Returns a decode_attn_fn (q, k_cache, v_cache, kv_lens) -> (out,
    mass) computing PAMattention with KV sequence-sharded over ``axis``.

    q: (B, H, dh) replicated over ``axis``; caches (B, Hkv, S, dh) sharded
    on S; kv_lens (B,). ``mass`` is returned sequence-sharded-consistent
    (global (B, S) array, sharded like the cache on its S axis).
    """

    def local_fn(q, k, v, kv_lens):
        # shapes here are PER-SHARD: k/v (B, Hkv, S_loc, dh)
        B, H, dh = q.shape
        Hkv, S_loc = k.shape[1], k.shape[2]
        rep = H // Hkv
        scale = 1.0 / math.sqrt(dh)
        shard = jax.lax.axis_index(axis)
        start = shard * S_loc
        pos = start + jnp.arange(S_loc)                    # global positions
        live = pos[None, :] < kv_lens[:, None]             # (B, S_loc)

        # grouped (GQA) form: NO jnp.repeat KV expansion — query heads are
        # contracted against their shared kv head directly
        qg = q.reshape(B, Hkv, rep, dh)
        s = jnp.einsum("bgrd,bgsd->bgrs", qg.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        s = jnp.where(live[:, None, None, :], s, -jnp.inf)

        # ---- local partial (Alg. 1 Local_Attention) ----------------------
        m_loc = jnp.max(s, axis=-1)                        # (B, Hkv, rep)
        m_safe = jnp.where(jnp.isfinite(m_loc), m_loc, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(live[:, None, None, :], p, 0.0)
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bgrs,bgsd->bgrd", p, v.astype(jnp.float32))

        # ---- inter-device reduction (Alg. 1 Reduction) --------------------
        m_star = jax.lax.pmax(m_loc, axis)
        m_star_safe = jnp.where(jnp.isfinite(m_star), m_star, 0.0)
        w = jnp.where(jnp.isfinite(m_loc),
                      jnp.exp(m_loc - m_star_safe), 0.0)   # (B, Hkv, rep)
        o = jax.lax.psum(w[..., None] * o_loc, axis)
        l = jax.lax.psum(w * l_loc, axis)
        l_safe = jnp.where(l > 0, l, 1.0)
        out = (o / l_safe[..., None]).reshape(B, H, dh).astype(q.dtype)

        # per-token mass on MY shard, normalized by the global (m*, l)
        p_norm = (p * w[..., None]) / l_safe[..., None]
        n_live = jax.lax.psum(jnp.sum(live, axis=-1), axis)  # (B,)
        mass = (jnp.mean(p_norm, axis=(1, 2))
                * n_live[:, None].astype(jnp.float32))
        return out, mass

    return jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp), P(dp, None, axis, None), P(dp, None, axis, None),
                  P(dp)),
        out_specs=(P(dp), P(dp, axis)),
        check_vma=False,
    )


def fused_update_decode(q, k_cache, v_cache, k_new, v_new, kv_lens, *,
                        axis: str = "model"):
    """§Perf ``pam_shard_decode``: one shard_map doing BOTH the new-token
    cache write and PAMattention over the sequence-sharded cache.

    The baseline lets GSPMD lower ``cache.at[b, :, pos].set(new)`` on a
    sequence-sharded axis, which materializes a gather of the whole cache;
    here each shard applies the write only if ``pos`` falls in its range
    (a masked local dynamic-update), then computes its local partial and
    joins the exact psum merge. Uses the ambient abstract mesh.

    q: (B, H, dh); caches (B, Hkv, S, dh) sequence-sharded on ``axis``;
    k_new/v_new: (B, Hkv, dh); kv_lens: (B,) pre-append lengths.
    Returns (out, mass, k_cache, v_cache).
    """
    from repro.models import perf_flags
    mesh = perf_flags.abstract_mesh()
    B = q.shape[0]
    dp: tuple | None = tuple(a for a in mesh.axis_names
                             if a in ("pod", "data")) or None
    if dp is not None:
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        if B % dp_size:
            dp = None

    def local(q, kc, vc, kn, vn, lens):
        Bl, H, dh = q.shape
        Hkv, S_loc = kc.shape[1], kc.shape[2]
        rep = H // Hkv
        scale = 1.0 / math.sqrt(dh)
        shard = jax.lax.axis_index(axis)
        start = shard * S_loc

        # ---- masked local cache write (the paper's intra-device mapping:
        # the owning bank group takes the token; everyone else no-ops) ----
        pos_local = lens - start
        in_range = (pos_local >= 0) & (pos_local < S_loc)
        safe = jnp.clip(pos_local, 0, S_loc - 1)
        bidx = jnp.arange(Bl)
        old_k = kc[bidx, :, safe]
        old_v = vc[bidx, :, safe]
        kc = kc.at[bidx, :, safe].set(
            jnp.where(in_range[:, None, None], kn, old_k))
        vc = vc.at[bidx, :, safe].set(
            jnp.where(in_range[:, None, None], vn, old_v))

        # ---- local partial + exact psum merge (Alg. 1) -------------------
        # grouped (GQA) form: NO jnp.repeat — the baseline materializes
        # rep x the KV shard; here queries are grouped per kv head instead
        live = (start + jnp.arange(S_loc))[None, :] < (lens + 1)[:, None]
        qg = q.reshape(Bl, Hkv, rep, dh)
        # bf16 operands read directly, fp32 accumulate: no cast copy of the
        # KV shard (iteration 3 of §Perf cell A)
        s = jnp.einsum("bgrd,bgsd->bgrs", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(live[:, None, None, :], s, -jnp.inf)
        m_loc = jnp.max(s, axis=-1)                        # (B, Hkv, rep)
        m_safe = jnp.where(jnp.isfinite(m_loc), m_loc, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(live[:, None, None, :], p, 0.0)
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bgrs,bgsd->bgrd", p, vc,
                           preferred_element_type=jnp.float32)

        m_star = jax.lax.pmax(m_loc, axis)
        m_star_safe = jnp.where(jnp.isfinite(m_star), m_star, 0.0)
        w = jnp.where(jnp.isfinite(m_loc),
                      jnp.exp(m_loc - m_star_safe), 0.0)
        o = jax.lax.psum(w[..., None] * o_loc, axis)
        l = jax.lax.psum(w * l_loc, axis)
        l_safe = jnp.where(l > 0, l, 1.0)
        out = (o / l_safe[..., None]).reshape(Bl, H, dh).astype(q.dtype)

        p_norm = (p * w[..., None]) / l_safe[..., None]    # (B,Hkv,rep,S)
        n_live = jax.lax.psum(jnp.sum(live, axis=-1), axis)
        mass = (jnp.mean(p_norm, axis=(1, 2))
                * n_live[:, None].astype(jnp.float32))
        return out, mass, kc, vc

    kv_spec = P(dp, None, axis, None)
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp), kv_spec, kv_spec, P(dp), P(dp), P(dp)),
        out_specs=(P(dp), P(dp, axis), kv_spec, kv_spec),
        check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, kv_lens)


def make_gather_based_decode_attn(mesh: Mesh, *, axis: str = "model",
                                  dp=None):
    """The L-PIM / request-level baseline (paper §3.3.1 C1): all-gather the
    KV shards to every device, then attend locally. Same numerics, O(S)
    collective bytes — kept as the ablation/benchmark counterpart."""

    def local_fn(q, k, v, kv_lens):
        k_full = jax.lax.all_gather(k, axis, axis=2, tiled=True)
        v_full = jax.lax.all_gather(v, axis, axis=2, tiled=True)
        from repro.models.attention import dense_decode_attn
        return dense_decode_attn(q, k_full, v_full, kv_lens)

    return jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp), P(dp, None, axis, None), P(dp, None, axis, None),
                  P(dp)),
        out_specs=(P(dp), P(dp, None)),
        check_vma=False,
    )
