"""Routed top-k MoE with shared experts (Qwen3-MoE / DeepSeek-V2 style).

Sort-based capacity dispatch (MegaBlocks-style, dense-shape form):
tokens are ranked per expert, gathered into an (E, C, d) batch, processed
with one batched matmul per projection, and combined by gate weight.
Expert-parallel sharding shards the leading E axis of both the expert
weights and the (E, C, d) dispatch buffers over the `model` mesh axis —
XLA inserts the all-to-all pair.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import MoEConfig
from repro.models.layers import init_linear


class MoEParams(NamedTuple):
    router: jax.Array       # (d, E)
    w_gate: jax.Array       # (E, d, f)
    w_up: jax.Array         # (E, d, f)
    w_down: jax.Array       # (E, f, d)
    shared_gate: jax.Array | None   # (d, n_shared*f) fused shared experts
    shared_up: jax.Array | None
    shared_down: jax.Array | None


def init_moe(key, d: int, cfg: MoEConfig, dtype) -> MoEParams:
    ks = jax.random.split(key, 7)
    E, f = cfg.num_experts, cfg.d_expert
    scale = 1.0 / math.sqrt(d)
    w_gate = (jax.random.normal(ks[1], (E, d, f), jnp.float32) * scale).astype(dtype)
    w_up = (jax.random.normal(ks[2], (E, d, f), jnp.float32) * scale).astype(dtype)
    w_down = (jax.random.normal(ks[3], (E, f, d), jnp.float32)
              / math.sqrt(f)).astype(dtype)
    sh = cfg.num_shared
    return MoEParams(
        router=init_linear(ks[0], d, E, jnp.float32),
        w_gate=w_gate, w_up=w_up, w_down=w_down,
        shared_gate=init_linear(ks[4], d, sh * f, dtype) if sh else None,
        shared_up=init_linear(ks[5], d, sh * f, dtype) if sh else None,
        shared_down=init_linear(ks[6], sh * f, d, dtype) if sh else None,
    )


def moe_forward(p: MoEParams, x: jax.Array, cfg: MoEConfig
                ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.top_k
    xf = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p.router)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)             # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=1),
        axis=0)
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # ---- sort-based dispatch with capacity ------------------------------
    cap = int(math.ceil(T * K / E * cfg.capacity_factor))
    flat_expert = expert_ids.reshape(T * K)                     # (TK,)
    flat_gate = gate_vals.reshape(T * K)
    flat_token = jnp.repeat(jnp.arange(T), K)

    # position of each assignment within its expert (stable by token order)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)    # (TK, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)
    pos_in_expert = jnp.take_along_axis(
        pos_in_expert, flat_expert[:, None], axis=1)[:, 0]      # (TK,)
    keep = pos_in_expert < cap
    slot = flat_expert * cap + pos_in_expert                    # (TK,) in [0, E*cap)
    slot = jnp.where(keep, slot, E * cap)                       # overflow -> sentinel

    # scatter token ids & gates into (E*cap,) dispatch table
    tok_table = jnp.full((E * cap + 1,), 0, jnp.int32).at[slot].set(
        flat_token.astype(jnp.int32))
    gate_table = jnp.zeros((E * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, flat_gate, 0.0))
    tok_table, gate_table = tok_table[:-1], gate_table[:-1]

    def _pin(t, spec):
        """§Perf ``moe_pin``: explicit expert-parallel constraints on the
        dispatch intermediates — without them GSPMD replicates the expert
        compute (measured ~200x the sharded ideal on the 235B MoE)."""
        from repro.models import perf_flags
        if not perf_flags.enabled("moe_pin"):
            return t
        import jax.sharding as jsh
        mesh = perf_flags.abstract_mesh()
        if not ("data" in mesh.axis_names and "model" in mesh.axis_names):
            return t
        ok = all(ax is None or t.shape[i] % mesh.shape[ax] == 0
                 for i, ax in enumerate(spec))
        return jax.lax.with_sharding_constraint(
            t, jsh.PartitionSpec(*spec)) if ok else t

    # §Perf cell C verdict: neither E-axis nor capacity-axis pins localize
    # the expert matmuls under GSPMD (see EXPERIMENTS.md §Perf — the
    # capacity-axis attempt made bytes 4x and collectives 7.6x WORSE);
    # gather-based dispatch needs explicit shard_map EP all_to_all.
    xe = xf[tok_table].reshape(E, cap, d)                       # (E, C, d)
    xe = _pin(xe, ("data", None, None))
    g = _pin(jnp.einsum("ecd,edf->ecf", xe, p.w_gate),
             ("data", None, "model"))
    u = _pin(jnp.einsum("ecd,edf->ecf", xe, p.w_up),
             ("data", None, "model"))
    ye = _pin(jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p.w_down),
              ("data", None, None))

    gates = gate_table.reshape(E, cap).astype(ye.dtype)
    y = jnp.zeros((T, d), ye.dtype).at[tok_table.reshape(E * cap)].add(
        (ye * gates[..., None]).reshape(E * cap, d))

    if p.shared_gate is not None:
        sg = jnp.einsum("td,df->tf", xf, p.shared_gate)
        su = jnp.einsum("td,df->tf", xf, p.shared_up)
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(sg) * su, p.shared_down)

    return y.reshape(B, S, d).astype(x.dtype), aux


def moe_forward_dense_oracle(p: MoEParams, x: jax.Array, cfg: MoEConfig
                             ) -> jax.Array:
    """No-capacity-drop oracle (every token reaches its experts) — used by
    tests to bound the dispatch path's drop error."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p.router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    y = jnp.zeros_like(xf)
    for e in range(cfg.num_experts):
        sel = (expert_ids == e)                                  # (T, K)
        w = jnp.sum(jnp.where(sel, gate_vals, 0.0), axis=-1)     # (T,)
        g = jnp.einsum("td,df->tf", xf, p.w_gate[e])
        u = jnp.einsum("td,df->tf", xf, p.w_up[e])
        ye = jnp.einsum("tf,fd->td", jax.nn.silu(g) * u, p.w_down[e])
        y = y + w[:, None].astype(ye.dtype) * ye
    if p.shared_gate is not None:
        sg = jnp.einsum("td,df->tf", xf, p.shared_gate)
        su = jnp.einsum("td,df->tf", xf, p.shared_up)
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(sg) * su, p.shared_down)
    return y.reshape(B, S, d).astype(x.dtype)
