"""Hot-window ring buffer (PR 5): per-slot hot-tier memory independent
of max_len, token streams pinned exact against a dense-Smax twin.

Covers the acceptance surface: ring-vs-dense-twin exactness on greedy
and micro_steps=8 configs, wraparound at exactly ``hot_window``, short
sequences (``true_len < hot_window`` — no eviction yet), an Alg. 2
promotion landing on the slot about to be evicted, migration of a
request mid-wrap (including across differing hot windows), hot-tier
bytes/slot constant across max_len, and the config validation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_pam

from repro.cluster.migration import migrate
from repro.core.tiers import HOT, WARM, clamp_hot_to_window
from repro.kernels.flash_decode import ring_position_map
from repro.models import transformer as tf
from repro.serving import EngineSpec, Request, ServingConfig

jax.config.update("jax_platform_name", "cpu")

WINDOW = 16


@pytest.fixture(scope="module")
def setup(llama_model):
    return llama_model


def _pam(max_len=64):
    return make_pam(max_len=max_len, hot=8, warm=16)


def _engine(cfg, params, *, max_len=64, block_size=0, hot_window=0,
            micro_steps=1, eos=-1, name="dev"):
    scfg = ServingConfig(max_batch=3, max_len=max_len, pam=_pam(max_len),
                         block_size=block_size, hot_window=hot_window,
                         micro_steps=micro_steps, eos_token=eos)
    return EngineSpec(model=cfg, serving=scfg, name=name).build(params)


def _run(eng, prompts, max_new=20):
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p, max_new_tokens=max_new))
    eng.run()
    return {i: eng.requests[i].outputs for i in range(len(prompts))}


def _prompts(n=4, plen=24, seed=0, vocab=256):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, plen) for _ in range(n)]


# ------------------------------------------------------------- unit level
def test_ring_position_map_identity_and_wrap():
    rp, va = ring_position_map(jnp.array([0, 3, 8, 13]), 8)
    rp, va = np.asarray(rp), np.asarray(va)
    assert not va[0].any()                       # empty sequence
    assert rp[1][:3].tolist() == [0, 1, 2]       # identity below window
    assert va[1].tolist() == [True] * 3 + [False] * 5
    assert va[2].all() and rp[2].tolist() == list(range(8))
    # len 13, W 8: slots hold positions 5..12, each congruent mod 8
    assert sorted(rp[3].tolist()) == list(range(5, 13))
    assert all(rp[3][j] % 8 == j for j in range(8))


def test_clamp_hot_to_window_demotes_evicted_tags():
    tier = jnp.full((1, 8), HOT, jnp.int32)
    out = np.asarray(clamp_hot_to_window(tier, jnp.array([6]), 4))
    assert out[0, :2].tolist() == [WARM, WARM]   # slid out of window
    assert (out[0, 2:] == HOT).all()             # in-window tags kept


# --------------------------------------------------- dense-twin exactness
def test_ring_stream_exact_vs_dense_twin_greedy(setup):
    """Sequences run to 44 tokens with a 16-slot ring: ~2 full wraps.
    The ring engine's token streams are identical to the pre-ring dense
    engine's, and the hot buffer really is ring-sized."""
    cfg, params = setup
    prompts = _prompts(vocab=cfg.vocab)
    dense = _run(_engine(cfg, params), prompts)
    ring_eng = _engine(cfg, params, block_size=8, hot_window=WINDOW)
    ring = _run(ring_eng, prompts)
    assert ring_eng.cache.k.shape[3] == WINDOW
    assert ring == dense


def test_ring_stream_exact_micro8(setup):
    cfg, params = setup
    prompts = _prompts(vocab=cfg.vocab)
    dense = _run(_engine(cfg, params), prompts)
    ring = _run(_engine(cfg, params, block_size=8, hot_window=WINDOW,
                        micro_steps=8), prompts)
    assert ring == dense


def test_ring_stream_exact_with_eos_on_device(setup):
    """EOS detection stays on-device with a ring hot tier (frozen slots
    rewrite their own ring slot idempotently)."""
    cfg, params = setup
    prompts = _prompts(vocab=cfg.vocab, seed=3)
    eos = int(_run(_engine(cfg, params), prompts, max_new=24)[0][5])
    dense = _run(_engine(cfg, params, eos=eos), prompts, max_new=24)
    ring = _run(_engine(cfg, params, block_size=8, hot_window=WINDOW,
                        micro_steps=4, eos=eos), prompts, max_new=24)
    assert ring == dense


# ------------------------------------------------------- boundary edges
def test_wraparound_at_exactly_window(setup):
    """Prompt length == hot_window: the commit fills every ring slot and
    the FIRST decode append wraps onto slot 0."""
    cfg, params = setup
    prompts = _prompts(n=3, plen=WINDOW, vocab=cfg.vocab, seed=1)
    dense = _run(_engine(cfg, params), prompts, max_new=12)
    ring = _run(_engine(cfg, params, block_size=8, hot_window=WINDOW),
                prompts, max_new=12)
    assert ring == dense


def test_short_sequence_no_eviction(setup):
    """true_len < hot_window: nothing is ever evicted and the ring is the
    identity layout — slot j holds position j. Prompt positions are
    bitwise the dense twin's (same prefill, re-laid out); decode-appended
    positions agree to float ulps (their activations flow through the
    merged two-partial attention instead of one softmax)."""
    cfg, params = setup
    plen = 6
    prompts = _prompts(n=2, plen=plen, vocab=cfg.vocab, seed=2)
    twin = _engine(cfg, params)
    dense = _run(twin, prompts, max_new=4)       # final length 9 < 16
    eng = _engine(cfg, params, block_size=8, hot_window=WINDOW)
    ring = _run(eng, prompts, max_new=4)
    assert ring == dense
    for slot in range(2):                        # admitted in order
        length = int(np.asarray(eng.cache.lengths[slot]))
        assert plen < length < WINDOW
        np.testing.assert_array_equal(
            np.asarray(eng.cache.k[:, slot, :, :plen]),
            np.asarray(twin.cache.k[:, slot, :, :plen]))
        np.testing.assert_allclose(
            np.asarray(eng.cache.k[:, slot, :, plen:length]),
            np.asarray(twin.cache.k[:, slot, :, plen:length]),
            rtol=1e-5, atol=1e-5)


def test_promotion_landing_on_about_to_evict_slot(setup):
    """Force an Alg. 2-style promotion of the exact position the next
    append will evict: the tier clamp re-tags it (no stale hot read of
    an overwritten slot) and the stream stays dense-twin exact."""
    cfg, params = setup
    prompts = _prompts(n=1, plen=24, vocab=cfg.vocab, seed=4)
    twin = _engine(cfg, params)
    eng = _engine(cfg, params, block_size=8, hot_window=WINDOW)
    for e in (twin, eng):
        e.submit(Request(id=0, prompt=prompts[0], max_new_tokens=20))
    for _ in range(4):                      # lengths: 24 -> 28
        twin.step()
        eng.step()
    slot = eng.requests[0].slot
    length = int(np.asarray(eng.cache.lengths[slot]))
    victim = length - WINDOW                # evicted by the NEXT append
    assert victim >= 0
    eng.pam_state = eng.pam_state._replace(
        tier=eng.pam_state.tier.at[slot, victim].set(HOT))
    while any(s is not None for s in eng.slots):
        eng.step()
    twin.run()
    assert eng.requests[0].outputs == twin.requests[0].outputs
    # the clamp demoted the promotion once the slot was overwritten
    assert int(np.asarray(eng.pam_state.tier[slot, victim])) != HOT


def test_migration_mid_wrap(setup):
    """Export a request whose ring has wrapped, import it elsewhere —
    including onto an engine with a DIFFERENT hot window — and the
    stream matches the unmigrated dense twin."""
    cfg, params = setup
    prompt = _prompts(n=1, plen=24, vocab=cfg.vocab, seed=5)[0]
    twin = _engine(cfg, params)
    twin.submit(Request(id=0, prompt=prompt, max_new_tokens=24))
    twin.run()
    expect = twin.requests[0].outputs

    for dst_kw in (dict(block_size=8, hot_window=WINDOW),
                   dict(block_size=8)):    # ring -> full-window too
        src = _engine(cfg, params, block_size=8, hot_window=WINDOW,
                      name="src")
        dst = _engine(cfg, params, name="dst", **dst_kw)
        src.submit(Request(id=0, prompt=prompt, max_new_tokens=24))
        for _ in range(10):                # 24 -> 34: wrapped past 16
            src.step()
        assert int(np.asarray(
            src.cache.lengths[src.requests[0].slot])) > WINDOW
        migrate(src, dst, 0)
        while any(s is not None for s in dst.slots):
            dst.step()
        assert dst.requests[0].outputs == expect


# --------------------------------------------------- footprint + config
def test_hot_bytes_per_slot_independent_of_max_len(setup):
    """The capacity headline: hot-tier bytes/slot are constant across
    max_len with a ring, and scale linearly without one."""
    cfg, params = setup
    ring_bytes, full_bytes = [], []
    for smax in (64, 128, 256):
        eng = _engine(cfg, params, max_len=smax, block_size=8,
                      hot_window=WINDOW)
        assert eng.cache.k.shape[3] == WINDOW
        ring_bytes.append(eng.summary()["hot_bytes_per_slot"])
        full = _engine(cfg, params, max_len=smax, block_size=8)
        full_bytes.append(full.summary()["hot_bytes_per_slot"])
    assert len(set(ring_bytes)) == 1            # Smax-independent
    assert full_bytes[1] == 2 * full_bytes[0]   # legacy scales with Smax
    assert full_bytes[2] == 4 * full_bytes[0]
    assert ring_bytes[0] == full_bytes[0] * WINDOW // 64


def test_ring_config_validation(setup):
    cfg, params = setup
    with pytest.raises(ValueError):     # ring needs the paged backfill
        _engine(cfg, params, hot_window=WINDOW)
    with pytest.raises(ValueError):     # window larger than max_len
        _engine(cfg, params, block_size=8, hot_window=128)
    with pytest.raises(ValueError):     # cache-level guard too
        tf.init_decode_cache(cfg, 2, 64, hot_window=WINDOW)
