"""Continuous-batching serving front end (PR 8).

- ``chunking``: chunked-prefill slice planning (engine hook).
- ``server``: async streaming server over ``ClusterRouter``/``PAMEngine``.
- ``admission``: SLO-aware admission control (shed / preempt).
- ``loadgen``: seeded arrival traces + TTFT/TPOT/SLO scoring.

Submodules are imported lazily: the serving engine imports
``repro.frontend.chunking`` while ``repro.frontend.server`` imports the
cluster layer (which imports the engine) — eager imports here would be
a cycle.
"""

import importlib

_SUBMODULES = ("admission", "chunking", "loadgen", "server")


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f"{__name__}.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_SUBMODULES))
