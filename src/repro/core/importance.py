"""KV-token importance tracking (paper §6.3.1, eqs. 7-8).

Per-token importance factor:   I_i(j) = lam * S_i(j) + (1 - lam) * I_i(j-1)
Per-tier cumulative score:     IS_D(j) = sum_{i in D} I_i(j) / #tokens(D)

``S_i(j)`` is the per-step performance score from the retrieval-sparsity
algorithm — here the (normalized) attention weight mass a token received at
step j (summed over heads), which is what Double-Sparsity-style methods
expose. The EMA damps step-to-step volatility so the scheduler (Alg. 2)
does not thrash tokens across tiers.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

DEFAULT_LAMBDA = 0.6  # paper: "lambda is set as 0.6"


@partial(jax.jit, static_argnames=("lam",))
def update_importance(importance: jax.Array, step_score: jax.Array,
                      lam: float = DEFAULT_LAMBDA) -> jax.Array:
    """Eq. (7): EMA update. Shapes broadcast; typically (tokens,)."""
    return lam * step_score + (1.0 - lam) * importance


def step_score_from_attn_weights(weights: jax.Array,
                                 head_axis: int = 0) -> jax.Array:
    """Derive S_i(j) from attention probabilities.

    weights: (..., heads, tokens) attention probabilities for the current
    query. Returns (..., tokens): mean attention mass per token across heads,
    scaled by token count so scores are O(1) regardless of context length.
    """
    score = jnp.mean(weights, axis=head_axis)
    n = score.shape[-1]
    return score * n


@partial(jax.jit, static_argnames=("num_tiers",))
def tier_importance_score(importance: jax.Array,
                          tier_of_token: jax.Array,
                          num_tiers: int = 3,
                          valid: jax.Array | None = None) -> jax.Array:
    """Eq. (8): mean importance of tokens on each tier.

    importance: (tokens,), tier_of_token: (tokens,) int in [0, num_tiers),
    valid: optional bool (tokens,). Returns (num_tiers,) mean score; empty
    tiers score 0.
    """
    if valid is None:
        valid = jnp.ones_like(importance, dtype=bool)
    w = valid.astype(importance.dtype)
    sums = jax.ops.segment_sum(importance * w, tier_of_token,
                               num_segments=num_tiers)
    counts = jax.ops.segment_sum(w, tier_of_token, num_segments=num_tiers)
    return sums / jnp.maximum(counts, 1.0)


def topk_hot_set(importance: jax.Array, k: int,
                 valid: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Select the k most important tokens (the hot working set).

    Returns (indices (k,), mask_over_tokens (tokens,) bool). Invalid tokens
    are never selected (importance forced to -inf).
    """
    scores = importance
    if valid is not None:
        scores = jnp.where(valid, importance, -jnp.inf)
    _, idx = jax.lax.top_k(scores, k)
    mask = jnp.zeros(importance.shape, bool).at[idx].set(
        True if valid is None else valid[idx])
    return idx, mask


def context_locality_hit_rate(prev_hot: jax.Array,
                              cur_hot: jax.Array) -> jax.Array:
    """Fraction of the current hot set already hot last step (§3.2 metric)."""
    inter = jnp.sum(prev_hot & cur_hot)
    denom = jnp.maximum(jnp.sum(cur_hot), 1)
    return inter / denom
