"""Multi-device PAM cluster (paper §4.3): heterogeneous-device router,
inter-device KV migration, and online load balancing over N serving
engines."""

from repro.cluster.balancer import BalancerConfig, KVBalancer
from repro.cluster.migration import KVSnapshot, can_migrate, migrate
from repro.cluster.router import (ClusterDevice, ClusterRouter,
                                  RouterConfig, TokenEvent, build_cluster)

__all__ = ["BalancerConfig", "KVBalancer", "KVSnapshot", "can_migrate",
           "migrate", "ClusterDevice", "ClusterRouter", "RouterConfig",
           "TokenEvent", "build_cluster"]
