"""Prefix-sharing copy-on-write block pool (PR 7).

Two co-equal halves:

* a property-based invariant suite — 200+ seeded random interleavings
  of admit / fork / decode-append / finish / migrate / evict against a
  host-level content oracle, asserting refcount conservation
  (``BlockAllocator.check_refcounts``), no-write-to-shared (every KV
  write targets a block whose sole table reference is the writer), and
  content exactness (every live request's mapped blocks spell exactly
  its token stream; every trie entry spells exactly its key);

* engine twin-exactness — a trie-admitted request (zero prefill compute
  for the shared prefix, CoW on the divergent tail) emits a token
  stream IDENTICAL to the same request served with the cache off, for
  greedy, sampled, and micro-batched decode, and across a mid-decode
  migration — plus the capacity half: shared admissions fit where
  unshared cannot (pool occupancy < sum of table lengths), pressure
  evicts trie-only blocks instead of failing.
"""

import jax
import numpy as np
import pytest
from _hyp import given, interleaving_seed, seed_corpus, settings
from conftest import make_pam

from repro.cluster import can_migrate, migrate
from repro.serving import (BlockAllocator, EngineSpec, OutOfBlocks,
                           PrefixTrie, Request, ServingConfig)

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------- allocator refcounts
def test_adopt_shares_and_free_decrefs():
    alloc = BlockAllocator(num_blocks=8, block_size=4)
    t0 = alloc.allocate(0, 12)                      # 3 fresh blocks
    alloc.adopt(1, t0[:2])                          # share 2 of them
    alloc.allocate(1, 12)                           # + 1 fresh
    assert alloc.refcount[t0[0]] == 2
    assert alloc.used_blocks == 4                   # NOT 3 + 3
    assert alloc.used_blocks < sum(len(t) for t in alloc.tables.values())
    assert alloc.free(0) == 1                       # only the unshared one
    assert alloc.refcount[t0[0]] == 1               # still live via seq 1
    assert alloc.free(1) == 3
    assert alloc.free_blocks == 8
    assert alloc.check_refcounts()


def test_free_unknown_is_noop_and_double_decref_raises():
    alloc = BlockAllocator(num_blocks=4, block_size=4)
    assert alloc.free(99) == 0                      # unknown: explicit no-op
    tbl = alloc.allocate(0, 4)
    assert alloc.free(0) == 1
    assert alloc.free(0) == 0                       # second free: no-op
    with pytest.raises(ValueError, match="double free"):
        alloc.decref(tbl[0])                        # raw double-free: loud
    with pytest.raises(ValueError):
        alloc.incref(tbl[0])                        # free block can't gain refs
    assert alloc.check_refcounts()


def test_admit_shared_is_atomic_under_out_of_blocks():
    alloc = BlockAllocator(num_blocks=4, block_size=4)
    t0 = alloc.allocate(0, 8)
    before = (dict(alloc.refcount), alloc.free_blocks)
    with pytest.raises(OutOfBlocks):
        alloc.admit_shared(1, t0, 9 * 4)            # needs 7 fresh > 2 free
    assert (dict(alloc.refcount), alloc.free_blocks) == before
    assert 1 not in alloc.tables                    # nothing half-mapped
    assert alloc.check_refcounts()


def test_backpressure_accounts_shared_blocks_once():
    """OutOfBlocks triggers on PHYSICAL occupancy, not on the sum of
    table lengths — sharing buys real admission headroom."""
    alloc = BlockAllocator(num_blocks=6, block_size=4)
    t0 = alloc.allocate(0, 16)                      # 4 blocks
    alloc.admit_shared(1, t0[:3], 16)               # 3 shared + 1 fresh
    assert sum(len(t) for t in alloc.tables.values()) == 8 > 6
    assert alloc.used_blocks == 5 and alloc.free_blocks == 1
    with pytest.raises(OutOfBlocks):
        alloc.allocate(2, 8)                        # 2 fresh > 1 free
    alloc.allocate(2, 4)                            # 1 fresh still fits
    assert alloc.check_refcounts()


def test_checker_catches_corruption():
    """check_refcounts is a real oracle: seeded corruptions trip it."""
    alloc = BlockAllocator(num_blocks=4, block_size=4)
    alloc.allocate(0, 8)
    assert alloc.check_refcounts()
    alloc.refcount[alloc.table(0)[0]] += 1          # phantom reference
    assert not alloc.check_refcounts()
    alloc.refcount[alloc.table(0)[0]] -= 1
    alloc.tables[0].append(alloc.tables[0][0])      # double mapping
    assert not alloc.check_refcounts()
    alloc.tables[0].pop()
    alloc._free.append(alloc.table(0)[1])           # freed while referenced
    assert not alloc.check_refcounts()


# --------------------------------------------------------- prefix trie
def _mk(num_blocks=32, bs=4):
    alloc = BlockAllocator(num_blocks, bs)
    return alloc, PrefixTrie(bs, alloc)


def test_trie_roundtrip_full_and_partial():
    alloc, trie = _mk()
    toks = list(range(10))                          # 2 full blocks + 2 tail
    tbl = alloc.allocate(0, 10 + 4)
    assert trie.insert(toks, tbl) == 3              # 2 full + 1 partial
    m, ids = trie.lookup(toks)
    assert m == 10 and ids == tbl[:3]
    m, ids = trie.lookup(toks[:6])                  # 1 full + partial lcp 2
    assert m == 6 and ids == tbl[:2]
    m, ids = trie.lookup([99] + toks)               # shifted: no match
    assert m == 0 and ids == []
    # trie holds one pin per indexed block: publisher finishing keeps KV
    alloc.free(0)
    assert alloc.used_blocks == 3
    assert alloc.check_refcounts(trie.block_refs())


def test_trie_eviction_is_lru_leaf_first_and_respects_sharers():
    alloc, trie = _mk(num_blocks=8)
    a = list(range(8))                              # 2 full blocks
    b = list(range(4)) + [9, 9, 9, 9]               # shares block 0 path
    trie.insert(a, alloc.allocate(0, 8))
    trie.insert(b, alloc.allocate(1, 8))            # publishes 1 new block
    alloc.free(0)
    # seq 1 still live: its published leaf (rc 2) must survive eviction,
    # and the shared interior node (b's path runs through it) must too —
    # even though its block is now trie-only
    touched, _ = trie.lookup(a)                     # a's leaf is now MRU
    assert touched == 8
    freed = trie.evict(10)                          # drain what's legal
    assert freed == 1                               # only a's leaf block
    m, _ = trie.lookup(b)
    assert m == 8                                   # pinned path intact
    m, _ = trie.lookup(a)
    assert m == 4                                   # interior node survives
    assert alloc.check_refcounts(trie.block_refs())


def test_trie_interior_nodes_survive_leaf_eviction():
    alloc, trie = _mk(num_blocks=8)
    toks = list(range(12))                          # chain of 3 full blocks
    trie.insert(toks, alloc.allocate(0, 12))
    alloc.free(0)
    assert trie.evict(1) == 1                       # only the LEAF goes
    m, _ = trie.lookup(toks)
    assert m == 8                                   # prefix still contiguous
    assert alloc.check_refcounts(trie.block_refs())


# ---------------------------------------- property: random interleavings
def _drive_interleaving(seed, steps=40):
    """Host-model mirror of the engine's admission/CoW protocol, driven
    by one rng seed; asserts the full invariant set after every op."""
    rng = np.random.default_rng(seed)
    bs = 4
    alloc = BlockAllocator(num_blocks=24, block_size=bs)
    trie = PrefixTrie(bs, alloc)
    content: dict[int, list] = {}        # physical block -> slot tokens
    live: dict[int, dict] = {}           # rid -> {toks, prompt_len, window}
    past: list[list[int]] = []           # prompts seen (fork targets)
    next_rid = [0]
    prefixes = [list(map(int, rng.integers(0, 5, 8))) for _ in range(3)]

    def table_refs(b):
        return alloc.refcount.get(b, 0) - trie.block_refs().get(b, 0)

    def write(rid, p, tok):
        b = alloc.table(rid)[p // bs]
        assert table_refs(b) == 1, \
            f"write to shared block {b} (rid {rid}, pos {p})"
        c = content.setdefault(b, [])
        while len(c) <= p % bs:
            c.append(None)
        c[p % bs] = tok

    def admit(toks, *, via_trie=True):
        rid = next_rid[0]
        next_rid[0] += 1
        window = len(toks) + int(rng.integers(1, 9))
        if alloc.blocks_for(window) > alloc.num_blocks:
            return
        if via_trie:
            matched, ids = trie.lookup(toks)
            matched = min(matched, len(toks) - 1)
        else:
            matched, ids = 0, []         # migration import: all fresh
        nfull = matched // bs
        shared, cow = ids[:nfull], matched % bs > 0
        cow_src = ids[nfull] if cow else -1
        try:                             # engine's admission order
            alloc.adopt(rid, shared)
            if cow:
                alloc.incref(cow_src)    # pin across eviction
            need = alloc.blocks_for(window) - len(shared)
            if need > alloc.free_blocks:
                trie.evict(need - alloc.free_blocks)
            alloc.allocate(rid, window)
        except OutOfBlocks:              # backpressure: full rollback
            if cow:
                alloc.decref(cow_src)
            alloc.free(rid)
            assert alloc.check_refcounts(trie.block_refs())
            return
        tbl = alloc.table(rid)
        for b in tbl[len(shared):]:
            content[b] = []              # fresh blocks start blank
        if cow:                          # duplicate BEFORE first write
            content[tbl[nfull]] = list(content[cow_src])
            alloc.decref(cow_src)        # pin released after the copy
        for p in range(matched, len(toks)):
            write(rid, p, toks[p])       # suffix prefill scatter
        trie.insert(toks, tbl)           # publish after commit
        live[rid] = {"toks": list(toks), "prompt": len(toks),
                     "window": window}
        past.append(list(toks))

    def check_all():
        assert alloc.check_refcounts(trie.block_refs())
        for rid, info in live.items():
            tbl = alloc.table(rid)
            got = [content[tbl[p // bs]][p % bs]
                   for p in range(len(info["toks"]))]
            assert got == info["toks"], f"rid {rid} content diverged"
        stack = [trie.root]
        while stack:                     # every trie entry spells its key
            node = stack.pop()
            for key, child in node.children.items():
                assert content[child.block][:bs] == list(key)
                stack.append(child)
            for key, entry in node.partials.items():
                assert content[entry[0]][:len(key)] == list(key)

    for _ in range(steps):
        op = rng.choice(["admit", "fork", "append", "finish", "migrate",
                         "evict"], p=[.3, .2, .25, .1, .08, .07])
        if op == "admit":
            pre = prefixes[rng.integers(len(prefixes))]
            toks = pre + list(map(int, rng.integers(0, 5,
                                                    rng.integers(1, 9))))
            admit(toks)
        elif op == "fork" and past:
            admit(list(past[rng.integers(len(past))]))  # exact duplicate
        elif op == "append" and live:
            rid = int(rng.choice(sorted(live)))
            info = live[rid]
            if len(info["toks"]) < info["window"]:
                tok = int(rng.integers(0, 5))
                info["toks"].append(tok)
                write(rid, len(info["toks"]) - 1, tok)
        elif op == "finish" and live:
            rid = int(rng.choice(sorted(live)))
            alloc.free(rid)
            del live[rid]
        elif op == "migrate" and live:
            rid = int(rng.choice(sorted(live)))
            info = live.pop(rid)
            alloc.free(rid)              # export: free-without-finish
            nrid = next_rid[0]
            next_rid[0] += 1
            need = alloc.blocks_for(info["window"])
            if need > alloc.free_blocks:
                trie.evict(need - alloc.free_blocks)
            try:                         # import: fresh blocks only
                alloc.allocate(nrid, info["window"])
            except OutOfBlocks:
                check_all()
                continue
            for b in alloc.table(nrid):
                content[b] = []
            for p, tok in enumerate(info["toks"]):
                write(nrid, p, tok)      # snapshot scatter
            trie.insert(info["toks"][:info["prompt"]],
                        alloc.table(nrid))
            live[nrid] = info
        elif op == "evict":
            trie.evict(int(rng.integers(1, 4)))
        check_all()
    for rid in sorted(live):             # drain
        alloc.free(rid)
    assert alloc.check_refcounts(trie.block_refs())
    trie.evict(alloc.num_blocks)
    assert alloc.free_blocks == alloc.num_blocks   # nothing leaked


@pytest.mark.parametrize("seed", seed_corpus(220))
def test_interleaving_invariants(seed):
    """220 seeded random interleavings through the host model — the
    always-on half of the property suite."""
    _drive_interleaving(seed)


@settings(max_examples=60, deadline=None)
@given(interleaving_seed)
def test_interleaving_invariants_hypothesis(seed):
    """Hypothesis exploration (and shrinking) of the same driver."""
    _drive_interleaving(seed)


# -------------------------------------------------- engine twin tests
def _pam():
    return make_pam(max_len=64, hot=16, warm=24)


def _eng(model, *, prefix_cache, name="dev", max_batch=2, pool=None, **kw):
    cfg, params = model
    scfg = ServingConfig(max_batch=max_batch, max_len=64, pam=_pam(),
                         block_size=8, prefix_cache=prefix_cache,
                         pool_blocks=pool, **kw)
    return EngineSpec(model=cfg, serving=scfg, name=name).build(params)


def _shared_prompts(vocab, seed=7):
    """1-3 share a 20-token prefix (distinct 6-token tails), 4 is an
    exact duplicate of 1 (forces a CoW admission), 5 is unrelated."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, 20)
    p = {i: np.concatenate([shared, rng.integers(0, vocab, 6)])
         for i in (1, 2, 3)}
    p[4] = p[1].copy()
    p[5] = rng.integers(0, vocab, 5)
    return p


@pytest.mark.parametrize("mode", ["greedy", "sampled", "micro"])
def test_twin_exactness_staggered(qwen_model, mode):
    """Trie-admitted requests (staggered waves: later arrivals hit the
    prefixes earlier ones published, incl. one CoW fork) emit token
    streams IDENTICAL to the cache-off engine."""
    kw = {"sampled": dict(temperature=0.8, top_k=8, sample_seed=3),
          "micro": dict(micro_steps=8)}.get(mode, {})
    prompts = _shared_prompts(qwen_model[0].vocab)
    streams = {}
    for cache in (False, True):
        eng = _eng(qwen_model, prefix_cache=cache, **kw)
        for i in sorted(prompts):
            eng.submit(Request(id=i, prompt=prompts[i], max_new_tokens=10))
        s = eng.run()
        streams[cache] = {i: eng.requests[i].outputs for i in prompts}
        if cache:
            assert eng.allocator.check_refcounts(eng.trie.block_refs())
            assert s["prefix_hits"] > 0 and s["cow_copies"] > 0
            assert s["cached_prefix_tokens"] > 0
            assert s["novel_prefill_tokens"] < sum(
                len(p) for p in prompts.values())
    assert streams[True] == streams[False]


def test_twin_exactness_across_migration(qwen_model):
    """A trie-admitted (CoW) request migrated mid-decode continues its
    exact stream on the target; refcounts stay conserved on BOTH pools
    and the import republishes the prompt to the target's trie."""
    cfg, _ = qwen_model
    rng = np.random.default_rng(11)
    prompts = {0: rng.integers(0, cfg.vocab, 26), 2: rng.integers(0, cfg.vocab, 12)}
    prompts[1] = prompts[0].copy()
    twin = _eng(qwen_model, prefix_cache=False, max_batch=3, name="twin")
    for i in sorted(prompts):
        twin.submit(Request(id=i, prompt=prompts[i], max_new_tokens=12))
    twin.run()

    src = _eng(qwen_model, prefix_cache=True, name="src")
    dst = _eng(qwen_model, prefix_cache=True, name="dst")
    for i in [0, 2, 1]:                   # duplicate arrives in wave 2
        src.submit(Request(id=i, prompt=prompts[i], max_new_tokens=12))
    while not (1 in src.requests
               and src.requests[1].status == "running"):
        src.step()
    src.step()                            # mid-decode on the CoW request
    assert src.prefix_hits > 0 and src.cow_copies > 0
    assert can_migrate(src, dst, 1)
    migrate(src, dst, 1)
    assert src.allocator.check_refcounts(src.trie.block_refs())
    while any(s is not None for s in src.slots) or src.waiting:
        src.step()
    while any(s is not None for s in dst.slots) or dst.waiting:
        dst.step()
    for rid in prompts:
        eng = dst if rid == 1 else src
        assert eng.requests[rid].outputs == twin.requests[rid].outputs, rid
    assert dst.trie.num_blocks > 0        # import published the prompt
    assert dst.allocator.check_refcounts(dst.trie.block_refs())


def test_shared_admission_fits_where_unshared_cannot(qwen_model):
    """Capacity half of the tentpole: a 6-block pool serves a 24-token
    prompt (4-block window) AND its duplicate CONCURRENTLY only with
    the prefix cache — occupancy counts shared blocks once — while the
    cache-off engine must serialize them. Streams stay twin-exact."""
    cfg, _ = qwen_model
    rng = np.random.default_rng(5)
    prompts = {0: rng.integers(0, cfg.vocab, 24)}
    prompts[1] = prompts[0].copy()
    ref = _eng(qwen_model, prefix_cache=False, max_batch=2, name="ref")
    for i in sorted(prompts):
        ref.submit(Request(id=i, prompt=prompts[i], max_new_tokens=8))
    ref.run()

    both_running = {}
    streams = {}
    for cache in (False, True):
        eng = _eng(qwen_model, prefix_cache=cache, pool=6, name="tight")
        for i in sorted(prompts):
            eng.submit(Request(id=i, prompt=prompts[i], max_new_tokens=8))
        seen = False
        while any(s is not None for s in eng.slots) or eng.waiting:
            eng.step()
            running = sum(s is not None for s in eng.slots)
            if running == 2:
                seen = True
                assert eng.allocator.used_blocks < sum(
                    len(t) for t in eng.allocator.tables.values())
        both_running[cache] = seen
        streams[cache] = {i: eng.requests[i].outputs for i in prompts}
    assert both_running[True] and not both_running[False]
    assert streams[True] == streams[False] == {
        i: ref.requests[i].outputs for i in prompts}


def test_pressure_evicts_trie_blocks_instead_of_failing(qwen_model):
    """Once the publishers finish, their trie-pinned blocks are the only
    occupancy; an unrelated admission that needs the space evicts them
    (cache degrades to recompute) rather than backpressuring forever."""
    cfg, _ = qwen_model
    rng = np.random.default_rng(9)
    a = rng.integers(0, cfg.vocab, 24)
    b = rng.integers(0, cfg.vocab, 24)
    eng = _eng(qwen_model, prefix_cache=True, pool=6, name="tight")
    eng.submit(Request(id=0, prompt=a, max_new_tokens=8))
    eng.run()
    assert eng.trie.num_blocks > 0
    eng.submit(Request(id=1, prompt=b, max_new_tokens=8))
    eng.run()
    assert eng.trie.evictions > 0
    assert eng.requests[1].outputs
    assert eng.allocator.check_refcounts(eng.trie.block_refs())
    ref = _eng(qwen_model, prefix_cache=False, max_batch=2, name="ref")
    ref.submit(Request(id=1, prompt=b, max_new_tokens=8))
    ref.run()
    assert eng.requests[1].outputs == ref.requests[1].outputs


def test_prefix_cache_config_validation(qwen_model):
    cfg, params = qwen_model
    with pytest.raises(ValueError):       # trie needs the paged pool
        EngineSpec(model=cfg, serving=ServingConfig(
            max_batch=2, max_len=64, pam=_pam(),
            prefix_cache=True)).build(params)


def test_summary_reports_sharing_counters(qwen_model):
    prompts = _shared_prompts(qwen_model[0].vocab)
    eng = _eng(qwen_model, prefix_cache=True)
    for i in sorted(prompts):
        eng.submit(Request(id=i, prompt=prompts[i], max_new_tokens=6))
    s = eng.run()
    for key in ("prefix_hits", "cached_prefix_tokens",
                "novel_prefill_tokens", "cow_copies", "trie_blocks",
                "trie_evictions"):
        assert key in s, key
    assert s["prefix_hits"] >= 2          # two later waves hit
    assert s["cached_prefix_tokens"] >= 16
