"""The unified serving event surface (PR 10).

``ServeEvent`` is the ONE token-stream record emitted by every serving
path: the cluster router's event loop, the single-engine ``serve()``
generator, and the async frontend's per-request stream handles all
speak it. Before PR 10 the router had its own ``TokenEvent`` and the
frontend re-wrapped records per stream; ``launch/serve.py`` special-
cased the two. Now a backend — ``ServingEngine`` or ``ClusterRouter`` —
exposes the same two methods:

  ``as_router()``  -> the ``ClusterRouter`` view of the backend (a
                      router returns itself; an engine wraps itself as
                      a one-device cluster), and
  ``serve(...)``   -> a generator of ``ServeEvent``s that drives the
                      backend to drain.

``ClusterRouter.TokenEvent`` remains as an alias for back-compat.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class ServeEvent:
    """One emitted token (or terminal marker) of one request's stream.

    ``done`` marks the request's last event; ``rejected`` marks a
    request shed by admission/SLO policy (its only event — ``token`` is
    meaningless there). ``time`` is the backend's (simulated or wall)
    clock at emission; ``index`` is the token's position in the
    request's output stream; ``device`` names the engine that produced
    it.
    """

    time: float
    request_id: int
    token: int
    index: int
    device: str
    done: bool
    rejected: bool = False
