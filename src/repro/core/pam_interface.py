"""Inter-device PAM interface (paper §6.2) — layout-aware KV migration.

Each tier stores KV in a tier-native layout:
  hot  (HBM)  : bank-interleaved dense  (G, Tg, H, d) — kernel-ready
  warm (DDR)  : paged blocks            (nblocks, block, H, d)
  cold (SSD)  : paged blocks, large block size (flash-page aligned)

Migrating tokens across tiers requires a layout transformation. The paper
offloads this to a hardware unit: a *command reorder unit* (sender) streams
tokens into a *re-layout buffer* in destination order, and an *address
generation unit* (receiver) issues the writes — no host round-trip.

JAX adaptation: a migration is a single fused gather->scatter with indices
precomputed by ``make_migration_plan`` (the command-reorder step). The
whole transfer compiles into one XLA gather + one scatter on contiguous
buffers — the software analogue of removing the CPU from the critical path;
the perfmodel charges it at link bandwidth (vs. host path: 2x PCIe + CPU
reformat, the >20x gap the paper reports).

Paged-pool addendum (serving fast path): when every tier's blocks live in
ONE shared ``PagedKVPool`` and tier residency is per-token metadata
(``PAMState.tier``), an Alg. 2 migration never moves bytes at all —
``migrate_tier_tags`` edits the tags and the next decode step's per-tier
masks/block tables simply select different pages. That is the degenerate
(and cheapest) case of the §6.2 interface: a *table edit* rather than a
tensor copy. The gather/scatter plan above remains the model for
migrations that DO cross a physical pool boundary (inter-device, or a
future dense-hot-window eviction).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class MigrationPlan(NamedTuple):
    """Precomputed index plan for one inter-tier transfer."""
    src_token_idx: jax.Array   # (n,) token slots to read from source pool
    dst_token_idx: jax.Array   # (n,) token slots to write in dest pool
    count: jax.Array           # scalar — number of live entries (<= n)


def make_migration_plan(moved_mask: jax.Array, src_slot_of_token: jax.Array,
                        dst_free_slots: jax.Array) -> MigrationPlan:
    """Command-reorder step: sort moved tokens into streaming order.

    moved_mask: (tokens,) bool — tokens leaving the source tier this step.
    src_slot_of_token: (tokens,) physical slot of each token in the source
    pool. dst_free_slots: (cap,) free physical slots in the destination.
    The plan is padded to ``dst_free_slots.shape[0]``; entries past ``count``
    alias slot 0 but are masked on scatter.
    """
    n = dst_free_slots.shape[0]
    # Stream in ascending source-slot order (sequential reads on the sender).
    order = jnp.argsort(jnp.where(moved_mask, src_slot_of_token, 2**30))
    count = jnp.minimum(jnp.sum(moved_mask), n)
    take = order[:n]
    live = jnp.arange(n) < count
    return MigrationPlan(
        src_token_idx=jnp.where(live, take, 0),
        dst_token_idx=jnp.where(live, dst_free_slots, 0),
        count=count,
    )


def apply_migration(src_pool: jax.Array, dst_pool: jax.Array,
                    plan: MigrationPlan,
                    src_slot_of_token: jax.Array) -> jax.Array:
    """Receiver step: gather from source layout, scatter into dest layout.

    src_pool: (src_slots, H, d); dst_pool: (dst_slots, H, d).
    Returns the updated destination pool. One gather + one masked scatter.
    """
    n = plan.src_token_idx.shape[0]
    src_slots = src_slot_of_token[plan.src_token_idx]          # (n,)
    data = src_pool[src_slots]                                  # (n, H, d)
    live = (jnp.arange(n) < plan.count)[:, None, None]
    cur = dst_pool[plan.dst_token_idx]
    return dst_pool.at[plan.dst_token_idx].set(jnp.where(live, data, cur))


def migrate_tier_tags(tier: jax.Array, moved_mask: jax.Array,
                      dst_tier: jax.Array | int) -> jax.Array:
    """Zero-copy migration: re-tag ``moved_mask`` tokens as ``dst_tier``.

    With a shared paged pool, this IS the whole inter-tier transfer — no
    KV bytes move; the next step's tier masks and block-table gather pick
    up the new residency. ``tier``/``moved_mask``: (..., tokens);
    ``dst_tier``: scalar or broadcastable tier ids.
    """
    return jnp.where(moved_mask, dst_tier, tier)


# --------------------------------------------------- hot-window ring (PR 5)
# The hot tier's dense buffer is a ring of ``window`` slots (absolute
# position p at slot p % window; see ``kernels.flash_decode.
# ring_position_map``). These are the §6.2 re-layout transforms between
# the ring layout and the logical (absolute-position) layout:
# demotion *is* the ring append overwriting the evicted slot (the evicted
# token's bytes already live in its mapped pool block — the engine mirrors
# every append), and promotion of an in-window token is a block->ring
# copy (``promote_block_to_ring``).

def logical_to_ring(kv: jax.Array, ring_pos: jax.Array,
                    valid: jax.Array) -> jax.Array:
    """Re-layout one sequence's logical KV onto ring coordinates.

    kv: (..., S, dh) absolute-position layout; ring_pos/valid: (W,) from
    ``ring_position_map``. Returns (..., W, dh) — slot j holds position
    ring_pos[j], dead slots zeroed. The admission-commit / migration-
    import half of the ring interface.
    """
    smax = kv.shape[-2]
    idx = jnp.clip(ring_pos, 0, smax - 1)
    g = jnp.take(kv, idx, axis=-2)
    return jnp.where(valid[:, None], g, jnp.zeros((), kv.dtype))


def ring_to_logical(ring_kv: jax.Array, ring_pos: jax.Array,
                    valid: jax.Array, base: jax.Array) -> jax.Array:
    """Scatter one sequence's ring-resident KV back into an absolute-
    position layout on top of ``base`` (normally the pool gather, so
    out-of-window positions keep their capacity-tier bytes).

    ring_kv: (..., W, dh); base: (..., S, dh). The migration-export half
    of the ring interface (§6.2 sender: hot rows stream through the ring
    index map, warm/cold rows come from the block-table gather).
    """
    smax = base.shape[-2]
    # Invalid slots (ring_pos < 0, only when the sequence is shorter
    # than the window) are routed to smax + ring_pos: in-bounds, above
    # every valid position, and distinct per slot — so the scatter has
    # UNIQUE indices (well-defined order) and invalid slots rewrite
    # their own gathered value, a true no-op at a dead position.
    idx = jnp.where(valid, jnp.clip(ring_pos, 0, smax - 1),
                    smax + ring_pos)
    cur = jnp.take(base, idx, axis=-2)
    vals = jnp.where(valid[:, None], ring_kv, cur)
    return _put_along_seq(base, idx, vals)


def _put_along_seq(base: jax.Array, idx: jax.Array,
                   vals: jax.Array) -> jax.Array:
    """base (..., S, dh) .at[..., idx, :] <- vals (..., W, dh)."""
    return base.at[..., idx, :].set(vals)


def promote_block_to_ring(ring_kv: jax.Array, pool: jax.Array,
                          table_row: jax.Array, position: jax.Array,
                          block_size: int, window: int) -> jax.Array:
    """Promotion: copy token ``position`` from its mapped pool block into
    its ring slot — one on-device gather + scatter, no host round-trip.

    ring_kv: (L, Hkv, W, dh) one sequence's ring; pool: (L, NB+1, bs,
    Hkv, dh); table_row: (nb,) physical ids. Only meaningful for
    in-window positions (out-of-window tokens have no ring slot; callers
    read them through the block table instead).
    """
    blk = table_row[position // block_size]
    tok = pool[:, blk, position % block_size]          # (L, Hkv, dh)
    return ring_kv.at[:, :, position % window, :].set(tok)


def paged_gather_logical(pool: jax.Array, block_table: jax.Array
                         ) -> jax.Array:
    """Re-layout: paged pool -> logical-order dense view, batched tables.

    pool: (NB, block, H, d); block_table: (B, nb) physical block ids in
    logical order per sequence. Returns (B, H, nb*block, d) — the jnp
    reference for the Pallas kernel's in-grid table walk (the kernel
    additionally skips pages with no participating token).
    """
    g = pool[block_table]                       # (B, nb, block, H, d)
    B, nb, bs, H, d = g.shape
    return jnp.moveaxis(g, 3, 1).reshape(B, H, nb * bs, d)


def gather_prefix_logical(pool: jax.Array, table_row: jax.Array,
                          prefix_len: jax.Array) -> jax.Array:
    """§6.2 sharer-side re-layout for prefix-cache admissions (PR 7):
    gather one request's CACHED PREFIX — the trie-matched blocks another
    request (or the trie alone) also references — from the shared pool
    into the logical dense layout, zeroed past ``prefix_len``.

    pool: (L, NB+1, bs, Hkv, dh); table_row: (nb,) physical ids in
    logical order (sentinel for unmapped); prefix_len: scalar cached
    tokens. Pure read: shared blocks are never written through this
    path, which is what lets any number of sharers (and tier-tag
    migrations — residency is per-request metadata) coexist on the same
    physical bytes. Returns (L, Hkv, nb*bs, dh).
    """
    g = pool[:, table_row]                        # (L, nb, bs, Hkv, dh)
    L, nb, bs, Hkv, dh = g.shape
    seq = jnp.moveaxis(g.reshape(L, nb * bs, Hkv, dh), 1, 2)
    live = jnp.arange(nb * bs)[None, None, :, None] < prefix_len
    return jnp.where(live, seq, jnp.zeros((), seq.dtype))


def paged_to_dense(pool: jax.Array, block_table: jax.Array,
                   block_size: int) -> jax.Array:
    """Re-layout: paged blocks -> contiguous dense (kernel-ready).

    pool: (nblocks, block, H, d); block_table: (nlogical,) physical block ids
    in logical order. Returns (nlogical*block, H, d).
    """
    gathered = pool[block_table]                 # (nlogical, block, H, d)
    return gathered.reshape((-1,) + pool.shape[2:])


def dense_to_paged(dense: jax.Array, pool: jax.Array,
                   block_table: jax.Array, block_size: int) -> jax.Array:
    """Re-layout: contiguous dense -> paged blocks (inverse transform)."""
    blocks = dense.reshape((-1, block_size) + dense.shape[1:])
    return pool.at[block_table].set(blocks)


def bank_interleave(dense: jax.Array, assign: jax.Array,
                    num_groups: int, group_cap: int) -> tuple[jax.Array, jax.Array]:
    """Re-layout: dense tokens -> (G, Tg, ...) bank-group-interleaved layout
    per the §6.1 mapping. Returns (interleaved, slot_of_token)."""
    n = dense.shape[0]
    # rank within group = running count of same-group tokens before me
    onehot = jax.nn.one_hot(assign, num_groups, dtype=jnp.int32)  # (n, G)
    rank = jnp.cumsum(onehot, axis=0) - onehot                    # (n, G)
    rank_in_group = jnp.take_along_axis(rank, assign[:, None], 1)[:, 0]
    slot = assign * group_cap + jnp.minimum(rank_in_group, group_cap - 1)
    out = jnp.zeros((num_groups * group_cap,) + dense.shape[1:],
                    dense.dtype).at[slot].set(dense)
    return out.reshape((num_groups, group_cap) + dense.shape[1:]), slot
