"""zamba2-7b [arXiv:2411.15242; unverified] — Mamba2 backbone with a SHARED
attention+MLP block applied between mamba groups (81 blocks total:
13 x (5 mamba + shared attn) + 3 mamba tail = 68 mamba + 13 attn)."""
from repro.models.config import (HybridConfig, ModelConfig, SSMConfig,
                                 register)

CONFIG = register(ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, d_head=112,
    rope_theta=1e4,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, n_groups=1,
                  conv_kernel=4, chunk=128),
    hybrid=HybridConfig(n_groups=13, mamba_per_group=5, tail_mamba=3),
))
