"""Chunked prefill (PR 8): bounded admission slices interleaved with
decode, bit-identical to single-shot admission.

Acceptance invariants pinned here:
  * twin exactness — greedy, sampled (temperature + top-k), micro k=8,
    and across a mid-decode migration;
  * no engine step prefills more than ``prefill_chunk`` tokens per
    in-flight admission (``max_chunk_slice_tokens``);
  * decode keeps exactly ONE fused dispatch per device step while
    chunks advance, and running requests keep emitting tokens while a
    long prompt is still filling (the latency-spike fix);
  * chunked admission composes with the prefix cache (trie hit + CoW);
  * the PR 7 follow-on: same-bucket trie and plain admissions batch
    through ONE suffix prefill + ONE donated multi-slot commit.
"""

import numpy as np
import pytest

from conftest import build_model, make_engine, make_pam, make_requests
from repro.frontend.chunking import ChunkPlan, plan_slices, validate_budget
from repro.serving import Request
from repro.serving.engine import PREFILLING, RUNNING


def _chunk_engine(name="dev", chunk=8, max_len=64, latency=None, **kw):
    cfg, params = build_model()
    pam = make_pam(max_len=max_len)
    return cfg, make_engine(cfg, params, pam=pam, name=name,
                            latency=latency, max_batch=4, max_len=max_len,
                            block_size=8, prefill_chunk=chunk, **kw)


def _streams(eng, rids):
    return {i: list(eng.requests[i].outputs) for i in rids}


# --------------------------------------------------------- host planning
def test_plan_slices_covers_and_bounds():
    for start, total, budget in ((0, 30, 8), (5, 64, 16), (12, 13, 4)):
        slices = plan_slices(start, total, budget)
        assert slices[0][0] == start
        assert sum(t for _, t in slices) == total - start
        assert all(t == budget for _, t in slices[:-1])
        assert 0 < slices[-1][1] <= budget
        ends = [b + t for b, t in slices]
        assert ends == [b for b, _ in slices[1:]] + [total]


def test_chunk_plan_next_slice_walks_schedule():
    plan = ChunkPlan(rid=0, slot=1, start=3, total=20, budget=8)
    seen = []
    while not plan.finished:
        begin, t = plan.next_slice()
        seen.append((begin, t))
        plan.done += t
    assert seen == plan_slices(3, 20, 8)


def test_validate_budget_rejects_non_pow2():
    validate_budget(16)
    for bad in (0, -8, 3, 12):
        with pytest.raises(ValueError):
            validate_budget(bad)


def test_engine_rejects_chunk_without_paged_pool():
    cfg, params = build_model()
    with pytest.raises(ValueError):
        make_engine(cfg, params, pam=make_pam(), max_batch=2, max_len=64,
                    block_size=0, prefill_chunk=8)


# --------------------------------------------------------- twin exactness
def _mixed_requests(cfg, plens=(30, 9, 16, 5), max_new=8, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(id=i, prompt=rng.integers(0, cfg.vocab, p),
                    max_new_tokens=max_new)
            for i, p in enumerate(plens)]


@pytest.mark.parametrize("sampling", ["greedy", "sampled"])
def test_chunked_twin_exact(sampling):
    kw = ({} if sampling == "greedy"
          else dict(temperature=0.8, top_k=8, sample_seed=7))
    cfg, eng = _chunk_engine("chunked", chunk=8, **kw)
    _, twin = _chunk_engine("twin", chunk=0, **kw)
    for e in (eng, twin):
        for r in _mixed_requests(cfg):
            e.submit(Request(id=r.id, prompt=r.prompt,
                             max_new_tokens=r.max_new_tokens))
        e.run()
    assert _streams(eng, range(4)) == _streams(twin, range(4))
    s = eng.summary()
    # prompts of 30, 9 and 16 novel tokens exceed the budget of 8
    assert s["chunked_admissions"] == 3
    assert s["max_chunk_slice_tokens"] <= 8
    assert twin.summary().get("chunked_admissions") is None


def test_chunked_twin_exact_micro8():
    cfg, eng = _chunk_engine("chunked", chunk=8, micro_steps=8)
    _, twin = _chunk_engine("twin", chunk=0, micro_steps=8)
    for e in (eng, twin):
        for r in _mixed_requests(cfg, max_new=12):
            e.submit(Request(id=r.id, prompt=r.prompt,
                             max_new_tokens=r.max_new_tokens))
        e.run()
    assert _streams(eng, range(4)) == _streams(twin, range(4))
    assert eng.summary()["chunked_admissions"] == 3


def test_chunked_twin_exact_across_migration():
    from repro.cluster import can_migrate, migrate

    twin_cfg, twin = _chunk_engine("twin", chunk=0)
    reqs = _mixed_requests(twin_cfg, plens=(30, 12, 26), max_new=10)
    for r in reqs:
        twin.submit(Request(id=r.id, prompt=r.prompt,
                            max_new_tokens=r.max_new_tokens))
    twin.run()

    _, src = _chunk_engine("src", chunk=8)
    _, dst = _chunk_engine("dst", chunk=8)
    for r in reqs:
        src.submit(Request(id=r.id, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens))
    # step past the chunked fills into mid-decode, then migrate rid 0
    while (0 not in src.requests
           or src.requests[0].status != RUNNING
           or len(src.requests[0].outputs) < 3):
        src.step()
    assert can_migrate(src, dst, 0)
    migrate(src, dst, 0)
    while any(s is not None for s in src.slots) or src.waiting:
        src.step()
    while any(s is not None for s in dst.slots) or dst.waiting:
        dst.step()
    assert dst.requests[0].outputs == twin.requests[0].outputs
    for rid in (1, 2):
        assert src.requests[rid].outputs == twin.requests[rid].outputs


def test_chunked_composes_with_prefix_cache_cow():
    cfg, params = build_model()
    rng = np.random.default_rng(4)
    shared = rng.integers(0, cfg.vocab, 12)       # unaligned vs block 8
    prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab, 18)])
               for _ in range(2)]

    def run(chunk, cache):
        pam = make_pam(max_len=64)
        eng = make_engine(cfg, params, pam=pam, name="e", max_batch=1,
                          max_len=64, block_size=8, prefix_cache=cache,
                          prefill_chunk=chunk)
        for i, p in enumerate(prompts):
            eng.submit(Request(id=i, prompt=p, max_new_tokens=6))
        eng.run()
        return _streams(eng, range(2)), eng.summary()

    ref, _ = run(0, False)
    plain, s_plain = run(0, True)
    chunked, s_chunk = run(8, True)
    assert plain == ref and chunked == ref
    # same trie behavior either way: one hit, one CoW of the shared tail
    for s in (s_plain, s_chunk):
        assert s["prefix_hits"] == 1 and s["cow_copies"] == 1
    assert s_chunk["chunked_admissions"] >= 1


# ------------------------------------------------- dispatch + interleave
def test_decode_single_dispatch_and_interleave_while_chunking():
    cfg, eng = _chunk_engine("dev", chunk=8, max_len=96)
    short = make_requests(1, cfg.vocab, plen=8, max_new=24)[0]
    long_ = Request(id=1,
                    prompt=np.random.default_rng(9).integers(
                        0, cfg.vocab, 40),
                    max_new_tokens=4)
    eng.submit(short)
    eng.step()                          # short is RUNNING
    assert eng.requests[0].status == RUNNING
    eng.submit(long_)
    eng.step()                          # long admits its first slice
    assert eng.requests[1].status == PREFILLING
    emitted = [len(eng.requests[0].outputs)]
    while eng.requests[1].status == PREFILLING:
        d0 = eng.decode_dispatches
        eng.step()
        emitted.append(len(eng.requests[0].outputs))
        # decode stays ONE fused dispatch per step while a slice fills
        assert eng.decode_dispatches - d0 == 1
    # the running request kept streaming during every fill step
    assert all(b - a == 1 for a, b in zip(emitted, emitted[1:]))
    eng.run()
    assert eng.decode_dispatches == eng.decode_device_steps
    s = eng.summary()
    assert s["chunk_slices"] == len(plan_slices(0, 40, 8))
    assert s["max_chunk_slice_tokens"] <= 8


def test_chunk_slice_lengths_bounded_by_budget():
    cfg, eng = _chunk_engine("dev", chunk=16, max_len=96)
    rng = np.random.default_rng(2)
    for i, plen in enumerate((70, 33, 17)):
        eng.submit(Request(id=i, prompt=rng.integers(0, cfg.vocab, plen),
                           max_new_tokens=4))
    eng.run()
    s = eng.summary()
    assert s["chunked_admissions"] == 3
    assert s["max_chunk_slice_tokens"] <= 16
    assert s["chunk_slices"] == sum(
        len(plan_slices(0, p, 16)) for p in (70, 33, 17))


# ------------------------------------- PR 7 follow-on: batched trie path
def test_trie_and_plain_admissions_batch_in_one_commit():
    """A prefix-cache hit and a plain same-bucket admission arriving
    together ride ONE batched suffix prefill + ONE donated multi-slot
    commit, and the plain rider's stream is untouched by sharing."""
    cfg, params = build_model()
    rng = np.random.default_rng(6)
    parent = rng.integers(0, cfg.vocab, 24)       # 3 full blocks
    child = np.concatenate([parent[:16],          # trie hit: 16 cached,
                            rng.integers(0, cfg.vocab, 12)])  # 12 novel
    plain = rng.integers(0, cfg.vocab, 14)        # novel bucket 16, like
    #                                               the child's suffix

    pam = make_pam(max_len=64)
    eng = make_engine(cfg, params, pam=pam, name="dev", max_batch=4,
                      max_len=64, block_size=8, prefix_cache=True)
    eng.submit(Request(id=0, prompt=parent, max_new_tokens=4))
    eng.step()                                    # parent published
    eng.submit(Request(id=1, prompt=child, max_new_tokens=4))
    eng.submit(Request(id=2, prompt=plain, max_new_tokens=4))
    p0, a0 = eng.prefill_dispatches, eng.admit_dispatches
    eng.step()
    assert eng.prefill_dispatches - p0 == 1       # one batched prefill
    assert eng.admit_dispatches - a0 == 1         # one multi-slot commit
    assert eng.summary()["prefix_hits"] == 1
    eng.run()

    ref = make_engine(cfg, params, pam=make_pam(max_len=64), name="ref",
                      max_batch=4, max_len=64, block_size=8)
    for rid, prompt in ((0, parent), (1, child), (2, plain)):
        ref.submit(Request(id=rid, prompt=prompt, max_new_tokens=4))
    ref.run()
    assert _streams(eng, range(3)) == _streams(ref, range(3))
