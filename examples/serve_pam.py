"""End-to-end serving example (the paper's primary scenario): batch a
request stream through the PAM engine and compare against the
vLLM-offloading baseline under the SAME modeled hardware.

    PYTHONPATH=src python examples/serve_pam.py
"""

import jax
import numpy as np

from repro.perfmodel import make_latency_model
from repro.models import transformer as tfm
from repro.models.config import get_config, reduced
from repro.perfmodel.model import LLAMA3_70B, SystemKind, make_system
from repro.serving import (EngineSpec, PAMManagerConfig, Request,
                           ServingConfig)

cfg = reduced(get_config("pam-llama-7b"))
params = tfm.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(7)

prompts = [rng.integers(0, cfg.vocab, rng.integers(12, 40))
           for _ in range(10)]

results = {}
for system in (SystemKind.PAM, SystemKind.LSPIM, SystemKind.VLLM_OFFLOAD):
    pam_cfg = None
    if system != SystemKind.VLLM_OFFLOAD:   # baseline has no PIM manager
        pam_cfg = PAMManagerConfig(
            max_tokens=128, hot_capacity=16, warm_capacity=32,
            compression=4, recency_window=4, schedule_interval=2,
            use_tiering=(system == SystemKind.PAM))
    eng = EngineSpec(
        model=cfg,
        serving=ServingConfig(max_batch=4, max_len=128,
                              pam=pam_cfg)).build(
        params,
        # each engine token models 16384 hardware tokens: the run exercises
        # the paper-scale hierarchy (vLLM's offload spills past HBM; PAM's
        # sparse working set stays on HBM-PIM)
        latency_model=make_latency_model(make_system(system), LLAMA3_70B,
                                         context_scale=16384))
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p, max_new_tokens=24))
    results[system.value] = eng.run()
    s = results[system.value]
    print(f"{system.value:14s}  tput={s['throughput_tok_s']:8.0f} tok/s  "
          f"p50_tpot={s['p50_tpot_s']*1e3:6.2f} ms  "
          f"p99_tpot={s['p99_tpot_s']*1e3:6.2f} ms")

# the paper's SLO metric is decode per-token latency (TPOT)
speedup = (results["vllm-offload"]["p50_tpot_s"]
           / results["pam"]["p50_tpot_s"])
print(f"\nPAM vs vLLM-offloading p50 TPOT (same engine, modeled "
      f"hardware): {speedup:.1f}x faster")
assert speedup > 5.0
