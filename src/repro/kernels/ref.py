"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function is the mathematically-direct implementation the kernels are
``assert_allclose``'d against across shape/dtype sweeps (interpret=True on
CPU, compiled on TPU).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        scale: float | None = None) -> jax.Array:
    """q: (B, H, S, d); k/v: (B, H_kv, S, d). Monolithic softmax attention."""
    B, H, Sq, d = q.shape
    H_kv, Sk = k.shape[1], k.shape[2]
    rep = H // H_kv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    kh = jnp.repeat(k, rep, axis=1).astype(jnp.float32)
    vh = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kh) * scale
    if causal:
        qpos = jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Sk)[None, :]
        s = jnp.where(kpos <= qpos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vh)
    return out.astype(q.dtype)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array | None = None, *,
                     kv_len: int | None = None,
                     scale: float | None = None) -> jax.Array:
    """q: (B, H, d); k/v: (B, H_kv, S, d); mask: (B, S). One decode step."""
    B, H, d = q.shape
    H_kv, S = k.shape[1], k.shape[2]
    rep = H // H_kv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    live = jnp.ones((B, S), bool) if mask is None else mask.astype(bool)
    if kv_len is not None:
        live = live & (jnp.arange(S)[None, :] < kv_len)
    kh = jnp.repeat(k, rep, axis=1).astype(jnp.float32)
    vh = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), kh) * scale
    s = jnp.where(live[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # all-masked rows
    out = jnp.einsum("bhs,bhsd->bhd", p, vh)
    return out.astype(q.dtype)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array, d_skip: jax.Array) -> jax.Array:
    """Sequential (scan) oracle of the SSD recurrence.

    x: (B, L, H, P); dt: (B, L, H); a: (H,); b/c: (B, L, G, N); d_skip: (H,).
    """
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    bh = jnp.repeat(b, rep, axis=2).astype(jnp.float32)   # (B, L, H, N)
    ch = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(h_state, inp):
        xt, dtt, bt, ct = inp           # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dtt * af)[..., None, None]       # (B,H,1,1)
        upd = dtt[..., None, None] * bt[..., :, None] * xt[..., None, :]
        h_state = decay * h_state + upd                   # (B,H,N,P)
        yt = jnp.einsum("bhn,bhnp->bhp", ct, h_state)
        return h_state, yt

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bh, 1, 0), jnp.moveaxis(ch, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + d_skip[None, None, :, None] * xf
    return y.astype(x.dtype)
