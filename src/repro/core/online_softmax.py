"""Online-softmax partial-attention algebra (PAMattention §5.1, Alg. 1).

The core identity: softmax-attention over a concatenated KV set equals the
exact merge of per-partition partial results, where each partition carries
``(O, m, l)``:

    O_t = sum_j exp(s_j - m_t) v_j     (unnormalized partial output)
    m_t = max_j s_j                    (partition max logit)
    l_t = sum_j exp(s_j - m_t)         (partition normalizer at m_t)

Merging partitions t in any order/grouping (associative + commutative):

    m* = max_t m_t
    O  = sum_t exp(m_t - m*) O_t
    l  = sum_t exp(m_t - m*) l_t
    attention = O / l

This file is the pure-JAX reference algebra used by: the Pallas decode
kernel's intra-device reduction (paper's bank-group RUs), the inter-device
``shard_map`` merge (paper's HBM-PIM global reduction), and the property
tests.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AttnPartial(NamedTuple):
    """Partial attention state for one KV partition.

    Shapes (leading batch/head dims ``...`` are arbitrary):
      o: (..., d)   unnormalized output  sum exp(s - m) * v
      m: (...,)     running max logit
      l: (...,)     running normalizer  sum exp(s - m)
    """

    o: jax.Array
    m: jax.Array
    l: jax.Array


# Identity element: m = -inf, o = 0, l = 0. exp(-inf - m*) = 0 kills it.
def empty_partial(d: int, batch_shape: tuple[int, ...] = (),
                  dtype=jnp.float32) -> AttnPartial:
    return AttnPartial(
        o=jnp.zeros(batch_shape + (d,), dtype),
        m=jnp.full(batch_shape, -jnp.inf, dtype),
        l=jnp.zeros(batch_shape, dtype),
    )


def local_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    scale: float | None = None,
                    mask: jax.Array | None = None) -> AttnPartial:
    """Alg. 1 ``Local_Attention``: partial attention over one KV partition.

    q: (..., d), k: (..., S, d), v: (..., S, d) -> AttnPartial over (...,).
    ``mask``: optional boolean (..., S); False positions are excluded.
    All math in fp32 for stability regardless of input dtype.
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("...d,...sd->...s", qf, kf) * scale
    if mask is not None:
        s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    # Guard fully-masked partitions: keep m finite inside exp by substitution.
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("...s,...sd->...d", p, vf)
    return AttnPartial(o=o, m=m, l=l)


def merge_partials(a: AttnPartial, b: AttnPartial) -> AttnPartial:
    """Alg. 1 ``Reduction`` for two partials — associative & commutative."""
    m = jnp.maximum(a.m, b.m)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    wa = jnp.where(jnp.isfinite(a.m), jnp.exp(a.m - m_safe), 0.0)
    wb = jnp.where(jnp.isfinite(b.m), jnp.exp(b.m - m_safe), 0.0)
    return AttnPartial(
        o=wa[..., None] * a.o + wb[..., None] * b.o,
        m=m,
        l=wa * a.l + wb * b.l,
    )


def merge_many(partials: AttnPartial) -> AttnPartial:
    """Reduce a stacked AttnPartial whose leading axis indexes partitions.

    o: (T, ..., d), m/l: (T, ...). Single-pass exact merge (the paper's
    inter-device reduction: find global max, rescale, accumulate).
    """
    m_star = jnp.max(partials.m, axis=0)
    m_safe = jnp.where(jnp.isfinite(m_star), m_star, 0.0)
    w = jnp.where(jnp.isfinite(partials.m),
                  jnp.exp(partials.m - m_safe[None]), 0.0)
    o = jnp.sum(w[..., None] * partials.o, axis=0)
    l = jnp.sum(w * partials.l, axis=0)
    return AttnPartial(o=o, m=m_star, l=l)


def tree_merge(partials: AttnPartial) -> AttnPartial:
    """Hierarchical (binary-tree) reduction — models the paper's tiered RUs.

    Numerically equivalent to ``merge_many``; exercised by property tests to
    certify that any reduction topology (intra-bank -> intra-device ->
    inter-device) yields the same result.
    """
    t = partials.o.shape[0]
    if t == 1:
        return AttnPartial(partials.o[0], partials.m[0], partials.l[0])
    half = t // 2
    left = tree_merge(AttnPartial(partials.o[:half], partials.m[:half],
                                  partials.l[:half]))
    right = tree_merge(AttnPartial(partials.o[half:], partials.m[half:],
                                   partials.l[half:]))
    return merge_partials(left, right)


def finalize(p: AttnPartial, out_dtype=None) -> jax.Array:
    """Normalize a merged partial into the attention output O / l."""
    l_safe = jnp.where(p.l > 0, p.l, 1.0)
    out = p.o / l_safe[..., None]
    if out_dtype is not None:
        out = out.astype(out_dtype)
    return out


def attention_from_partitions(q: jax.Array, ks: list[jax.Array],
                              vs: list[jax.Array],
                              scale: float | None = None,
                              masks: list[jax.Array] | None = None,
                              out_dtype=None) -> jax.Array:
    """End-to-end Alg. 1: local attention per partition + exact merge."""
    if masks is None:
        masks = [None] * len(ks)
    acc = None
    for k, v, msk in zip(ks, vs, masks):
        part = local_attention(q, k, v, scale=scale, mask=msk)
        acc = part if acc is None else merge_partials(acc, part)
    assert acc is not None, "need at least one partition"
    return finalize(acc, out_dtype=out_dtype or q.dtype)


def reference_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        scale: float | None = None,
                        mask: jax.Array | None = None,
                        out_dtype=None) -> jax.Array:
    """Monolithic softmax attention oracle (what Alg. 1 must equal)."""
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("...d,...sd->...s", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if mask is not None:
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("...s,...sd->...d", p, v.astype(jnp.float32))
    return out.astype(out_dtype or q.dtype)
