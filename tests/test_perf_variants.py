"""§Perf variant correctness: every hillclimbing optimization must be
numerics-preserving (or bounded, for precision changes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import perf_flags
from repro.models.attention import chunked_attention, sp_attention

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(autouse=True)
def _reset_flags():
    yield
    perf_flags.set_flags()


def _qkv(seed, B=2, S=64, H=8, Hkv=4, d=16):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, S, H, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, d))
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
def test_sp_attention_matches_chunked(causal):
    q, k, v = _qkv(0)
    want = chunked_attention(q, k, v, causal=causal, chunk=16)
    perf_flags.set_flags("sp_attn")
    got = sp_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_bf16_probs_bounded_error():
    q, k, v = _qkv(1)
    want = chunked_attention(q, k, v, causal=True, chunk=16)
    perf_flags.set_flags("bf16_probs")
    got = chunked_attention(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_remat_dots_same_loss_and_grads():
    from repro.models import transformer as tf
    from repro.models.config import get_config, reduced
    cfg = reduced(get_config("qwen3-0.6b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(2))
    key = jax.random.PRNGKey(3)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab),
             "labels": jax.random.randint(key, (2, 16), 0, cfg.vocab)}

    loss_fn = lambda p: tf.loss_fn(cfg, p, batch, remat=True)
    l0, g0 = jax.value_and_grad(loss_fn)(params)
    perf_flags.set_flags("remat_dots")
    l1, g1 = jax.value_and_grad(loss_fn)(params)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_moe_pin_is_noop_numerically():
    import dataclasses
    from repro.models import moe as moe_mod
    from repro.models.config import get_config, reduced
    cfg = reduced(get_config("qwen3-moe-235b-a22b"))
    mcfg = dataclasses.replace(cfg.moe, capacity_factor=2.0)
    p = moe_mod.init_moe(jax.random.PRNGKey(4), cfg.d_model, mcfg,
                         jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, cfg.d_model))
    y0, _ = moe_mod.moe_forward(p, x, mcfg)
    perf_flags.set_flags("moe_pin")
    y1, _ = moe_mod.moe_forward(p, x, mcfg)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-6)
