"""Paged warm/cold KV pool + in-kernel block-table gather (PR 2).

Covers the acceptance surface: dense-vs-paged decode equivalence through
the real serving engine, the Pallas kernel's table walk against the jnp
reference gather, allocator reuse-after-free / no-double-mapping,
migration-as-table-edit preserving attention output, the one-fused-
dispatch-per-step invariant with block tables, and the sparse-read
accounting (pages touched < dense-window pages).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pam_interface, tiers
from repro.core.tiers import COLD, HOT, WARM
from repro.kernels import ops as kops
from conftest import build_model, make_pam

from repro.models import transformer as tf
from repro.serving import (BlockAllocator, EngineSpec, OutOfBlocks,
                           PAMManagerConfig, Request, ServingConfig)

jax.config.update("jax_platform_name", "cpu")


# ----------------------------------------------------------- kernel / ops
def _rand_pool(key, NB, bs, Hkv, d):
    pk = jax.random.normal(jax.random.fold_in(key, 1), (NB + 1, bs, Hkv, d))
    pv = jax.random.normal(jax.random.fold_in(key, 2), (NB + 1, bs, Hkv, d))
    return pk, pv


@pytest.mark.parametrize("rep", [1, 4])
@pytest.mark.parametrize("bs", [8, 16])
def test_paged_kernel_matches_reference_gather(rep, bs):
    """flash_decode_paged (interpret mode, block table walked in-grid)
    equals the jnp gather-through-table reference partial."""
    B, Hkv, d, NB, nb = 3, 2, 16, 12, 4
    H = Hkv * rep
    key = jax.random.PRNGKey(rep * 31 + bs)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, d))
    pk, pv = _rand_pool(key, NB, bs, Hkv, d)
    bt = jax.random.randint(jax.random.fold_in(key, 3), (B, nb), 0, NB)
    mask = jax.random.uniform(jax.random.fold_in(key, 4),
                              (B, nb * bs)) < 0.4
    got = kops.paged_decode_attention_partial(q, pk, pv, bt, mask,
                                              use_kernel=True,
                                              interpret=True)
    ref = kops.paged_decode_attention_partial(q, pk, pv, bt, mask,
                                              use_kernel=False)
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def _mirrored_pool(kc, vc, bs):
    """Build a pool + disjoint per-sequence tables mirroring a dense
    (B, Hkv, S, d) cache, S a block multiple."""
    B, Hkv, S, d = kc.shape
    nb = S // bs
    table = (jnp.arange(nb)[None, :] + jnp.arange(B)[:, None] * nb)
    pool_k = jnp.zeros((B * nb + 1, bs, Hkv, d)).at[:B * nb].set(
        jnp.moveaxis(kc, 1, 2).reshape(B * nb, bs, Hkv, d))
    pool_v = jnp.zeros((B * nb + 1, bs, Hkv, d)).at[:B * nb].set(
        jnp.moveaxis(vc, 1, 2).reshape(B * nb, bs, Hkv, d))
    return pool_k, pool_v, table.astype(jnp.int32)


def test_paged_tiered_attention_equals_dense_masked():
    """Hot(dense) ⊕ paged(pool) merged partials == one masked softmax
    over the union participation set — for any tier split."""
    B, H, Hkv, d, S, bs = 3, 8, 2, 16, 32, 8
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, d))
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d))
    pool_k, pool_v, table = _mirrored_pool(kc, vc, bs)
    lens = jnp.array([32, 20, 9])
    live = jnp.arange(S)[None, :] < lens[:, None]
    part = jax.random.uniform(jax.random.fold_in(key, 3), (B, S)) < 0.7
    hot = jax.random.uniform(jax.random.fold_in(key, 4), (B, S)) < 0.5
    hot_m = hot & part & live
    pgd_m = ~hot & part & live
    out_p, mass_p = kops.paged_masked_decode_attention(
        q, kc, vc, pool_k, pool_v, table, hot_m, pgd_m, lens,
        use_kernel=False)
    out_d, mass_d = kops.masked_decode_attention(q, kc, vc, part, lens,
                                                 use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mass_p), np.asarray(mass_d),
                               rtol=1e-4, atol=1e-5)


def test_migration_is_a_table_edit():
    """Alg. 2 tier moves re-tag tokens; with a shared pool NO pool bytes
    change and the merged attention output is invariant to the split."""
    B, H, Hkv, d, S, bs = 2, 4, 2, 16, 32, 8
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, H, d))
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, S, d))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, S, d))
    pool_k, pool_v, table = _mirrored_pool(kc, vc, bs)
    lens = jnp.full((B,), S)
    part = jax.random.uniform(jax.random.fold_in(key, 3), (B, S)) < 0.6

    tier = jax.random.randint(jax.random.fold_in(key, 4), (B, S), 0, 3)
    moved = jax.random.uniform(jax.random.fold_in(key, 5), (B, S)) < 0.3
    tier2 = pam_interface.migrate_tier_tags(tier, moved, WARM)
    assert int(jnp.sum(tier2 != tier)) > 0     # something migrated

    outs = []
    for t in (tier, tier2):
        hot_m = part & (t == HOT)
        pgd_m = part & (t != HOT)
        out, _ = kops.paged_masked_decode_attention(
            q, kc, vc, pool_k, pool_v, table, hot_m, pgd_m, lens,
            use_kernel=False)
        outs.append(np.asarray(out))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)


def test_block_residency_summary():
    tier = jnp.array([[HOT, HOT, WARM, WARM, COLD, COLD, COLD, COLD]])
    valid = jnp.array([[True] * 6 + [False] * 2])
    res = tiers.block_residency(tier, valid, 4)
    np.testing.assert_array_equal(np.asarray(res), [[HOT, COLD]])
    counts = tiers.blocks_per_tier(tier, valid, 4)
    assert int(counts[HOT]) == 1 and int(counts[COLD]) == 1


# -------------------------------------------------------------- allocator
def test_allocator_reuse_after_free():
    alloc = BlockAllocator(num_blocks=8, block_size=4)
    t0 = list(alloc.allocate(0, 16))           # 4 blocks
    t1 = list(alloc.allocate(1, 16))           # 4 blocks — pool full
    assert alloc.check_no_double_mapping()
    with pytest.raises(OutOfBlocks):
        alloc.allocate(2, 4)
    alloc.free(0)
    t2 = list(alloc.allocate(2, 16))
    assert set(t2) == set(t0)                  # physical ids recycled
    assert alloc.check_no_double_mapping()
    assert not (set(t2) & set(t1))
    row = alloc.padded_table(2, 8, sentinel=8)
    assert row.shape == (8,)
    assert list(row[4:]) == [8] * 4            # unmapped -> sentinel


# ---------------------------------------------------------- serving engine
def _engine(block_size=0, pool_blocks=None, micro_steps=1, max_batch=3,
            max_len=64, hot=4, warm=8, seed=0):
    cfg, params = build_model("qwen3-0.6b", seed=seed)
    pam = make_pam(max_len=max_len, hot=hot, warm=warm, recency_window=2)
    return cfg, EngineSpec(model=cfg, serving=ServingConfig(
        max_batch=max_batch, max_len=max_len, pam=pam,
        micro_steps=micro_steps, block_size=block_size,
        pool_blocks=pool_blocks)).build(params)


def _submit(cfg, eng, n=4, plen=30, max_new=10, seed=0):
    rng = np.random.default_rng(seed)
    for i in range(n):
        eng.submit(Request(id=i, prompt=rng.integers(0, cfg.vocab, plen),
                           max_new_tokens=max_new))


def test_paged_engine_tokens_match_dense_engine():
    """The paged block-table decode path emits the same greedy tokens as
    the dense path — storage layout, not math. Long prompts + tiny hot
    capacity force real warm/cold (paged) reads."""
    cfg, e_dense = _engine(block_size=0)
    _submit(cfg, e_dense)
    e_dense.run()
    cfg2, e_paged = _engine(block_size=8)
    _submit(cfg2, e_paged)
    s = e_paged.run()
    for rid in e_dense.requests:
        assert (e_dense.requests[rid].outputs
                == e_paged.requests[rid].outputs), rid
    # the paged gather engaged and skipped pages
    assert s["blocks_touched_per_step"] > 0
    assert s["blocks_touched_per_step"] < s["blocks_window_per_step"]


def test_paged_fastpath_micro_loop_matches():
    cfg, e_sync = _engine(block_size=8, micro_steps=1)
    _submit(cfg, e_sync)
    e_sync.run()
    cfg2, e_fast = _engine(block_size=8, micro_steps=4)
    _submit(cfg2, e_fast)
    summary = e_fast.run()
    for rid in e_sync.requests:
        assert (e_sync.requests[rid].outputs
                == e_fast.requests[rid].outputs), rid
    assert summary["decode_dispatches"] < summary["decode_device_steps"]


def test_paged_single_dispatch_per_step_and_donation():
    """Block tables don't break the fused fast path: ONE decode dispatch
    per engine step, and the cache (incl. pools), PAM state (incl. the
    block table) and token vector are donated."""
    cfg, eng = _engine(block_size=8, max_batch=2)
    _submit(cfg, eng, n=2, plen=20, max_new=6)

    calls = {"decode": 0}
    fused_real = eng._get_micro(1)
    eng._micro_jits[1] = (
        lambda *a, **k: (calls.__setitem__("decode", calls["decode"] + 1),
                         fused_real(*a, **k))[1])
    eng.step()
    assert calls["decode"] == 1
    pk_buf = eng.cache.pk
    tbl_buf = eng.pam_state.block_table
    k_buf = eng.cache.k
    for _ in range(3):
        eng.step()
    assert calls["decode"] == 4
    assert eng.decode_dispatches == 4
    assert pk_buf.is_deleted()          # pools donated, not copied
    assert tbl_buf.is_deleted()         # table rides the donated state
    assert k_buf.is_deleted()


def test_paged_capacity_backpressure_and_reuse():
    """A pool too small for two concurrent windows serializes admission
    (OutOfBlocks never escapes), recycles freed blocks, and finishes
    every request."""
    # each request needs ceil((20+6)/8) = 4 blocks; pool holds 5
    cfg, eng = _engine(block_size=8, pool_blocks=5, max_batch=3)
    _submit(cfg, eng, n=3, plen=20, max_new=6)
    out = eng.run()
    assert out["finished"] == 3
    assert eng.allocator.check_no_double_mapping()
    assert eng.allocator.free_blocks == 5
    assert out["pool_occupancy_peak"] <= 1.0
    for rid, rs in eng.requests.items():
        assert len(rs.outputs) == rs.request.max_new_tokens, rid


def test_paged_config_validation():
    cfg, params = build_model("qwen3-0.6b")
    with pytest.raises(ValueError):   # paged requires PAM tiers
        EngineSpec(model=cfg, serving=ServingConfig(
            max_batch=2, max_len=64, block_size=8)).build(params)
    pam = PAMManagerConfig(max_tokens=60, hot_capacity=4, warm_capacity=8)
    with pytest.raises(ValueError):   # max_len must be a block multiple
        EngineSpec(model=cfg, serving=ServingConfig(
            max_batch=2, max_len=60, pam=pam, block_size=8)).build(params)
    pam64 = PAMManagerConfig(max_tokens=64, hot_capacity=4,
                             warm_capacity=8)
    with pytest.raises(ValueError):   # pool_blocks must be positive
        EngineSpec(model=cfg, serving=ServingConfig(
            max_batch=2, max_len=64, pam=pam64, block_size=8,
            pool_blocks=0)).build(params)


def test_unservable_request_fails_loudly():
    """A request whose window can never fit the pool raises instead of
    starving the queue forever (backpressure only helps when waiting
    can)."""
    cfg, eng = _engine(block_size=8, pool_blocks=2)
    _submit(cfg, eng, n=1, plen=20, max_new=6)   # needs 4 blocks > 2
    with pytest.raises(ValueError, match="blocks"):
        eng.run()


def test_paged_cache_requires_append_coords():
    """decode_step refuses a paged cache without append coordinates —
    a silent dense fall-back would desync the pool mirror."""
    cfg, params = build_model("qwen3-0.6b")
    cache = tf.init_decode_cache(cfg, 2, 32, paged_blocks=8, block_size=8)
    with pytest.raises(ValueError):
        tf.decode_step(cfg, params, jnp.zeros((2,), jnp.int32), cache)


def test_init_decode_cache_rejects_paged_for_cacheless_family():
    cfg = build_model("mamba2-780m")[0]
    with pytest.raises(ValueError):
        tf.init_decode_cache(cfg, 2, 32, paged_blocks=8, block_size=8)
