"""Prefix-sharing benchmark (PR 7): share-ratio sweep.

Serves the SAME staggered trace (max_batch 2, so later waves can hit
prefixes earlier waves published) at increasing prompt share ratios —
the fraction of each prompt drawn from a common prefix — once with the
prefix cache on and once with it off (the twin). Records, per ratio:

* novel vs cached prefill tokens (cached = zero prefill compute)
* prefill FLOPs saved, charged at the standard 2 * params per token
* peak paged-pool occupancy (shared blocks count ONCE — the capacity
  win) on the cache engine vs the twin
* tokens lost — positionwise token-stream diff vs the twin, which the
  PR 7 acceptance invariant pins at ZERO (sharing is exact, not lossy)
"""

from __future__ import annotations

import numpy as np


def _streams(eng, rids):
    return {i: list(eng.requests[i].outputs) for i in rids}


def _tokens_lost(ref: dict, got: dict) -> int:
    lost = 0
    for i, r in ref.items():
        g = got[i]
        lost += sum(a != b for a, b in zip(r, g)) + abs(len(r) - len(g))
    return lost


def prefix_sweep(share_ratios=(0.0, 0.25, 0.5, 0.75), n_requests=8,
                 plen=32, max_new=8) -> dict:
    import jax
    from repro.models import transformer as tf
    from repro.models.config import get_config, reduced
    from repro.serving import (EngineSpec, PAMManagerConfig, Request,
                               ServingConfig)

    cfg = reduced(get_config("qwen3-0.6b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    n_params = cfg.param_count()

    def engine(prefix_cache):
        pam = PAMManagerConfig(max_tokens=64, hot_capacity=16,
                               warm_capacity=24, compression=4,
                               recency_window=4, schedule_interval=2)
        return EngineSpec(model=cfg, serving=ServingConfig(
            max_batch=2, max_len=64, pam=pam, block_size=8,
            prefix_cache=prefix_cache)).build(params)

    points = {}
    tokens_lost_total = 0
    for r in share_ratios:
        rng = np.random.default_rng(17)
        shared = rng.integers(0, cfg.vocab, int(round(r * plen)))
        prompts = {i: np.concatenate([
            shared, rng.integers(0, cfg.vocab, plen - len(shared))])
            for i in range(n_requests)}
        runs = {}
        for cache in (False, True):
            eng = engine(cache)
            for i in sorted(prompts):
                eng.submit(Request(id=i, prompt=prompts[i],
                                   max_new_tokens=max_new))
            summary = eng.run()
            runs[cache] = (summary, _streams(eng, prompts))
        summary, streams = runs[True]
        lost = _tokens_lost(runs[False][1], streams)
        tokens_lost_total += lost
        cached = summary["cached_prefix_tokens"]
        points[f"{r:.2f}"] = {
            "share_ratio": r,
            "prompt_tokens": int(n_requests * plen),
            "novel_prefill_tokens": int(summary["novel_prefill_tokens"]),
            "cached_prefix_tokens": int(cached),
            "prefix_hits": int(summary["prefix_hits"]),
            "cow_copies": int(summary["cow_copies"]),
            "prefill_flops_saved": float(2.0 * n_params * cached),
            "pool_occupancy_peak": float(summary["pool_occupancy_peak"]),
            "pool_occupancy_peak_nocache":
                float(runs[False][0]["pool_occupancy_peak"]),
            "tokens_lost": int(lost),
        }
    lo, hi = f"{share_ratios[0]:.2f}", f"{share_ratios[-1]:.2f}"
    return {
        "points": points,
        "tokens_lost_total": int(tokens_lost_total),
        "flops_saved_at_half": points.get(
            "0.50", points[hi])["prefill_flops_saved"],
        "occupancy_drop_lo_to_hi": (points[lo]["pool_occupancy_peak"]
                                    - points[hi]["pool_occupancy_peak"]),
        "model_params": int(n_params),
    }


def prefix_rows(result: dict | None = None) -> tuple[dict, list[tuple]]:
    if result is None:
        result = prefix_sweep()
    rows = []
    for key in sorted(result["points"]):
        p = result["points"][key]
        rows.append((
            f"prefix/share_{key}", 0.0,
            f"novel={p['novel_prefill_tokens']} "
            f"cached={p['cached_prefix_tokens']} "
            f"flops_saved={p['prefill_flops_saved']:.3g} "
            f"occupancy={p['pool_occupancy_peak']:.3f} "
            f"lost={p['tokens_lost']}"))
    rows.append(("prefix/summary", 0.0,
                 f"tokens_lost={result['tokens_lost_total']} "
                 f"occupancy_drop={result['occupancy_drop_lo_to_hi']:.3f}"))
    return result, rows


if __name__ == "__main__":
    _, rows = prefix_rows()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
