"""Deterministic, seeded fault injection for the serving cluster.

A chaos run is a LIST of ``FaultEvent``s pinned to router ticks — the
same spec + seed always produces the same failure trace, so recovery
tests and the chaos benchmark are exactly reproducible. The router
polls ``FaultInjector.due(tick)`` at the top of every tick and applies
device faults itself; transfer faults (drop / corrupt) arm a verdict
queue that the recovery manager consumes on each snapshot transfer.

Fault kinds
-----------
- ``kill``      device stops mid-decode: no more steps, no heartbeats.
                In-flight KV is LOST — recovery must replay.
- ``stall``     straggler: the device keeps serving but every modeled
                step costs ``factor``x (thermal throttle, failing NIC).
- ``unstall``   clears a stall.
- ``drop``      the next ``count`` snapshot transfers vanish in flight
                (timeout at the receiver -> retry).
- ``corrupt``   the next ``count`` snapshot transfers arrive with
                flipped KV bytes (checksum mismatch -> retry).
- ``exhaust``   hog every free pool block on the device (admission
                starvation — drives preemption-by-demotion).
- ``release``   frees a previous ``exhaust`` hog.

Spec grammar (``--chaos``): comma-separated events,
``kind[:device]@tick`` with optional suffixes ``xFACTOR`` (stall) and
``*COUNT`` (drop/corrupt), e.g.::

    kill:hbm0@120, stall:cxl0@50x8, corrupt@30*2, exhaust:cxl1@25
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np

DEVICE_KINDS = ("kill", "stall", "unstall", "exhaust", "release")
TRANSFER_KINDS = ("drop", "corrupt")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    tick: int                 # router tick at which the fault fires
    kind: str                 # see module docstring
    device: str = ""          # target name; "" for transfer faults
    factor: float = 4.0       # stall slowdown multiplier
    count: int = 1            # transfers affected (drop/corrupt)

    def __post_init__(self):
        if self.kind not in DEVICE_KINDS + TRANSFER_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind in DEVICE_KINDS and not self.device:
            raise ValueError(f"{self.kind} fault needs a device name")


def parse_chaos(spec: str) -> list[FaultEvent]:
    """Parse the ``--chaos`` grammar (module docstring) into events."""
    events: list[FaultEvent] = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        head, _, tickpart = item.partition("@")
        if not tickpart:
            raise ValueError(f"fault {item!r}: missing '@tick'")
        kind, _, device = head.partition(":")
        factor, count = 4.0, 1
        if "x" in tickpart:
            tickpart, _, f = tickpart.partition("x")
            factor = float(f)
        if "*" in tickpart:
            tickpart, _, c = tickpart.partition("*")
            count = int(c)
        events.append(FaultEvent(tick=int(tickpart), kind=kind,
                                 device=device, factor=factor,
                                 count=count))
    return events


class FaultInjector:
    """Replays a fault trace against the router (see module docstring).

    ``seed`` drives only the corruption byte positions — the trace
    itself is fully determined by the event list.
    """

    def __init__(self, events: list[FaultEvent], seed: int = 0):
        self._pending = sorted(events, key=lambda e: e.tick)
        self._rng = np.random.default_rng(seed)
        self._verdicts: collections.deque[str] = collections.deque()
        self.fired: list[FaultEvent] = []

    @classmethod
    def from_spec(cls, spec: str, seed: int = 0) -> "FaultInjector":
        return cls(parse_chaos(spec), seed=seed)

    # ------------------------------------------------------------ schedule
    def due(self, tick: int) -> list[FaultEvent]:
        """Pop every event scheduled at or before ``tick``. Transfer
        faults are armed internally and returned for logging only."""
        out: list[FaultEvent] = []
        while self._pending and self._pending[0].tick <= tick:
            ev = self._pending.pop(0)
            if ev.kind in TRANSFER_KINDS:
                self._verdicts.extend([ev.kind] * ev.count)
            out.append(ev)
            self.fired.append(ev)
        return out

    @property
    def exhausted(self) -> bool:
        return not self._pending and not self._verdicts

    # ------------------------------------------------------------ transfers
    def transfer_verdict(self) -> str:
        """Fate of the next snapshot transfer: 'ok', 'drop' or
        'corrupt' (armed verdicts are consumed in order)."""
        return self._verdicts.popleft() if self._verdicts else "ok"

    def corrupt(self, snap) -> None:
        """Flip a few KV bytes of a wire-copy ``KVSnapshot`` in place
        (the checksum seal is left as sealed at export, so ``verify``
        catches the damage)."""
        flat = snap.k.reshape(-1).view(np.uint8)
        idx = self._rng.integers(0, flat.size, size=8)
        flat[idx] ^= 0xFF
