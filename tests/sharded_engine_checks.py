"""Sharded-engine checks — executed by test_sharded_engine.py in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set
BEFORE jax import, which is why this is a standalone script).

The PR 10 acceptance bar: a shard-N engine built from the SAME
``EngineSpec`` (only ``shard`` differing) emits token streams
BIT-IDENTICAL to the unsharded engine — greedy, sampled, and with
``micro_steps=8`` — while keeping the 1-dispatch/step and donation
invariants; a request migrated mid-decode between engines of DIFFERENT
shard counts continues bit-exactly; and a 2-way replica group serves
from ~1/2 the param bytes per device that a full copy would take.

Checks:
  1. greedy twin exactness at shard 2 and 4 (+ dispatch/donation)
  2. sampled (temperature=1.0) twin exactness at shard 2
  3. micro_steps=8 twin exactness at shard 2
  4. mid-decode migration shard 2 -> shard 4 stays bit-exact (sampled)
  5. replica group: 2-way group param bytes <= 0.6x the full copy,
     cluster streams exact; from_cli round-trip forms the ISSUE's
     "hbm:1,cxl:2 --shard 2" topology
"""
import dataclasses
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import numpy as np  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

from repro.cluster.migration import KVSnapshot  # noqa: E402
from repro.cluster.spec import ClusterSpec  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.models.config import get_config, reduced  # noqa: E402
from repro.perfmodel.devices import HBM_CLASS  # noqa: E402
from repro.serving.engine import Request, ServingConfig  # noqa: E402
from repro.serving.pam_manager import PAMManagerConfig  # noqa: E402
from repro.serving.spec import EngineSpec  # noqa: E402

assert jax.device_count() == 8, jax.device_count()

CFG = reduced(get_config("qwen3-0.6b"))
PARAMS = tf.init_params(CFG, jax.random.PRNGKey(0))
PAM = PAMManagerConfig(max_tokens=64, hot_capacity=8, warm_capacity=16,
                       compression=4, recency_window=4,
                       schedule_interval=2)
SCFG = ServingConfig(pam=PAM, max_batch=2, max_len=64, block_size=8,
                     pool_blocks=23, hot_window=16)


def requests(n=3, plen=20, max_new=10, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(id=i + 1,
                    prompt=rng.integers(1, CFG.vocab, plen),
                    max_new_tokens=max_new) for i in range(n)]


def run(shard, scfg=SCFG, n=3):
    eng = EngineSpec(model=CFG, serving=scfg, shard=shard,
                     name=f"s{shard}").build(PARAMS)
    for r in requests(n):
        eng.submit(r)
    eng.run()
    return {rid: rs.outputs for rid, rs in eng.requests.items()}, eng


def check_greedy_twins_and_invariants():
    base, e1 = run(1)
    full_bytes = e1.params_bytes_per_device()
    for shard in (2, 4):
        got, eng = run(shard)
        assert got == base, f"shard {shard} diverged from unsharded"
        # 1 fused dispatch per device decode step, sharding included
        assert eng.decode_dispatches == eng.decode_device_steps
        assert eng.shard == shard
        assert eng.summary()["shard"] == shard
        # sharded params really occupy ~1/shard of a full copy
        per_dev = eng.params_bytes_per_device()
        assert per_dev <= 0.6 * full_bytes / (shard // 2 or 1), \
            (shard, per_dev, full_bytes)
    # donation: the sharded cache buffers are consumed by the fused
    # step, never copied (capture mid-run, confirm deleted at the end)
    eng = EngineSpec(model=CFG, serving=SCFG, shard=2,
                     name="don").build(PARAMS)
    for r in requests():
        eng.submit(r)
    for _ in range(4):
        eng.step()
    k_buf, pk_buf = eng.cache.k, eng.cache.pk
    tbl_buf = eng.pam_state.block_table
    eng.run()
    assert k_buf.is_deleted() and pk_buf.is_deleted()
    assert tbl_buf.is_deleted()
    print("1. greedy twins exact at shard 2/4; 1 dispatch/step; "
          f"donated; param bytes/device {full_bytes} -> "
          f"{per_dev} at shard 4")


def check_sampled_twins():
    scfg = dataclasses.replace(SCFG, temperature=1.0, sample_seed=11)
    base, _ = run(1, scfg)
    got, _ = run(2, scfg)
    assert got == base, "sampled shard-2 stream diverged"
    print("2. sampled (T=1.0) twins exact at shard 2")


def check_micro_twins():
    scfg = dataclasses.replace(SCFG, micro_steps=8)
    base, _ = run(1, scfg)
    got, eng = run(2, scfg)
    assert got == base, "micro_steps=8 shard-2 stream diverged"
    # the micro loop fuses several device steps into each dispatch
    # (the trailing dispatch runs fewer than 8 when the budget clips)
    assert eng.decode_device_steps > eng.decode_dispatches
    print("3. micro_steps=8 twins exact at shard 2")


def check_cross_shard_migration():
    scfg = dataclasses.replace(SCFG, temperature=1.0, sample_seed=5)
    base, _ = run(1, scfg)
    src = EngineSpec(model=CFG, serving=scfg, shard=2,
                     name="src").build(PARAMS)
    dst = EngineSpec(model=CFG, serving=scfg, shard=4,
                     name="dst").build(PARAMS)
    for r in requests(2):
        src.submit(r)
    for _ in range(4):                       # both mid-decode
        src.step()
    snap = KVSnapshot.export(src, 1)
    assert snap.src_shard == 2               # observability field
    assert snap.verify()
    snap.commit(dst)                         # 2-way ring -> 4-way ring
    src.run()
    dst.run()
    assert dst.requests[1].outputs == base[1], "migrated stream diverged"
    assert src.requests[2].outputs == base[2], "stay-behind diverged"
    print("4. mid-decode migration shard 2 -> 4 bit-exact (sampled)")


def check_replica_groups():
    base, e1 = run(1)
    full_bytes = e1.params_bytes_per_device()
    spec = ClusterSpec.of(CFG, [HBM_CLASS, HBM_CLASS], serving=SCFG,
                          shard=2)
    assert len(spec.groups) == 1 and spec.groups[0].devices == 2
    assert spec.physical_devices == 2
    router = spec.build(PARAMS)
    assert len(router.devices) == 1          # one engine per group
    eng = router.devices[0].engine
    assert eng.shard == 2
    assert eng.params_bytes_per_device() <= 0.6 * full_bytes
    for r in requests():
        router.submit(r)
    s = router.run()
    assert s["finished"] == 3
    for rid, rs in router.finished.items():
        assert rs.outputs == base[rid], rid

    # the ISSUE's launcher example: a lone hbm + one 2-way cxl group
    spec = ClusterSpec.from_cli("hbm:1,cxl:2", model=CFG, serving=SCFG,
                                shard=2)
    assert [g.devices for g in spec.groups] == [1, 2]
    assert spec.cli() == "hbm:1,cxl:2"       # round-trip
    print(f"5. 2-way replica group: {eng.params_bytes_per_device()} "
          f"bytes/device vs {full_bytes} full copy; cluster streams "
          f"exact; hbm:1,cxl:2 --shard 2 forms [1, 2]-device groups")


if __name__ == "__main__":
    check_greedy_twins_and_invariants()
    check_sampled_twins()
    check_micro_twins()
    check_cross_shard_migration()
    check_replica_groups()
    print("ALL SHARDED ENGINE CHECKS PASSED")
