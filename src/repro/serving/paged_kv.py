"""Paged KV storage (paper §4.2.2: "PAM adopts PagedAttention, using a
block table to record the physical locations of KV tokens").

``BlockAllocator`` is host-side bookkeeping (free list, per-sequence block
tables). ``PagedKVPool`` owns the device arrays — one pool per memory tier;
the warm/cold tiers store paged, the hot tier stores dense kernel-ready
buffers (see ``pam_manager``). Gather/scatter between layouts goes through
``repro.core.pam_interface`` (the hardware re-layout unit of §6.2).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


class OutOfBlocks(RuntimeError):
    pass


class BlockAllocator:
    """Free-list block allocator with per-sequence tables."""

    def __init__(self, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(num_blocks - 1, -1, -1))
        self.tables: dict[int, list[int]] = {}

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def allocate(self, seq_id: int, n_tokens: int) -> list[int]:
        need = self.blocks_for(n_tokens) - len(self.tables.get(seq_id, []))
        if need > len(self._free):
            raise OutOfBlocks(
                f"need {need} blocks, {len(self._free)} free")
        tbl = self.tables.setdefault(seq_id, [])
        for _ in range(max(need, 0)):
            tbl.append(self._free.pop())
        return tbl

    def free(self, seq_id: int) -> None:
        for b in self.tables.pop(seq_id, []):
            self._free.append(b)

    def table(self, seq_id: int) -> list[int]:
        return self.tables.get(seq_id, [])

    def check_no_double_mapping(self) -> bool:
        used = [b for t in self.tables.values() for b in t]
        return len(used) == len(set(used)) and \
            not (set(used) & set(self._free))


@dataclasses.dataclass
class PagedKVPool:
    """Device-side paged KV storage for one tier: K and V pools shaped
    (L, nblocks, block, Hkv, dh) (or latent (L, nblocks, block, r))."""
    k: jax.Array
    v: jax.Array
    block_size: int

    @classmethod
    def create(cls, n_layers: int, num_blocks: int, block_size: int,
               n_kv: int, d_head: int, dtype=jnp.bfloat16) -> "PagedKVPool":
        shape = (n_layers, num_blocks, block_size, n_kv, d_head)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   block_size=block_size)

    def write_tokens(self, layer_k: jax.Array, layer_v: jax.Array,
                     block_ids: np.ndarray, slot_ids: np.ndarray
                     ) -> "PagedKVPool":
        """Scatter tokens into (block, slot) positions.

        layer_k/v: (L, T, Hkv, dh); block_ids/slot_ids: (T,).
        """
        bi = jnp.asarray(block_ids)
        si = jnp.asarray(slot_ids)
        return PagedKVPool(
            k=self.k.at[:, bi, si].set(jnp.moveaxis(layer_k, 1, 1)),
            v=self.v.at[:, bi, si].set(jnp.moveaxis(layer_v, 1, 1)),
            block_size=self.block_size)

    def gather_tokens(self, block_ids: np.ndarray, slot_ids: np.ndarray
                      ) -> tuple[jax.Array, jax.Array]:
        """Gather (L, T, Hkv, dh) for the given token positions."""
        bi = jnp.asarray(block_ids)
        si = jnp.asarray(slot_ids)
        return self.k[:, bi, si], self.v[:, bi, si]


def token_to_block_slot(positions: np.ndarray, table: list[int],
                        block_size: int) -> tuple[np.ndarray, np.ndarray]:
    """Map logical token positions -> (physical block id, slot) via table."""
    pos = np.asarray(positions)
    logical = pos // block_size
    phys = np.asarray(table, np.int32)[logical]
    return phys, pos % block_size
