"""Mamba-2 (SSD) block: fused projection, causal conv, selective scan.

Train/prefill uses the chunked SSD form (``repro.kernels.ssd_scan`` on TPU,
its jnp-equivalent math under jit elsewhere); decode keeps an O(1) recurrent
state per layer — conv ring buffer + (H, N, P) SSM state — which is why the
SSM/hybrid architectures are the ``long_500k``-eligible ones.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import SSMConfig
from repro.models.layers import init_linear, rms_norm


class SSMParams(NamedTuple):
    in_proj: jax.Array      # (d, 2*di + 2*G*N + H)
    conv_w: jax.Array       # (ck, conv_dim)   conv_dim = di + 2*G*N
    conv_b: jax.Array       # (conv_dim,)
    dt_bias: jax.Array      # (H,)
    a_log: jax.Array        # (H,)  A = -exp(a_log)
    d_skip: jax.Array       # (H,)
    out_norm: jax.Array     # (di,)
    out_proj: jax.Array     # (di, d)


def _dims(d: int, cfg: SSMConfig):
    di = cfg.d_inner(d)
    H = cfg.n_heads(d)
    conv_dim = di + 2 * cfg.n_groups * cfg.d_state
    return di, H, conv_dim


def init_ssm(key, d: int, cfg: SSMConfig, dtype) -> SSMParams:
    di, H, conv_dim = _dims(d, cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * cfg.n_groups * cfg.d_state + H
    return SSMParams(
        in_proj=init_linear(ks[0], d, proj_out, dtype),
        conv_w=(jax.random.normal(ks[1], (cfg.conv_kernel, conv_dim),
                                  jnp.float32) * 0.1).astype(dtype),
        conv_b=jnp.zeros((conv_dim,), dtype),
        dt_bias=jnp.zeros((H,), jnp.float32),
        a_log=jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        d_skip=jnp.ones((H,), jnp.float32),
        out_norm=jnp.ones((di,), dtype),
        out_proj=init_linear(ks[3], di, d, dtype),
    )


def _split_proj(z_xbc_dt: jax.Array, d: int, cfg: SSMConfig):
    di, H, conv_dim = _dims(d, cfg)
    gn = cfg.n_groups * cfg.d_state
    z = z_xbc_dt[..., :di]
    xbc = z_xbc_dt[..., di:di + conv_dim]
    dt = z_xbc_dt[..., di + conv_dim:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xbc: (B, L, C); w: (ck, C)."""
    ck = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (ck - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i][None, None, :]
              for i in range(ck))
    return jax.nn.silu(out + b[None, None, :])


def ssd_chunked_jnp(x, dt, a, b, c, d_skip, chunk: int,
                    return_final_state: bool = False):
    """Chunk-parallel SSD in pure jnp — same math as the Pallas kernel;
    used for the XLA (non-TPU / dry-run) path. Shapes as kernels.ssd_scan.
    With ``return_final_state`` also returns h_L (B, H, N, P) fp32 — the
    prefill path uses it to seed the decode cache."""
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    pad = (chunk - L % chunk) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk
    xf = x.reshape(B, nc, chunk, H, P).astype(jnp.float32)
    dtf = dt.reshape(B, nc, chunk, H).astype(jnp.float32)
    bh = jnp.repeat(b, rep, axis=2).reshape(B, nc, chunk, H, N).astype(jnp.float32)
    ch = jnp.repeat(c, rep, axis=2).reshape(B, nc, chunk, H, N).astype(jnp.float32)
    af = a.astype(jnp.float32)

    logdec = dtf * af                                   # (B, nc, Q, H)
    seg = jnp.cumsum(logdec, axis=2)                    # s_t within chunk

    # intra-chunk. Mask BEFORE exp: upper-triangle gaps are positive and
    # overflow, and 0*inf in the VJP poisons every gradient upstream.
    gap = seg[:, :, :, None, :] - seg[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    decay = jnp.exp(jnp.where(tri[None, None, :, :, None], gap, -jnp.inf))
    scores = jnp.einsum("bnqhs,bnuhs->bnquh", ch, bh)   # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bnquh,bnquh,bnuh,bnuhp->bnqhp",
                         scores, decay, dtf, xf)

    # inter-chunk: sequential state pass over chunks
    tail = jnp.exp(seg[:, :, -1:, :] - seg) * dtf       # (B,nc,Q,H)
    dstate = jnp.einsum("bnqh,bnqhs,bnqhp->bnhsp", tail, bh, xf)
    total_dec = jnp.exp(seg[:, :, -1, :])               # (B,nc,H)

    def step(h_in, inp):
        dec, dst = inp                                   # (B,H), (B,H,N,P)
        h_out = dec[..., None, None] * h_in + dst
        return h_out, h_in

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    h_final, h_ins = jax.lax.scan(
        step, h0, (jnp.moveaxis(total_dec, 1, 0), jnp.moveaxis(dstate, 1, 0)))
    h_ins = jnp.moveaxis(h_ins, 0, 1)                    # (B,nc,H,N,P) state at chunk start
    y_inter = jnp.einsum("bnqh,bnqhs,bnhsp->bnqhp",
                         jnp.exp(seg), ch, h_ins)

    y = (y_intra + y_inter).reshape(B, Lp, H, P) + \
        d_skip[None, None, :, None] * x.astype(jnp.float32)
    y = y[:, :L].astype(x.dtype)
    if return_final_state:
        return y, h_final
    return y


def ssm_forward(p: SSMParams, x: jax.Array, cfg: SSMConfig, *,
                rms_eps: float, use_kernel: bool = False) -> jax.Array:
    """Train/prefill pass. x: (B, L, d) -> (B, L, d)."""
    B, L, d = x.shape
    di, H, conv_dim = _dims(d, cfg)
    G, N, P = cfg.n_groups, cfg.d_state, cfg.head_dim

    zxbcdt = jnp.einsum("bld,de->ble", x, p.in_proj)
    z, xbc, dt_raw = _split_proj(zxbcdt, d, cfg)
    xbc = _causal_conv(xbc, p.conv_w, p.conv_b)
    xs = xbc[..., :di].reshape(B, L, H, P)
    bmat = xbc[..., di:di + G * N].reshape(B, L, G, N)
    cmat = xbc[..., di + G * N:].reshape(B, L, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias)
    a = -jnp.exp(p.a_log)

    if use_kernel:
        from repro.kernels import ops as kops
        y = kops.ssd(xs, dt, a, bmat, cmat, p.d_skip, chunk=cfg.chunk)
    else:
        y = ssd_chunked_jnp(xs, dt, a, bmat, cmat, p.d_skip, cfg.chunk)

    y = y.reshape(B, L, di) * jax.nn.silu(z)
    y = rms_norm(y, p.out_norm, rms_eps)
    return jnp.einsum("ble,ed->bld", y, p.out_proj)


def ssm_prefill(p: SSMParams, x: jax.Array, cfg: SSMConfig, *,
                rms_eps: float) -> tuple[jax.Array, "SSMCache"]:
    """Full-sequence pass that also returns the decode cache (conv tail +
    final SSM state) so serving can switch to recurrent decode."""
    B, L, d = x.shape
    di, H, conv_dim = _dims(d, cfg)
    G, N, P = cfg.n_groups, cfg.d_state, cfg.head_dim

    zxbcdt = jnp.einsum("bld,de->ble", x, p.in_proj)
    z, xbc_raw, dt_raw = _split_proj(zxbcdt, d, cfg)
    xbc = _causal_conv(xbc_raw, p.conv_w, p.conv_b)
    xs = xbc[..., :di].reshape(B, L, H, P)
    bmat = xbc[..., di:di + G * N].reshape(B, L, G, N)
    cmat = xbc[..., di + G * N:].reshape(B, L, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias)
    a = -jnp.exp(p.a_log)

    y, h_final = ssd_chunked_jnp(xs, dt, a, bmat, cmat, p.d_skip, cfg.chunk,
                                 return_final_state=True)
    y = y.reshape(B, L, di) * jax.nn.silu(z)
    y = rms_norm(y, p.out_norm, rms_eps)
    out = jnp.einsum("ble,ed->bld", y, p.out_proj)

    # conv ring buffer = last (ck-1) PRE-activation conv inputs
    ck = cfg.conv_kernel
    tail = jnp.pad(xbc_raw, ((0, 0), (max(ck - 1 - L, 0), 0), (0, 0)))
    cache = SSMCache(conv=tail[:, -(ck - 1):], state=h_final)
    return out, cache


class SSMCache(NamedTuple):
    conv: jax.Array    # (B, ck-1, conv_dim) last inputs
    state: jax.Array   # (B, H, N, P) fp32


def init_ssm_cache(batch: int, d: int, cfg: SSMConfig, dtype) -> SSMCache:
    di, H, conv_dim = _dims(d, cfg)
    return SSMCache(
        conv=jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
        state=jnp.zeros((batch, H, cfg.d_state, cfg.head_dim), jnp.float32),
    )


def ssm_decode(p: SSMParams, x: jax.Array, cache: SSMCache, cfg: SSMConfig,
               *, rms_eps: float) -> tuple[jax.Array, SSMCache]:
    """One-token recurrent step. x: (B, d) -> (B, d)."""
    B, d = x.shape
    di, H, conv_dim = _dims(d, cfg)
    G, N, P = cfg.n_groups, cfg.d_state, cfg.head_dim

    zxbcdt = jnp.einsum("bd,de->be", x, p.in_proj)
    z, xbc, dt_raw = _split_proj(zxbcdt, d, cfg)

    # conv ring buffer
    window = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # (B, ck, C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p.conv_w) + p.conv_b
    xbc_t = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xs = xbc_t[..., :di].reshape(B, H, P)
    bmat = xbc_t[..., di:di + G * N].reshape(B, G, N)
    cmat = xbc_t[..., di + G * N:].reshape(B, G, N)
    rep = H // G
    bh = jnp.repeat(bmat, rep, axis=1)                    # (B, H, N)
    ch = jnp.repeat(cmat, rep, axis=1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias)  # (B, H)
    a = -jnp.exp(p.a_log)

    decay = jnp.exp(dt * a)[..., None, None]              # (B, H, 1, 1)
    upd = (dt[..., None, None] * bh[..., :, None]
           * xs.astype(jnp.float32)[..., None, :])        # (B, H, N, P)
    state = decay * cache.state + upd
    y = jnp.einsum("bhn,bhnp->bhp", ch.astype(jnp.float32), state)
    y = y + p.d_skip[None, :, None] * xs.astype(jnp.float32)

    y = y.reshape(B, di).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(y, p.out_norm, rms_eps)
    out = jnp.einsum("be,ed->bd", y, p.out_proj)
    return out, SSMCache(conv=new_conv, state=state)
