"""Property tests: PAMattention's online-softmax algebra is EXACT.

The whole paper rests on Alg. 1 being numerically equivalent to monolithic
softmax attention for any partitioning of the KV set across tiers/banks —
these tests certify that with hypothesis-driven shapes/splits/scales.
"""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # hypothesis, or skip-stub fallback

from repro.core import online_softmax as osm

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    s=st.integers(2, 96),
    d=st.sampled_from([4, 8, 16, 32]),
    nsplit=st.integers(1, 5),
    logit_scale=st.floats(0.1, 30.0),
)
def test_partitioned_equals_monolithic(seed, s, d, nsplit, logit_scale):
    """Any contiguous partitioning merges to the exact softmax attention."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    q = _rand(k1, d, scale=logit_scale)
    k = _rand(k2, s, d)
    v = _rand(k3, s, d)

    ref = osm.reference_attention(q, k, v)

    # random split points
    rng = np.random.default_rng(seed)
    cuts = sorted(rng.choice(np.arange(1, s), size=min(nsplit, s - 1),
                             replace=False).tolist())
    bounds = [0] + cuts + [s]
    ks = [k[a:b] for a, b in zip(bounds[:-1], bounds[1:])]
    vs = [v[a:b] for a, b in zip(bounds[:-1], bounds[1:])]

    out = osm.attention_from_partitions(q, ks, vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), t=st.integers(1, 9),
       s=st.integers(1, 16), d=st.sampled_from([4, 8]))
def test_tree_merge_equals_flat_merge(seed, t, s, d):
    """Hierarchical RU reduction == single-pass reduction (any topology)."""
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, 3 * t)
    parts = []
    for i in range(t):
        q = _rand(keys[3 * i], d)
        k = _rand(keys[3 * i + 1], s, d)
        v = _rand(keys[3 * i + 2], s, d)
        parts.append(osm.local_attention(q, k, v))
    stacked = osm.AttnPartial(o=jnp.stack([p.o for p in parts]),
                              m=jnp.stack([p.m for p in parts]),
                              l=jnp.stack([p.l for p in parts]))
    flat = osm.merge_many(stacked)
    tree = osm.tree_merge(stacked)
    np.testing.assert_allclose(np.asarray(osm.finalize(flat)),
                               np.asarray(osm.finalize(tree)),
                               rtol=1e-6, atol=1e-6)


def test_merge_is_commutative_and_associative():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 9)
    d = 8
    parts = [osm.local_attention(_rand(ks[3 * i], d), _rand(ks[3 * i + 1], 7, d),
                                 _rand(ks[3 * i + 2], 7, d)) for i in range(3)]
    a, b, c = parts
    ab_c = osm.merge_partials(osm.merge_partials(a, b), c)
    a_bc = osm.merge_partials(a, osm.merge_partials(b, c))
    ba_c = osm.merge_partials(osm.merge_partials(b, a), c)
    for x in (a_bc, ba_c):
        np.testing.assert_allclose(np.asarray(osm.finalize(ab_c)),
                                   np.asarray(osm.finalize(x)),
                                   rtol=1e-6, atol=1e-6)


def test_empty_partition_is_identity():
    key = jax.random.PRNGKey(1)
    d = 16
    q = _rand(key, d)
    k = _rand(jax.random.fold_in(key, 1), 9, d)
    v = _rand(jax.random.fold_in(key, 2), 9, d)
    part = osm.local_attention(q, k, v)
    ident = osm.empty_partial(d)
    merged = osm.merge_partials(part, ident)
    np.testing.assert_allclose(np.asarray(osm.finalize(merged)),
                               np.asarray(osm.finalize(part)),
                               rtol=1e-7, atol=1e-7)
    # and the other side
    merged2 = osm.merge_partials(ident, part)
    np.testing.assert_allclose(np.asarray(osm.finalize(merged2)),
                               np.asarray(osm.finalize(part)),
                               rtol=1e-7, atol=1e-7)


def test_masked_partition_matches_subset():
    """A fully-masked tier contributes nothing; a partial mask equals
    attention over the unmasked subset only."""
    key = jax.random.PRNGKey(7)
    d, s = 8, 24
    q = _rand(key, d)
    k = _rand(jax.random.fold_in(key, 1), s, d)
    v = _rand(jax.random.fold_in(key, 2), s, d)
    mask = jnp.arange(s) % 3 == 0
    part = osm.local_attention(q, k, v, mask=mask)
    ref = osm.reference_attention(q, k[mask], v[mask])
    np.testing.assert_allclose(np.asarray(osm.finalize(part)),
                               np.asarray(ref), rtol=2e-5, atol=2e-5)
    # fully masked -> identity under merge
    dead = osm.local_attention(q, k, v, mask=jnp.zeros(s, bool))
    merged = osm.merge_partials(part, dead)
    np.testing.assert_allclose(np.asarray(osm.finalize(merged)),
                               np.asarray(ref), rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_batched_heads_shapes(seed):
    """Algebra broadcasts over (B, H) leading dims."""
    key = jax.random.PRNGKey(seed)
    B, H, S, d = 2, 4, 33, 16
    q = _rand(key, B, H, d)
    k = _rand(jax.random.fold_in(key, 1), B, H, S, d)
    v = _rand(jax.random.fold_in(key, 2), B, H, S, d)
    ref = osm.reference_attention(q, k, v)
    out = osm.attention_from_partitions(
        q, [k[..., :10, :], k[..., 10:, :]], [v[..., :10, :], v[..., 10:, :]])
    assert out.shape == (B, H, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
