"""Graceful ``hypothesis`` fallback for the property-based tests.

``pip install -r requirements-dev.txt`` gets the real thing. When
hypothesis is missing (minimal CI images), importing it here degrades each
``@given`` test into a cleanly-skipped stub instead of a collection error,
so the rest of the module's tests still run — a finer-grained version of
``pytest.importorskip`` (which would skip whole modules, including their
non-property tests).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                           # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def stub():
                pass
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return pytest.mark.skip(
                reason="hypothesis not installed "
                       "(pip install -r requirements-dev.txt)")(stub)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Accepts any strategy construction; values are never drawn."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()


# ------------------------------------------- PR 7 interleaving corpus
def seed_corpus(n=200, base=0):
    """Deterministic seed list for randomized drivers (e.g. the
    prefix-sharing interleaving suite): the driver function takes one
    integer seed, pytest parametrizes it over this corpus so the suite
    runs everywhere, and — when hypothesis is installed —
    ``@given(interleaving_seed)`` explores (and shrinks) arbitrary seeds
    through the SAME driver."""
    return list(range(base, base + n))


# Strategy for the hypothesis-side exploration of the same drivers; a
# stub (never drawn) when hypothesis is absent and @given degrades to a
# skipped test.
interleaving_seed = st.integers(min_value=0, max_value=2**32 - 1)
