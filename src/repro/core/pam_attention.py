"""PAMattention (paper §5, Algorithm 1) — single-host orchestration.

Ties together the pieces:
  1. (optional) retrieval sparsity picks the tokens that participate,
  2. tokens are partitioned by tier residency (HBM / DDR / SSD),
  3. each partition runs Local_Attention -> (O_t, m_t, l_t),
  4. hierarchical Reduction merges partials exactly,
  5. importance scores are updated (eq. 7) from the step's attention mass.

The distributed (shard_map) form lives in ``repro.distributed.pam_shard``;
the Pallas kernel form of step 3 in ``repro.kernels.flash_decode``. All
three are interchangeable and agree numerically (tested).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import importance as imp_mod
from repro.core import online_softmax as osm
from repro.core.tiers import COLD, HOT, WARM


@dataclasses.dataclass(frozen=True)
class PAMAttentionConfig:
    num_tiers: int = 3
    use_sparsity: bool = True
    compression: int = 8          # keep S/compression tokens per step
    lam: float = imp_mod.DEFAULT_LAMBDA


class PAMAttentionOutput(NamedTuple):
    out: jax.Array           # (H, d) attention output
    step_scores: jax.Array   # (S,) per-token attention mass S_i(j)
    new_importance: jax.Array


def pam_attention_step(q: jax.Array, k: jax.Array, v: jax.Array,
                       tier_of_token: jax.Array, valid: jax.Array,
                       importance: jax.Array,
                       cfg: PAMAttentionConfig = PAMAttentionConfig(),
                       scale: float | None = None) -> PAMAttentionOutput:
    """One decode-step attention for one sequence.

    q: (H, d) current query; k, v: (S, H_kv, d) full cached KV (GQA allowed:
    H must be a multiple of H_kv); tier_of_token/valid/importance: (S,).

    Partitions by tier, computes local partials per tier, merges exactly.
    With ``use_sparsity``, only the top-(S_valid/compression) tokens by
    current importance participate (retrieval sparsity; importance carries
    the context-locality signal).
    """
    S, H_kv, d = k.shape
    H = q.shape[0]
    rep = H // H_kv

    participate = valid
    if cfg.use_sparsity:
        n_valid = jnp.sum(valid)
        k_keep = jnp.maximum(n_valid // cfg.compression, 1)
        # static top-k size: S // compression rounded up, clamped by mask
        k_static = max(S // cfg.compression, 1)
        scores = jnp.where(valid, importance, -jnp.inf)
        _, idx = jax.lax.top_k(scores, k_static)
        sel = jnp.zeros((S,), bool).at[idx].set(True) & valid
        # honor dynamic budget: drop selected tokens ranked past k_keep
        ranks = jnp.argsort(jnp.argsort(-scores))
        sel = sel & (ranks < k_keep)
        participate = sel

    # Grouped GQA scores, computed ONCE: query heads that share a kv head
    # are contracted against it directly — (H_kv, rep, S), no jnp.repeat
    # KV expansion, no duplicated QK^T across tiers or the importance mass
    # (mirrors kernels/flash_decode's query-head grouping).
    sc = scale if scale is not None else 1.0 / jnp.sqrt(jnp.float32(d))
    qg = q.reshape(H_kv, rep, d)
    s_all = jnp.einsum("grd,sgd->grs", qg.astype(jnp.float32),
                       k.astype(jnp.float32)) * sc       # (H_kv, rep, S)

    # Per-tier local attention (Alg. 1 lines 3-4) — masks select residency;
    # each tier's partial reuses the shared score matrix.
    partials = []
    for tier in (HOT, WARM, COLD)[: cfg.num_tiers]:
        mask = participate & (tier_of_token == tier)      # (S,)
        s = jnp.where(mask[None, None, :], s_all, -jnp.inf)
        m = jnp.max(s, axis=-1)                           # (H_kv, rep)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("grs,sgd->grd", p, v.astype(jnp.float32))
        partials.append(osm.AttnPartial(o=o, m=m, l=l))

    stacked = osm.AttnPartial(
        o=jnp.stack([p.o for p in partials]),
        m=jnp.stack([p.m for p in partials]),
        l=jnp.stack([p.l for p in partials]),
    )
    merged = osm.tree_merge(stacked)                      # hierarchical RU
    out = osm.finalize(merged, out_dtype=q.dtype).reshape(H, d)

    # Step scores for eq. (7): exact attention mass per token this step,
    # reconstructed from the shared scores and the merged (m, l) stats.
    step_scores = _attention_mass(s_all, participate, merged)
    new_imp = imp_mod.update_importance(importance, step_scores, lam=cfg.lam)
    return PAMAttentionOutput(out=out, step_scores=step_scores,
                              new_importance=new_imp)


def _attention_mass(s_all, participate, merged: osm.AttnPartial):
    """Per-token softmax mass (head-mean, count-scaled) for importance.

    s_all: (H_kv, rep, S) precomputed grouped scores; merged m/l:
    (H_kv, rep) global softmax statistics from the tier merge."""
    H_kv, rep, S = s_all.shape
    s = jnp.where(participate[None, None, :], s_all, -jnp.inf)
    m_safe = jnp.where(jnp.isfinite(merged.m), merged.m, 0.0)
    p = jnp.exp(s - m_safe[..., None]) / jnp.maximum(merged.l,
                                                     1e-30)[..., None]
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    return imp_mod.step_score_from_attn_weights(p.reshape(H_kv * rep, S),
                                                head_axis=0)
