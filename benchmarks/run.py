"""Benchmark harness entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--section figs|kernels|engine|roofline]

``--out BENCH.json`` additionally records the machine-readable bench
trajectory point for the PR: real decode tokens/s of the serving fast path
and device dispatches per decode step (the fused-dispatch invariant).
"""

from __future__ import annotations

import argparse
import json


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=["all", "figs", "kernels", "engine",
                             "roofline", "cluster", "chaos", "prefix",
                             "serving", "obs", "shard"])
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out", default=None, metavar="BENCH.json",
                    help="write decode tokens/s + dispatch counts (and all "
                         "section rows) as JSON — the bench trajectory")
    args = ap.parse_args(argv)
    if args.out:              # fail fast, not after minutes of benching
        open(args.out, "a").close()

    rows: list[tuple] = []
    wallclock = None
    hot_scaling = None
    if args.section in ("all", "figs"):
        from benchmarks import paper_figs
        rows += paper_figs.fig9_online_slo()
        rows += paper_figs.fig10_offline()
        rows += paper_figs.fig11_energy()
        rows += paper_figs.fig12_ablation()
        rows += paper_figs.fig13_scalability()
        rows += paper_figs.headline_claims()
    if args.section in ("all", "kernels"):
        from benchmarks.kernel_bench import bench_kernels
        rows += bench_kernels()
    if args.section in ("all", "engine"):
        from benchmarks import engine_bench
        rows += engine_bench.bench_engine()
        wallclock = engine_bench.bench_decode_wallclock()
        rows += engine_bench.wallclock_rows(wallclock)
        hot_scaling = engine_bench.bench_hot_window_scaling()
        rows += engine_bench.hot_window_rows(hot_scaling)
    if args.section in ("all", "roofline"):
        from benchmarks.roofline import roofline_rows
        rows += roofline_rows(args.dryrun_dir)
    cluster = None
    if args.section in ("all", "cluster"):
        from benchmarks.cluster_bench import cluster_rows
        cluster, crows = cluster_rows()
        rows += crows
    chaos = None
    if args.section in ("all", "chaos"):
        from benchmarks.chaos_bench import chaos_rows
        chaos, xrows = chaos_rows()
        rows += xrows
    prefix = None
    if args.section in ("all", "prefix"):
        from benchmarks.prefix_bench import prefix_rows
        prefix, prows = prefix_rows()
        rows += prows
    serving = None
    if args.section in ("all", "serving"):
        from benchmarks.serving_bench import serving_rows
        serving, srows = serving_rows()
        rows += srows
    obs = None
    if args.section in ("all", "obs"):
        from benchmarks.obs_bench import obs_rows
        obs, orows = obs_rows()
        rows += orows
    shard = None
    if args.section in ("all", "shard"):
        from benchmarks.shard_bench import shard_rows
        shard, shrows = shard_rows()
        rows += shrows

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")

    if args.out:
        payload = {
            "rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in rows],
            "suite": {"section": args.section, "n_rows": len(rows)},
        }
        if cluster is not None:
            # heterogeneous-cluster trajectory point (paper §4.3):
            # 1 device vs 3-device cluster under the same bursty trace
            payload["cluster"] = cluster
            payload["cluster_tok_s"] = cluster["cluster_tok_s"]
            payload["cluster_best_single_tok_s"] = \
                cluster["best_single_tok_s"]
            payload["cluster_speedup_vs_best_single"] = \
                cluster["cluster_speedup_vs_best_single"]
            payload["cluster_migrations"] = cluster["migrations"]
        if prefix is not None:
            # prefix-sharing trajectory point (PR 7): prefill FLOPs
            # saved and pool occupancy vs prompt share ratio, token
            # streams pinned exact against the cache-off twin
            payload["prefix"] = prefix
            payload["prefix_tokens_lost"] = prefix["tokens_lost_total"]
            payload["prefix_flops_saved_at_half"] = \
                prefix["flops_saved_at_half"]
            payload["prefix_occupancy_drop"] = \
                prefix["occupancy_drop_lo_to_hi"]
        if serving is not None:
            # serving-under-load trajectory point (PR 8): TTFT/TPOT
            # tails + SLO attainment over seeded arrival traces, zero
            # lost/dup streamed tokens, chunked prefill cutting the
            # p99 TPOT tail at equal offered load
            payload["serving"] = serving
            payload["serving_slo_attainment"] = \
                serving["smoke_slo_attainment"]
            payload["serving_p99_ttft_s"] = serving["p99_ttft_s_worst"]
            payload["serving_tokens_lost"] = serving["tokens_lost_total"]
            payload["serving_chunked_p99_tpot_ratio"] = \
                serving["chunked_prefill"]["p99_tpot_ratio"]
        if obs is not None:
            # telemetry-overhead trajectory point (PR 9): decode tok/s
            # with collectors on vs off, streams pinned identical
            payload["obs"] = obs
            payload["obs_overhead_ratio"] = obs["overhead_ratio"]
            payload["obs_decode_tok_s_enabled"] = \
                obs["enabled"]["decode_tok_s"]
            payload["obs_decode_tok_s_disabled"] = \
                obs["disabled"]["decode_tok_s"]
        if shard is not None:
            # sharded-engine trajectory point (PR 10): twin-exact
            # streams at shard 1/2/4, one dispatch/step under
            # shard_map, ~1/N param bytes per device, and the Alg. 1
            # (O, m, l) merge's collective bytes flat in context
            payload["shard"] = shard
            payload["shard_tokens_lost"] = shard["tokens_lost_total"]
            payload["shard_dispatches_per_step"] = \
                shard["dispatches_per_step_max"]
            payload["shard_merge_bytes_flat"] = \
                shard["merge_bytes_flat"]
            payload["shard_param_bytes_ratio_2way"] = (
                shard["points"]["2"]["param_bytes_per_device"]
                / shard["points"]["1"]["param_bytes_per_device"])
        if chaos is not None:
            # fault-tolerance trajectory point (PR 6): goodput under an
            # injected device kill, token-exact vs the failure-free twin
            payload["chaos"] = chaos
            payload["chaos_tokens_lost"] = chaos["tokens_lost_total"]
            payload["chaos_kill_goodput_ratio"] = \
                chaos["kill_goodput_ratio"]
            payload["chaos_kill_recovery_latency_mean_s"] = \
                chaos["kill_recovery_latency_mean_s"]
        if wallclock is not None:
            payload["decode_wallclock"] = wallclock
            payload["decode_tok_s"] = wallclock["micro"]["decode_tok_s"]
            payload["dispatches_per_step"] = \
                wallclock["fused"]["dispatches_per_step"]
            paged = wallclock.get("paged")
            if paged is not None:
                # paged warm/cold gather: sparse-read + occupancy point
                payload["paged_blocks_touched_per_step"] = \
                    paged["blocks_touched_per_step"]
                payload["paged_blocks_window_per_step"] = \
                    paged["blocks_window_per_step"]
                payload["paged_page_read_fraction"] = \
                    paged["page_read_fraction"]
                payload["paged_pool_occupancy_peak"] = \
                    paged["pool_occupancy_peak"]
                payload["paged_decode_tok_s"] = paged["decode_tok_s"]
            ring = wallclock.get("ring")
            if ring is not None:
                # hot-window ring trajectory point (PR 5)
                payload["ring_decode_tok_s"] = ring["decode_tok_s"]
                payload["ring_hot_window"] = ring["hot_window"]
                payload["ring_hot_bytes_per_slot"] = \
                    ring["hot_bytes_per_slot"]
        if hot_scaling is not None:
            payload["hot_window_scaling"] = hot_scaling
            payload["hot_bytes_per_slot"] = \
                hot_scaling["hot_bytes_per_slot"]
            payload["hot_bytes_constant_across_smax"] = \
                hot_scaling["hot_bytes_constant_across_smax"]
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
