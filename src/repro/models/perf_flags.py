"""Trace-time performance switches for §Perf hillclimbing.

Each flag is a beyond-paper optimization toggled per dry-run variant so
before/after lowered artifacts can be compared cell-by-cell:

  sp_pin      pin sequence-parallel sharding on intra-block activations
              (attention/MLP inputs + outputs) — shrinks TP psum traffic
              from full activations to S-sharded activations
  bf16_probs  cast softmax probabilities to bf16 for the PV matmul —
              halves the dominant score-materialization bytes
  remat_dots  remat policy saves matmul outputs (no matmul recompute in
              the backward re-forward)
  pam_shard_decode  decode attention + cache update fused in one shard_map
              over the sequence axis (PAMattention distributed form) —
              removes the gather the GSPMD cache-scatter inserts
"""

from __future__ import annotations

import os

_FLAGS: set[str] = set()


def set_flags(*names: str) -> None:
    _FLAGS.clear()
    _FLAGS.update(names)


def from_env() -> None:
    set_flags(*[f for f in os.environ.get("REPRO_PERF", "").split(",") if f])


def enabled(name: str) -> bool:
    return name in _FLAGS


def active() -> tuple[str, ...]:
    return tuple(sorted(_FLAGS))

def abstract_mesh():
    """Ambient mesh across jax versions (see ``repro.compat``)."""
    from repro import compat
    return compat.abstract_mesh()
