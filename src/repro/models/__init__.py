"""Model substrate: the assigned architectures as pure-JAX functional models."""
