"""Declarative engine construction (PR 10): ``EngineSpec``.

The spec is the ONE way to describe an engine — model, serving policy,
and shard layout — separated from the runtime inputs (params, latency
model) that ``build()`` takes. Frozen and hashable, so specs can key
caches and travel through cluster/CLI layers by value.

``shard > 1`` builds the engine across that many local XLA devices on a
1-D ``("model",)`` mesh: params are tensor-sharded (GSPMD,
``distributed.sharding.param_shardings``), the hot ring splits its slot
axis and the paged pool its block axis across the mesh, and the fused
decode step merges per-shard attention partials with the exact Alg. 1
``pmax``/``psum`` reduction (``distributed.pam_shard``). Token streams
are bit-identical to the unsharded engine; see
docs/ARCHITECTURE.md#shard-layout.

The legacy ``ServingEngine(cfg, params, scfg, ...)`` constructor
survives as a deprecation shim that builds an ``EngineSpec``
internally.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.models.config import ModelConfig
from repro.serving.engine import ServingConfig, ServingEngine


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """What an engine IS: model + serving policy + shard layout + name.

    ``build(params, latency_model=...)`` turns the spec into a running
    ``ServingEngine``; everything else about the engine derives from
    these four fields. ``validate()`` raises actionable ``ValueError``s
    for spec-level inconsistencies (shard divisibility); device
    availability is only checked at build time, so specs can be
    constructed and round-tripped on any host.
    """

    model: ModelConfig
    serving: ServingConfig = ServingConfig()
    shard: int = 1
    name: str = "dev0"

    def validate(self) -> "EngineSpec":
        s, scfg = self.shard, self.serving
        if s < 1:
            raise ValueError(f"EngineSpec.shard must be >= 1, got {s}")
        if s == 1:
            return self
        if scfg.pam is None or not scfg.block_size:
            raise ValueError(
                f"shard={s} requires the PAM paged path (pam config + "
                f"block_size > 0): the sharded decode step splits the "
                f"hot ring and the paged pool across the mesh")
        window = scfg.hot_window or scfg.max_len
        if window % s:
            raise ValueError(
                f"shard={s}: hot ring of {window} slots does not split "
                f"evenly — pick hot_window (or max_len) divisible by "
                f"{s}, e.g. hot_window={-(-window // s) * s}")
        nb = self.total_pool_blocks()
        if nb % s:
            raise ValueError(
                f"shard={s}: pool of {nb} physical blocks (pool_blocks "
                f"+ 1 sentinel) does not split evenly — pass "
                f"pool_blocks={-(-nb // s) * s - 1} instead of "
                f"{nb - 1}")
        return self

    def total_pool_blocks(self) -> int:
        """Physical pool blocks including the sentinel trash block —
        the size of the pool's (sharded) block axis. 0 when dense."""
        scfg = self.serving
        if not scfg.block_size:
            return 0
        per_seq = scfg.max_len // max(scfg.block_size, 1)
        nb = (scfg.pool_blocks if scfg.pool_blocks is not None
              else scfg.max_batch * per_seq)
        return nb + 1

    def build(self, params: Any, *,
              latency_model: Optional[Callable[[dict], float]] = None
              ) -> ServingEngine:
        """Materialize the engine (the canonical constructor path)."""
        return ServingEngine(self, params, latency_model=latency_model)
