"""Async streaming server core (PR 8).

Turns the batch-oriented ``ClusterRouter``/``ServingEngine`` into a
long-lived serving loop with a per-request streaming token API:

- ``AsyncServer.submit`` registers a request and returns a
  ``StreamHandle`` — an async iterator over that request's
  ``TokenEvent``s, closed by its final (or rejection) event;
- the pump (``step`` / ``drain`` / the endpoint's background task)
  ticks the router, drains the shared event stream and fans each event
  out to its request's asyncio queue, recording a ``StreamRecord`` for
  scoring (``repro.frontend.loadgen.score``);
- an optional line-delimited-JSON TCP endpoint (``serve_endpoint``)
  exposes the same loop on a socket: one request object in, one JSON
  line per streamed token out.

A bare ``ServingEngine`` is wrapped as a single-device router
(``single_device_router``) so arrival gating, event diffing and the
SLO-admission hooks (shed / force-preempt) are uniform across the
single-device and cluster paths.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
from typing import Optional, Union

import numpy as np

from repro.cluster.router import ClusterRouter, RouterConfig
from repro.obs import metrics as obs_metrics
from repro.serving.engine import Request, ServingEngine
from repro.serving.events import ServeEvent

TokenEvent = ServeEvent    # the one event type every surface speaks


@dataclasses.dataclass
class StreamRecord:
    """Everything scoring needs about one request's stream."""

    rid: int
    arrival: float
    prompt_len: int
    max_new: int
    tokens: list[int] = dataclasses.field(default_factory=list)
    times: list[float] = dataclasses.field(default_factory=list)
    indices: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    rejected: bool = False


class StreamHandle:
    """Async iterator over one request's ``TokenEvent``s. The pump
    pushes events; a ``None`` sentinel (sent with the final event)
    ends iteration."""

    def __init__(self, record: StreamRecord):
        self.record = record
        self._q: asyncio.Queue = asyncio.Queue()

    def _push(self, ev: TokenEvent) -> None:
        self._q.put_nowait(ev)
        if ev.done:
            self._q.put_nowait(None)

    def __aiter__(self) -> "StreamHandle":
        return self

    async def __anext__(self) -> TokenEvent:
        ev = await self._q.get()
        if ev is None:
            raise StopAsyncIteration
        return ev


def single_device_router(engine: ServingEngine, *,
                         name: Optional[str] = None,
                         rcfg: RouterConfig = RouterConfig(),
                         preemptible: bool = False) -> ClusterRouter:
    """Compatibility alias for ``ClusterRouter.for_engine`` (PR 10) —
    the wrapping logic lives there now, next to the router it builds."""
    return ClusterRouter.for_engine(engine, name=name, rcfg=rcfg,
                                    preemptible=preemptible)


class AsyncServer:
    """Continuous-batching front end over a router (or bare engine).

    The router is single-threaded and simulation-clocked, so the server
    pumps it cooperatively: ``step()`` runs admission control, one
    router tick, and the event fan-out; ``drain()`` pumps until every
    submitted stream has closed, yielding to the event loop every
    ``ticks_per_yield`` ticks so concurrent consumers (stream
    iterators, socket writers) interleave."""

    def __init__(self, backend: Union[ClusterRouter, ServingEngine], *,
                 admission=None, ticks_per_yield: int = 8):
        if isinstance(backend, ServingEngine):
            backend = backend.as_router(
                preemptible=admission is not None)
        else:
            backend = backend.as_router()
        self.router = backend
        self.admission = admission
        self.ticks_per_yield = max(int(ticks_per_yield), 1)
        self.records: dict[int, StreamRecord] = {}
        self._handles: dict[int, StreamHandle] = {}
        self._next_rid = 0
        self._last_arrival = 0.0
        self._bind_obs()

    def _bind_obs(self) -> None:
        """Bind front-end instruments against the installed registry
        (once, at construction — the hot path only mutates)."""
        reg = obs_metrics.get_registry()
        self._mreg = reg
        self._m_submitted = reg.counter(
            "pam_frontend_requests_total",
            "requests accepted by the front end")
        self._m_finished = reg.counter(
            "pam_frontend_finished_total",
            "streams closed by a final (non-rejection) event")
        self._m_rejected = reg.counter(
            "pam_frontend_rejected_total",
            "streams closed by a rejection event")
        self._m_tokens = reg.counter(
            "pam_frontend_streamed_tokens_total",
            "token events fanned out to stream handles")
        self._m_queue = reg.gauge(
            "pam_frontend_queue_depth",
            "router shared-queue depth after the last pump tick")
        self._m_ttft = reg.histogram(
            "pam_frontend_ttft_seconds",
            "time to first streamed token (sim seconds)")
        self._m_itl = reg.histogram(
            "pam_frontend_itl_seconds",
            "inter-token gap, pooled across streams (sim seconds)")
        self._m_tpot = reg.histogram(
            "pam_frontend_tpot_seconds",
            "per-stream mean decode-token gap (sim seconds)")

    # ------------------------------------------------------------ intake
    def submit(self, prompt, max_new_tokens: int, *,
               rid: Optional[int] = None,
               arrival: Optional[float] = None) -> StreamHandle:
        """Register one request and return its stream. ``arrival``
        defaults to the cluster's current frontier; explicit arrivals
        are clamped nondecreasing (the router's stream contract)."""
        prompt = np.asarray(prompt, dtype=np.int32)
        if rid is None:
            rid = self._next_rid
        if rid in self.records:
            raise ValueError(f"duplicate request id {rid}")
        self._next_rid = max(self._next_rid, rid + 1)
        if arrival is None:
            arrival = self.router.now()
        arrival = max(float(arrival), self._last_arrival)
        self._last_arrival = arrival
        rec = StreamRecord(rid=rid, arrival=arrival,
                           prompt_len=int(prompt.shape[0]),
                           max_new=int(max_new_tokens))
        handle = StreamHandle(rec)
        self.records[rid] = rec
        self._handles[rid] = handle
        self._m_submitted.inc()
        self.router.submit(Request(id=rid, prompt=prompt,
                                   max_new_tokens=int(max_new_tokens),
                                   arrival=arrival))
        self._fanout()       # an unserviceable submit rejects synchronously
        return handle

    # -------------------------------------------------------------- pump
    def _fanout(self) -> None:
        for ev in self.router.drain_events():
            rec = self.records.get(ev.request_id)
            if rec is None:      # submitted around the server (tests)
                continue
            if ev.rejected:
                rec.rejected = True
                self._m_rejected.inc()
            else:
                if self._mreg.enabled:
                    self._m_tokens.inc()
                    if not rec.times:   # first token: TTFT vs arrival
                        self._m_ttft.observe(
                            max(ev.time - rec.arrival, 0.0))
                    else:               # later tokens: pooled ITL gap
                        self._m_itl.observe(
                            max(ev.time - rec.times[-1], 0.0))
                rec.tokens.append(ev.token)
                rec.times.append(ev.time)
                rec.indices.append(ev.index)
            if ev.done:
                rec.done = True
                if not ev.rejected:
                    self._m_finished.inc()
                    if self._mreg.enabled and len(rec.times) > 1:
                        gaps = np.maximum(np.diff(rec.times), 0.0)
                        self._m_tpot.observe(float(np.mean(gaps)))
            handle = self._handles.get(ev.request_id)
            if handle is not None:
                handle._push(ev)
                if ev.done:
                    del self._handles[ev.request_id]

    def step(self) -> bool:
        """One pump iteration; False once the backend is drained and
        every stream has closed."""
        if self.admission is not None:
            self.admission.control(self.router)
        live = self.router.tick()
        self._fanout()
        if self._mreg.enabled:
            self._m_queue.set(len(self.router.queue))
        return live or bool(self._handles)

    async def drain(self, max_ticks: Optional[int] = None) -> int:
        """Pump until all submitted streams finish; returns ticks."""
        limit = (max_ticks if max_ticks is not None
                 else self.router.rcfg.max_ticks)
        n = 0
        while self.step():
            n += 1
            if n >= limit:
                raise RuntimeError(f"server did not drain in {limit} ticks")
            if n % self.ticks_per_yield == 0:
                await asyncio.sleep(0)
        return n

    async def serve_trace(self, requests: list[Request],
                          max_ticks: Optional[int] = None
                          ) -> dict[int, StreamRecord]:
        """Benchmark entry: submit a whole time-ordered trace (the
        router's idle-jump advances sim time through arrival gaps),
        pump to completion, return the per-request records."""
        for req in requests:
            self.submit(req.prompt, req.max_new_tokens, rid=req.id,
                        arrival=req.arrival)
        await self.drain(max_ticks)
        return self.records

    # ---------------------------------------------------------- endpoint
    async def serve_endpoint(self, host: str = "127.0.0.1",
                             port: int = 0):
        """Line-delimited-JSON TCP endpoint. Each connection sends one
        request object — ``{"prompt": [int, ...], "max_new_tokens": n,
        "id": optional}`` — and receives one JSON line per
        ``TokenEvent`` (``{"rid", "token", "index", "time", "done",
        "rejected"}``). A ``{"op": "metrics"}`` line instead returns
        one JSON line with the live registry snapshot. Returns
        ``(server, port, pump_task)``; the caller owns shutdown
        (cancel the task, close the server)."""
        server = await asyncio.start_server(self._handle_conn, host, port)
        bound = server.sockets[0].getsockname()[1]
        pump = asyncio.create_task(self._endpoint_pump())
        return server, bound, pump

    async def _endpoint_pump(self) -> None:
        while True:
            self.step()
            await asyncio.sleep(0)

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            line = await reader.readline()
            if not line:
                return
            msg = json.loads(line)
            if msg.get("op") == "metrics":
                reg = obs_metrics.get_registry()
                writer.write(json.dumps({
                    "op": "metrics", "enabled": reg.enabled,
                    "metrics": reg.snapshot(),
                }).encode() + b"\n")
                await writer.drain()
                return
            handle = self.submit(np.asarray(msg["prompt"], np.int32),
                                 int(msg["max_new_tokens"]),
                                 rid=msg.get("id"))
            async for ev in handle:
                writer.write(json.dumps({
                    "rid": ev.request_id, "token": ev.token,
                    "index": ev.index, "time": ev.time,
                    "done": ev.done, "rejected": ev.rejected,
                }).encode() + b"\n")
                await writer.drain()
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    # ------------------------------------------------------------ metrics
    def summary(self) -> dict:
        """Front-end scorecard on the canonical key set (see
        docs/ARCHITECTURE.md): ``finished``/``rejected`` count closed
        streams, ``streamed_tokens`` the fanned-out token events."""
        recs = self.records.values()
        out = {"requests": len(self.records),
               "finished": sum(r.done and not r.rejected for r in recs),
               "rejected": sum(r.rejected for r in recs),
               "streamed_tokens": sum(len(r.tokens) for r in recs),
               "backend": self.router.summary()}
        if self.admission is not None:
            out["admission"] = self.admission.summary()
        return out
