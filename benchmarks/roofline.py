"""Roofline analysis over the dry-run artifacts (deliverable g).

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. The dry-run records are per-device (SPMD module).

Loop-trip correction: XLA-CPU ``cost_analysis`` counts while-loop bodies
ONCE (verified empirically: identical flops for n_layers=7/14/28), so the
raw numbers undercount scanned layers. A calibration pass
(``dryrun --calibrate``) lowers UNROLLED 2- and 4-layer variants per cell
and solves  body=(v4-v2)/2, outside=v2-2*body;  the corrected per-device
cost is  outside + n_layers*body  for flops, bytes, and collective bytes.

Caveat recorded in EXPERIMENTS.md: "bytes accessed" is XLA's post-fusion
operand+output sum — an upper bound on HBM traffic (a TPU-fused attention
kernel avoids the score materialization entirely; that delta is what §Perf
iterates on).

  compute term    = corrected_FLOPs_per_device / 197e12
  memory term     = corrected_bytes_per_device / 819e9
  collective term = corrected_collective_bytes_per_device / 50e9
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_CAP = 16e9          # v5e per chip


def load_records(dryrun_dir: str = "experiments/dryrun",
                 variant: str | None = None) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if variant and r.get("variant") != variant:
            continue
        recs.append(r)
    return recs


def calibration_index(dryrun_dir: str) -> dict:
    idx = {}
    for r in load_records(dryrun_dir, "calib"):
        if r.get("status") == "ok":
            idx[(r["arch"], r["shape"], r["mesh"])] = r
    return idx


def corrected_costs(rec: dict, calib: dict | None) -> dict:
    """Per-device (flops, bytes, coll) with loop-trip correction."""
    raw_coll = sum(v["bytes"] for v in rec["collectives"].values())
    out = {"flops": rec["cost"]["flops"],
           "bytes": rec["cost"]["bytes_accessed"],
           "coll": raw_coll, "corrected": False}
    if calib is not None:
        trips = calib["trips"]
        for key, cal in (("flops", calib["flops"]),
                         ("bytes", calib["bytes"]),
                         ("coll", calib["coll"])):
            corr = cal["outside"] + trips * cal["body"]
            # correction never reduces below the as-reported number
            out[key] = max(out[key], corr)
        out["corrected"] = True
    return out


def roofline_terms(rec: dict, calib: dict | None = None) -> dict | None:
    if rec.get("status") != "ok":
        return None
    costs = corrected_costs(rec, calib)
    t_c = costs["flops"] / PEAK_FLOPS
    t_m = costs["bytes"] / HBM_BW
    t_x = costs["coll"] / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    total_flops = costs["flops"] * rec["chips"]
    ratio = (rec["model_flops_global"] / total_flops
             if total_flops else 0.0)
    bound = max(t_c, t_m, t_x)
    mem_dev = rec["memory"]
    fits = (mem_dev["argument_bytes"] + mem_dev["temp_bytes"]
            + mem_dev["output_bytes"] - mem_dev["alias_bytes"]) <= HBM_CAP
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom[1], "bound_s": bound,
        "roofline_fraction": (t_c / bound) if bound else 0.0,
        "useful_flops_ratio": ratio,
        "fits_hbm": fits,
        "corrected": costs["corrected"],
        "bytes_per_device": mem_dev["argument_bytes"]
        + mem_dev["temp_bytes"],
    }


def roofline_rows(dryrun_dir: str = "experiments/dryrun",
                  variant: str = "baseline") -> list[tuple]:
    calib_idx = calibration_index(dryrun_dir)
    rows = []
    for rec in load_records(dryrun_dir, variant):
        tag = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("status") == "skipped":
            rows.append((tag, 0.0, f"SKIP: {rec['reason'][:60]}"))
            continue
        if rec.get("status") != "ok":
            rows.append((tag, float("inf"), "DRYRUN-ERROR"))
            continue
        calib = calib_idx.get((rec["arch"], rec["shape"], rec["mesh"]))
        t = roofline_terms(rec, calib)
        rows.append((
            tag, t["bound_s"] * 1e6,
            f"dom={t['dominant']} comp={t['compute_s']*1e6:.0f}us "
            f"mem={t['memory_s']*1e6:.0f}us coll={t['collective_s']*1e6:.0f}us "
            f"frac={t['roofline_fraction']:.2f} "
            f"useful={t['useful_flops_ratio']:.2f} fits={t['fits_hbm']} "
            f"cal={t['corrected']}"))
    return rows
