"""End-to-end serving driver: the PAM engine under a synthetic request
stream, with the paper's timing model attached.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --requests 16 --system pam

Multi-device cluster mode (paper §4.3) — route the stream across
heterogeneous devices with online KV balancing:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --requests 32 --devices hbm:1,cxl:2 --block-size 8

Chaos mode — inject a deterministic fault trace (kills, stalls,
transfer corruption, pool exhaustion) and serve through it with the
recovery watchdog attached:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --requests 32 --devices hbm:1,cxl:2 --block-size 8 \
        --chaos 'kill:cxl1@40,corrupt@20' --chaos-seed 0

Serving front-end mode (PR 8) — run a seeded arrival trace through the
async streaming server (``repro.frontend``) with chunked prefill and
SLO-aware admission, scoring TTFT/TPOT tails and SLO attainment:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --serve --requests 64 --trace onoff --rate 200 \
        --block-size 8 --prefill-chunk 8 --slo-ttft-ms 250

``--port N`` additionally drives the trace through the line-delimited
JSON socket endpoint on 127.0.0.1:N (0 picks a free port) instead of
the in-process API — same tokens, exercised over the wire.

Telemetry (PR 9) — any mode: ``--trace-out trace.json`` records the
request-lifecycle/device-event trace (open trace.json at
https://ui.perfetto.dev) and ``--metrics-interval N`` streams live
registry snapshots as JSON lines; both print the final metrics
snapshot at exit:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --requests 32 --devices hbm:1,cxl:2 --block-size 8 \
        --chaos 'kill:cxl1@40' --trace-out trace.json
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.perfmodel import make_latency_model
from repro.models import transformer as tfm
from repro.models.config import get_config, reduced
from repro.perfmodel.model import PAM_LLAMA_7B, SystemKind, make_system
from repro.serving import (EngineSpec, PAMManagerConfig, Request,
                           ServingConfig)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--system", default="pam",
                    choices=[k.value for k in SystemKind] + ["wallclock"])
    ap.add_argument("--no-sparsity", action="store_true")
    ap.add_argument("--block-size", type=int, default=0,
                    help="paged warm/cold KV block tokens (0 = dense)")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="physical pool blocks (default: no overcommit)")
    ap.add_argument("--hot-window", type=int, default=0,
                    help="hot-tier ring slots (0 = full window; requires "
                         "--block-size): per-slot HBM-tier bytes stop "
                         "scaling with --max-len")
    ap.add_argument("--devices", default=None, metavar="SPEC",
                    help="cluster mode: heterogeneous device spec, e.g. "
                         "'hbm:1,cxl:2' (see repro.perfmodel.devices)")
    ap.add_argument("--shard", type=int, default=1,
                    help="devices per replica group (PR 10): the fused "
                         "decode step runs shard_map'ed over this many "
                         "devices sharing ONE sharded param replica; "
                         "with --devices, same-class runs group by this "
                         "size (needs that many local/XLA host devices)")
    ap.add_argument("--arrival-gap-ms", type=float, default=2.0,
                    help="cluster mode: mean Poisson arrival gap")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="cluster mode: fault trace, e.g. "
                         "'kill:hbm0@120,stall:cxl0@50x8,corrupt@30*2' "
                         "(see repro.cluster.faults)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for injected corruption bytes")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="on-device sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill slice budget in tokens (pow-2; "
                         "0 = monolithic prefill; requires --block-size)")
    ap.add_argument("--serve", action="store_true",
                    help="front-end mode: stream a seeded arrival trace "
                         "through the async server (repro.frontend)")
    ap.add_argument("--trace", default="poisson",
                    choices=["poisson", "gamma", "onoff"],
                    help="--serve: arrival trace shape")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="--serve: mean arrival rate (req/s)")
    ap.add_argument("--slo-ttft-ms", type=float, default=250.0,
                    help="--serve: time-to-first-token SLO")
    ap.add_argument("--slo-tpot-ms", type=float, default=50.0,
                    help="--serve: per-output-token SLO")
    ap.add_argument("--port", type=int, default=None,
                    help="--serve: drive the trace through the NDJSON "
                         "socket endpoint on this port (0 = ephemeral)")
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record request-lifecycle + device events and "
                         "write a Perfetto-loadable Chrome trace JSON "
                         "here at exit (enables the metrics registry)")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    help="emit a live metrics snapshot JSON line every "
                         "N steps/ticks (0 = only the final snapshot; "
                         "any value enables the metrics registry)")
    args = ap.parse_args(argv)

    # telemetry (PR 9): install registry/collector BEFORE building
    # engines — instruments bind at construction time
    telemetry = bool(args.trace_out) or args.metrics_interval > 0
    if telemetry:
        obs_metrics.install()
    if args.trace_out:
        obs_trace.install()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))

    pam_cfg = None
    if cfg.has_decode:
        pam_cfg = PAMManagerConfig(
            max_tokens=args.max_len,
            hot_capacity=max(args.max_len // 8, 8),
            warm_capacity=max(args.max_len // 4, 16),
            compression=4, recency_window=8, schedule_interval=2,
            use_sparsity=not args.no_sparsity)

    if args.prefill_chunk and not args.block_size:
        ap.error("--prefill-chunk requires --block-size (paged KV)")
    scfg = ServingConfig(max_batch=args.max_batch, max_len=args.max_len,
                         pam=pam_cfg, block_size=args.block_size,
                         pool_blocks=args.pool_blocks,
                         hot_window=args.hot_window,
                         temperature=args.temperature, top_k=args.top_k,
                         prefill_chunk=args.prefill_chunk)
    rng = np.random.default_rng(0)

    try:
        if args.serve:                 # ---- front-end mode (PR 8)
            return _serve_mode(args, ap, cfg, params, scfg)
        return _batch_mode(args, ap, cfg, params, scfg, rng)
    finally:
        if telemetry:
            _finish_telemetry(args)


def _metrics_emit(tick: int) -> None:
    """One live metrics line (scalar series only; histograms land in
    the final snapshot)."""
    snap = obs_metrics.get_registry().snapshot()
    print(json.dumps({"op": "metrics", "tick": tick,
                      "counters": snap["counters"],
                      "gauges": snap["gauges"]}))


def _finish_telemetry(args) -> None:
    """Exit-time telemetry flush: final registry snapshot and (with
    ``--trace-out``) the balanced Chrome trace JSON."""
    reg = obs_metrics.get_registry()
    if reg.enabled:
        print(json.dumps({"op": "metrics", "final": True,
                          "metrics": reg.snapshot()}))
    tr = obs_trace.COLLECTOR
    if tr is not None and args.trace_out:
        tr.close_open()          # balanced even if work was in flight
        tr.write(args.trace_out)
        print(f"trace: {len(tr.events)} events "
              f"({tr.dropped} dropped) -> {args.trace_out}")


def _build_backend(args, ap, cfg, params, scfg, *,
                   recovery_default: bool = False):
    """(backend, engine-or-None): a ``ClusterRouter`` in ``--devices``
    mode, a bare ``ServingEngine`` otherwise — both speaking the PR 10
    unified surface (``as_router()`` / ``serve()``), so no caller
    special-cases the two. Construction goes through
    ``ClusterSpec``/``EngineSpec`` only."""
    if args.devices:                   # ---- cluster mode (paper §4.3)
        if args.system not in ("pam", "wallclock"):
            ap.error("--devices models PAM-class devices; --system must "
                     "be 'pam' (modeled, the default) or 'wallclock'")
        from repro.cluster import (BalancerConfig, ClusterSpec,
                                   FaultInjector, KVBalancer,
                                   RecoveryConfig)
        faults = rec_cfg = None
        if args.chaos:
            faults = FaultInjector.from_spec(args.chaos,
                                             seed=args.chaos_seed)
        if args.chaos or recovery_default:
            rec_cfg = RecoveryConfig()
        spec = ClusterSpec.from_cli(
            args.devices, model=cfg, serving=scfg, shard=args.shard,
            recovery=rec_cfg, wallclock=(args.system == "wallclock"))
        router = spec.build(params, balancer=KVBalancer(BalancerConfig()),
                            faults=faults)
        return router, None
    latency = None
    if args.system != "wallclock":
        latency = make_latency_model(make_system(args.system),
                                     PAM_LLAMA_7B)
    eng = EngineSpec(model=cfg, serving=scfg, shard=args.shard).build(
        params, latency_model=latency)
    return eng, eng


def _batch_mode(args, ap, cfg, params, scfg, rng) -> None:
    backend, engine = _build_backend(args, ap, cfg, params, scfg)
    router = backend.as_router()
    t = 0.0
    reqs = []
    for i in range(args.requests):
        if args.devices:
            t += float(rng.exponential(args.arrival_gap_ms / 1e3))
        reqs.append(Request(
            id=i, prompt=rng.integers(0, cfg.vocab, args.prompt_len),
            max_new_tokens=args.gen_len, arrival=t))
    if args.metrics_interval > 0:
        for req in reqs:
            router.submit(req)
        limit, n = router.rcfg.max_ticks, 0
        while router.tick():
            n += 1
            if n >= limit:
                raise RuntimeError(f"no drain in {limit} ticks")
            if n % args.metrics_interval == 0:
                _metrics_emit(n)
    else:
        # the unified streaming surface: one generator, engine or fleet
        for _ev in router.serve(reqs):
            pass
    summary = router.summary()
    if engine is not None:
        # single-device runs keep the engine-level detail keys (paged
        # stats, chunked-prefill counters, TPOT percentiles) alongside
        # the router view
        for k, v in engine.summary().items():
            summary.setdefault(k, v)
    print(json.dumps(summary, indent=1))
    for slo_ms in (100, 150, 200):
        print(f"SLO {slo_ms}ms attainment: "
              f"{router.slo_attainment(slo_ms/1e3):.3f}")


async def _pump_with_metrics(srv, trace, interval: int) -> None:
    """``serve_trace`` with a live metrics line every ``interval``
    pump iterations."""
    import asyncio

    for req in trace:
        srv.submit(req.prompt, req.max_new_tokens, rid=req.id,
                   arrival=req.arrival)
    limit, n = srv.router.rcfg.max_ticks, 0
    while srv.step():
        n += 1
        if n >= limit:
            raise RuntimeError(f"server did not drain in {limit} ticks")
        if n % interval == 0:
            _metrics_emit(n)
        if n % srv.ticks_per_yield == 0:
            await asyncio.sleep(0)


async def _drive_socket(srv, trace, port: int):
    """Replay the trace over the NDJSON endpoint: one loopback client
    per request, all token lines consumed (the wire-path variant of
    ``serve_trace`` — arrivals happen as connections land)."""
    import asyncio
    import json as _json

    server, bound, pump = await srv.serve_endpoint(port=port)

    async def one(req):
        reader, writer = await asyncio.open_connection("127.0.0.1", bound)
        writer.write((_json.dumps(
            {"id": req.id, "prompt": req.prompt.tolist(),
             "max_new_tokens": req.max_new_tokens}) + "\n").encode())
        await writer.drain()
        while True:
            line = await reader.readline()
            if not line or _json.loads(line)["done"]:
                break
        writer.close()

    try:
        await asyncio.gather(*(one(r) for r in trace))
    finally:
        pump.cancel()
        server.close()
        await server.wait_closed()
    return bound


def _serve_mode(args, ap, cfg, params, scfg) -> None:
    import asyncio

    from repro.frontend.admission import SLOAdmission, SLOSpec
    from repro.frontend.loadgen import TraceConfig, make_trace, score
    from repro.frontend.server import AsyncServer

    backend, _ = _build_backend(args, ap, cfg, params, scfg,
                                recovery_default=True)

    slo = SLOSpec(ttft_s=args.slo_ttft_ms / 1e3,
                  tpot_s=args.slo_tpot_ms / 1e3)
    trace = make_trace(TraceConfig(
        kind=args.trace, n_requests=args.requests, rate_rps=args.rate,
        prompt_len=(max(args.prompt_len // 2, 1), args.prompt_len),
        max_new=(max(args.gen_len // 2, 1), args.gen_len),
        vocab=cfg.vocab, seed=args.trace_seed))
    srv = AsyncServer(backend, admission=SLOAdmission(slo))

    port = None
    if args.port is None:
        if args.metrics_interval > 0:
            asyncio.run(_pump_with_metrics(srv, trace,
                                           args.metrics_interval))
        else:
            asyncio.run(srv.serve_trace(trace))
    else:
        port = asyncio.run(_drive_socket(srv, trace, args.port))

    sc = score(srv.records.values(), ttft_slo_s=slo.ttft_s,
               tpot_slo_s=slo.tpot_s)
    back = srv.router.summary()
    payload = {
        "mode": "serve",
        "trace": args.trace,
        "rate_rps": args.rate,
        "prefill_chunk": args.prefill_chunk,
        "port": port,
        "score": sc,
        "admission": srv.admission.summary(),
        "backend": {k: back[k] for k in
                    ("finished", "rejected", "total_tokens",
                     "makespan_s", "throughput_tok_s", "ticks")},
    }
    print(json.dumps(payload, indent=1))


if __name__ == "__main__":
    main()
