"""Training substrate tests: optimizer math, schedules, grad compression,
data determinism, checkpoint atomicity + resume, and a real end-to-end
loss-decreases run on a tiny model."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.data import SyntheticLM
from repro.models.config import get_config, reduced
from repro.training import optim
from repro.training.train_step import (TrainConfig, TrainState,
                                       build_train_step, compress_int8,
                                       decompress_int8, init_train_state)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    st = optim.adamw_init(params)
    cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, st, gnorm = optim.adamw_update(cfg, grads, st, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    st = optim.adamw_init(params)
    cfg = optim.AdamWConfig(lr=1.0, weight_decay=0.0, grad_clip=1.0)
    _, _, gnorm = optim.adamw_update(cfg, {"w": jnp.full(3, 1e6)}, st, params)
    assert float(gnorm) > 1e5   # reported norm is pre-clip


def test_wsd_schedule_phases():
    lr = optim.wsd_schedule(peak=1.0, warmup=10, stable=20, decay=10)
    assert float(lr(jnp.int32(5))) == pytest.approx(0.5)
    assert float(lr(jnp.int32(20))) == pytest.approx(1.0)
    assert float(lr(jnp.int32(40))) == pytest.approx(0.01, rel=1e-3)


def test_cosine_schedule_monotone_decay():
    lr = optim.cosine_schedule(peak=1.0, warmup=5, total=100)
    vals = [float(lr(jnp.int32(s))) for s in range(5, 100, 10)]
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))


# --------------------------------------------------------------- compression
def test_int8_roundtrip_error_small():
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (256,))
    q, s = compress_int8(g)
    deq = decompress_int8(q, s)
    assert q.dtype == jnp.int8
    rel = float(jnp.max(jnp.abs(deq - g)) / jnp.max(jnp.abs(g)))
    assert rel < 1.0 / 127 + 1e-6


def test_error_feedback_preserves_signal():
    """With error feedback, repeated compression of a constant gradient
    converges to the true value on average."""
    from repro.training.train_step import _compress_with_feedback
    g = {"w": jnp.full((64,), 0.013)}
    ef = {"w": jnp.zeros((64,))}
    total = jnp.zeros((64,))
    for _ in range(50):
        dq, ef = _compress_with_feedback(g, ef)
        total = total + dq["w"]
    np.testing.assert_allclose(np.asarray(total / 50),
                               np.full(64, 0.013), rtol=0.02)


# ---------------------------------------------------------------------- data
def test_synthetic_data_deterministic_and_restartable():
    ds = SyntheticLM(vocab=256, seq_len=32, batch=4, seed=7)
    a = ds.batch_at(step=5, rank=2)
    b = ds.batch_at(step=5, rank=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(step=5, rank=3)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["labels"][0, -1] == -1


# ------------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    d = str(tmp_path / "ck")
    save_pytree(tree, d)
    template = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    back = restore_pytree(template, d)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
    assert not os.path.exists(d + ".tmp")


def test_checkpoint_manager_retention_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(3)}
    for s in (10, 20, 30):
        mgr.save(s, jax.tree.map(lambda x, s=s: x + s, tree))
    assert mgr.steps() == [20, 30]
    assert mgr.latest_step() == 30
    step, restored = mgr.restore_latest(tree)
    assert step == 30
    np.testing.assert_allclose(np.asarray(restored["w"]), 30.0)


def test_checkpoint_crash_recovery(tmp_path):
    """A stale .tmp dir (crash mid-write) is ignored and GC'd."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    os.makedirs(str(tmp_path / "step_00000099.tmp"))
    assert mgr.latest_step() is None
    mgr.save(1, {"w": jnp.zeros(1)})
    assert mgr.latest_step() == 1
    assert not os.path.exists(str(tmp_path / "step_00000099.tmp"))


# ------------------------------------------------------------------- e2e
def test_train_loss_decreases_and_resumes(tmp_path):
    """Tiny model, real data pipeline, checkpoint mid-run, resume,
    and verify the resumed trajectory matches the uninterrupted one."""
    cfg = reduced(get_config("qwen3-0.6b"))
    tcfg = TrainConfig(adamw=optim.AdamWConfig(lr=1e-2, weight_decay=0.0))
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=32, batch=8, seed=1)
    step_fn = jax.jit(build_train_step(cfg, tcfg))

    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    losses = []
    mgr = CheckpointManager(str(tmp_path), keep=1)
    for s in range(40):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        if s == 19:
            mgr.save(20, state)

    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses

    # resume from step 20 and re-run steps 20..39 — identical trajectory
    step0, resumed = mgr.restore_latest(state)
    assert step0 == 20
    relosses = []
    for s in range(20, 40):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
        resumed, m = step_fn(resumed, batch)
        relosses.append(float(m["loss"]))
    np.testing.assert_allclose(relosses, losses[20:], rtol=1e-4)


def test_microbatch_accumulation_matches_full_batch():
    cfg = reduced(get_config("qwen3-0.6b"))
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=16, batch=8, seed=3)
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

    t_full = TrainConfig(adamw=optim.AdamWConfig(lr=1e-3, weight_decay=0.0))
    t_micro = TrainConfig(adamw=optim.AdamWConfig(lr=1e-3, weight_decay=0.0),
                          microbatches=4)
    s0 = init_train_state(cfg, t_full, jax.random.PRNGKey(0))
    s1 = TrainState(s0.params, s0.opt, s0.error_feedback)

    full_step = jax.jit(build_train_step(cfg, t_full))
    micro_step = jax.jit(build_train_step(cfg, t_micro))

    sA, mA = full_step(s0, batch)
    mb = {k: v.reshape((4, 2) + v.shape[1:]) for k, v in batch.items()}
    sB, mB = micro_step(s1, mb)

    np.testing.assert_allclose(float(mA["loss"]), float(mB["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(sA.params), jax.tree.leaves(sB.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)
