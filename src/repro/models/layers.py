"""Shared neural layers (pure functional JAX)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def init_linear(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(jnp.float32(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def init_embedding(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


# ------------------------------------------------------------------- RoPE
def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, d) or (..., S, d); positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    if x.ndim == angles.ndim + 1:                     # has head axis
        angles = angles[..., None, :]                 # (..., S, 1, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
