"""Serving front-end smoke for scripts/verify.sh: a seeded Poisson
trace streamed through the async server with chunked prefill and SLO
admission attached. Must stream every token exactly once (zero lost /
duplicated), keep every chunked stream bit-identical to a direct
engine run of the same requests, and attain the smoke SLO.

    PYTHONPATH=src python scripts/serving_smoke.py
"""

import asyncio

import jax

jax.config.update("jax_platform_name", "cpu")

from repro.frontend.admission import SLOAdmission, SLOSpec     # noqa: E402
from repro.frontend.loadgen import (TraceConfig, make_trace,   # noqa: E402
                                    score)
from repro.frontend.server import AsyncServer                  # noqa: E402
from repro.models import transformer as tf                     # noqa: E402
from repro.models.config import get_config, reduced            # noqa: E402
from repro.perfmodel import make_latency_model                 # noqa: E402
from repro.perfmodel.model import PAM_LLAMA_7B, make_system    # noqa: E402
from repro.serving import (EngineSpec, PAMManagerConfig,       # noqa: E402
                           Request, ServingConfig)

SLO = SLOSpec(ttft_s=0.25, tpot_s=0.05)


def main():
    cfg = reduced(get_config("qwen3-0.6b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    lat = make_latency_model(make_system("pam"), PAM_LLAMA_7B)
    pam = PAMManagerConfig(max_tokens=96, hot_capacity=12,
                           warm_capacity=24, compression=4,
                           recency_window=8, schedule_interval=2)
    scfg = ServingConfig(max_batch=4, max_len=96, pam=pam, block_size=8,
                         prefill_chunk=8)
    tcfg = TraceConfig(kind="poisson", n_requests=16, rate_rps=200.0,
                       prompt_len=(6, 40), max_new=(3, 10),
                       vocab=cfg.vocab, seed=3)

    eng = EngineSpec(model=cfg,
                     serving=scfg).build(params, latency_model=lat)
    srv = AsyncServer(eng, admission=SLOAdmission(SLO))
    records = asyncio.run(srv.serve_trace(make_trace(tcfg)))
    sc = score(records.values(), ttft_slo_s=SLO.ttft_s,
               tpot_slo_s=SLO.tpot_s)

    assert sc["lost_tokens"] == 0 and sc["dup_tokens"] == 0, sc
    assert sc["finished"] + sc["rejected"] == tcfg.n_requests, sc
    assert sc["slo_attainment"] >= 0.9, sc

    # chunked streams must be bit-identical to a direct engine run of
    # the same requests (no arrival gating, no front end in the loop)
    twin = EngineSpec(model=cfg,
                      serving=scfg).build(params, latency_model=lat)
    for r in make_trace(tcfg):
        twin.submit(Request(id=r.id, prompt=r.prompt,
                            max_new_tokens=r.max_new_tokens))
    twin.run()
    for rid, rec in records.items():
        if not rec.rejected:
            assert rec.tokens == twin.requests[rid].outputs, rid

    chunked = eng.summary()["chunked_admissions"]
    print(f"serving smoke OK: {sc['finished']} finished / "
          f"{sc['rejected']} rejected, {sc['tokens']} tokens streamed "
          f"exactly once, {chunked} chunked admissions, SLO attainment "
          f"{sc['slo_attainment']:.3f}, p99 TTFT "
          f"{sc['ttft_s']['p99'] * 1e3:.2f} ms sim")


if __name__ == "__main__":
    main()
