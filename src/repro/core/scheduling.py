"""Inter-device online KV scheduling (paper §6.3.2, Algorithm 2).

Greedy swap loop driving the per-tier importance ratio
``IS_H : IS_D : IS_S`` toward the offline-profiled target ``x : y : 1``:

  phase 1: while (x* + y*) < (x + y):  swap(least-important DDR token,
                                             most-important SSD token)
  phase 2: while x*/y*   <   x/y:      swap(least-important HBM token,
                                             most-important DDR token)

Both phases only demote *low*-importance tokens downward and promote
*high*-importance tokens upward, so the swap is always importance-improving
for the faster tier. The loop is bounded (``max_swaps``) — the paper reports
only ~0.7% of tokens move per decoding step — and implemented with
``lax.while_loop`` so it jits and runs on-device next to the KV cache.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.tiers import COLD, HOT, WARM

_NEG = -jnp.inf
_POS = jnp.inf


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    x: float = 8.0            # target IS_H / IS_S   (offline-profiled)
    y: float = 3.0            # target IS_D / IS_S
    max_swaps: int = 32       # per decode step; paper: ~0.7% of tokens
    eps: float = 1e-6


class _SwapState(NamedTuple):
    tier: jax.Array       # (tokens,) int32
    swaps: jax.Array      # scalar int32 — swaps executed so far
    moved: jax.Array      # (tokens,) bool — tokens moved this call
    stuck: jax.Array      # scalar bool — no improving swap exists; terminate


def _tier_stats(imp, tier, valid, t):
    on = (tier == t) & valid
    cnt = jnp.maximum(jnp.sum(on), 1)
    return jnp.sum(jnp.where(on, imp, 0.0)) / cnt, on


def _swap_phase(imp, valid, state: _SwapState, src_tier: int, dst_tier: int,
                cond_fn, max_swaps: int) -> _SwapState:
    """Repeatedly swap (least-important src) <-> (most-important dst)."""

    def body(s: _SwapState) -> _SwapState:
        on_src = (s.tier == src_tier) & valid
        on_dst = (s.tier == dst_tier) & valid
        demote = jnp.argmin(jnp.where(on_src, imp, _POS))   # least important fast-tier
        promote = jnp.argmax(jnp.where(on_dst, imp, _NEG))  # most important slow-tier
        # Only swap if it is importance-improving for the faster tier.
        ok = (jnp.any(on_src) & jnp.any(on_dst)
              & (imp[promote] > imp[demote]))
        new_tier = s.tier.at[demote].set(
            jnp.where(ok, dst_tier, s.tier[demote]))
        new_tier = new_tier.at[promote].set(
            jnp.where(ok, src_tier, new_tier[promote]))
        moved = s.moved.at[demote].set(s.moved[demote] | ok)
        moved = moved.at[promote].set(moved[promote] | ok)
        return _SwapState(new_tier, s.swaps + ok.astype(jnp.int32), moved,
                          ~ok)

    def cond(s: _SwapState):
        return (s.swaps < max_swaps) & ~s.stuck & cond_fn(s)

    out = jax.lax.while_loop(cond, body,
                             state._replace(stuck=jnp.zeros((), bool)))
    return out._replace(stuck=jnp.zeros((), bool))


@partial(jax.jit, static_argnames=("cfg",))
def schedule_kv(importance: jax.Array, tier_of_token: jax.Array,
                valid: jax.Array, cfg: ScheduleConfig = ScheduleConfig()
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Run Algorithm 2. Returns (new_tier_of_token, moved_mask, num_swaps)."""
    imp = importance.astype(jnp.float32)
    state = _SwapState(tier_of_token,
                       jnp.zeros((), jnp.int32),
                       jnp.zeros(tier_of_token.shape, bool),
                       jnp.zeros((), bool))

    def ratios(tier):
        is_h, _ = _tier_stats(imp, tier, valid, HOT)
        is_d, _ = _tier_stats(imp, tier, valid, WARM)
        is_s, _ = _tier_stats(imp, tier, valid, COLD)
        is_s = jnp.maximum(is_s, cfg.eps)
        return is_h / is_s, is_d / is_s

    # Phase 1 (lines 2-6): balance {HBM+DDR} vs SSD — swap DDR<->SSD while
    # (x* + y*) < (x + y).
    def phase1_cond(s: _SwapState):
        xs, ys = ratios(s.tier)
        return (xs + ys) < (cfg.x + cfg.y)

    state = _swap_phase(imp, valid, state, WARM, COLD, phase1_cond,
                        cfg.max_swaps)

    # Phase 2 (lines 7-11): balance HBM vs DDR — swap HBM<->DDR while
    # x*/y* < x/y.
    def phase2_cond(s: _SwapState):
        xs, ys = ratios(s.tier)
        return xs < (cfg.x / cfg.y) * jnp.maximum(ys, cfg.eps)

    state = _swap_phase(imp, valid, state, HOT, WARM, phase2_cond,
                        cfg.max_swaps)

    return state.tier, state.moved, state.swaps


def ratio_error(importance: jax.Array, tier_of_token: jax.Array,
                valid: jax.Array, cfg: ScheduleConfig) -> jax.Array:
    """Distance of current tier-importance ratios from the x:y:1 target —
    the quantity Algorithm 2 monotonically improves (property-tested)."""
    imp = importance.astype(jnp.float32)
    is_h, _ = _tier_stats(imp, tier_of_token, valid, HOT)
    is_d, _ = _tier_stats(imp, tier_of_token, valid, WARM)
    is_s, _ = _tier_stats(imp, tier_of_token, valid, COLD)
    is_s = jnp.maximum(is_s, cfg.eps)
    xs, ys = is_h / is_s, is_d / is_s
    return (jnp.maximum(cfg.x + cfg.y - (xs + ys), 0.0)
            + jnp.maximum(cfg.x / cfg.y - xs / jnp.maximum(ys, cfg.eps), 0.0))
