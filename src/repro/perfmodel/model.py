"""Analytical model of PAM + the four baselines (paper §7.1 methodology).

The paper evaluates with an in-house simulator (LLMServingSim + LLMCompass
+ Ramulator2 + OpenSSD). This module reproduces that methodology
analytically: every system is reduced to roofline terms over the same
hardware constants (Table 1), and every benchmark table/figure in
``benchmarks/`` is generated from it. The *real* algorithmic state
(hit rates, tier occupancy, migration counts) can be fed from the actual
serving engine (``ServingEngine(latency_model=...)``), closing the loop
between the executable system and the model.

Platform (paper §7.1): one node = 8 x (H100-80GB-class NPU) + 40xHBM +
40xDDR4 + 64ch SSD; PAM adds near-bank/controller PUs+RUs per Table 1.
"""

from __future__ import annotations

import dataclasses
import enum
import math

from repro.core.tiers import DDR_PIM, HBM_PIM, SSD_PIM, TierSpec


class SystemKind(str, enum.Enum):
    VLLM_OFFLOAD = "vllm-offload"
    ATTACC = "attacc"
    LPIM = "l-pim"
    LSPIM = "ls-pim"
    PAM = "pam"


@dataclasses.dataclass(frozen=True)
class ModelDesc:
    """Decode-step cost descriptor (enough for the paper's models)."""
    name: str
    params: float                 # active parameters
    n_layers: int
    n_kv_heads: int
    head_dim: int
    latent_dim: int = 0           # MLA: cached latent width (0 = GQA)

    def kv_bytes_per_token(self) -> float:
        if self.latent_dim:
            return self.n_layers * self.latent_dim * 2.0
        return self.n_layers * 2 * self.n_kv_heads * self.head_dim * 2.0


# paper's evaluation models
QWEN25_32B = ModelDesc("qwen2.5-32b", 32e9, 64, 8, 128)
LLAMA3_70B = ModelDesc("llama3-70b", 70e9, 80, 8, 128)
OPT_175B = ModelDesc("opt-175b", 175e9, 96, 96, 128)
PAM_LLAMA_7B = ModelDesc("pam-llama-7b", 6.7e9, 32, 32, 128)


@dataclasses.dataclass(frozen=True)
class NodeHW:
    """Per-node hardware (8-instance node, DGX-H100-comparable)."""
    npu_flops: float = 8 * 989e12          # bf16 dense
    npu_hbm_bw: float = 8 * 3.35e12
    hbm_cap: float = 8 * 80e9
    pcie_bw: float = 8 * 64e9              # offload path
    nvlink_bw: float = 8 * 450e9           # TP all-reduce path
    hbm: TierSpec = HBM_PIM
    ddr: TierSpec = DDR_PIM
    ssd: TierSpec = SSD_PIM
    # energy constants (pJ)
    pj_per_flop: float = 0.6
    pj_per_byte_pcie: float = 30.0
    pj_per_byte_nvlink: float = 10.0


@dataclasses.dataclass(frozen=True)
class SystemModel:
    kind: SystemKind
    hw: NodeHW = NodeHW()
    sparsity: int = 1             # retrieval compression (8 for LS-PIM/PAM)
    pam_hit_rate: float = 0.9     # hot-set fraction served from HBM tier
    mapping_imbalance: float = 1.0  # intra-device T_intra inflation
    reduction_overhead: float = 0.02  # PAMattention RU time share (<2%, §5.2)
    migrate_fraction: float = 0.001   # working-set fraction migrated/step (§6.3: <0.1%)

    # ------------------------------------------------------------ capacity
    def _caps(self, model: ModelDesc) -> tuple[float, float, float]:
        """Per-tier KV capacity: model weights occupy the top (HBM) tier."""
        hw = self.hw
        wbytes = 2.0 * model.params
        top = hw.hbm_cap if self.kind in (SystemKind.VLLM_OFFLOAD,
                                          SystemKind.ATTACC)             else hw.hbm.capacity_bytes
        return (max(top - wbytes, 0.0), hw.ddr.capacity_bytes,
                hw.ssd.capacity_bytes)

    def kv_capacity(self, model: ModelDesc) -> float:
        caps = self._caps(model)
        if self.kind == SystemKind.ATTACC:
            return caps[0]
        return sum(caps)

    # --------------------------------------------------------- placement
    def _tier_split(self, model: ModelDesc, kv_bytes: float
                    ) -> tuple[float, float, float]:
        """Fill-down placement of the resident KV across tiers."""
        out = []
        rest = kv_bytes
        for c in self._caps(model):
            take = min(rest, c)
            out.append(take)
            rest -= take
        return tuple(out)

    # ------------------------------------------------------------- timing
    def fc_time(self, model: ModelDesc, batch: int) -> float:
        """Projection/FFN step time on the NPU (weight-bandwidth bound at
        small batch, compute bound at large batch) — same for all systems."""
        hw = self.hw
        flops = 2.0 * model.params * batch
        wbytes = 2.0 * model.params
        return max(flops / hw.npu_flops, wbytes / hw.npu_hbm_bw)

    def attention_time(self, model: ModelDesc, batch: int,
                       context: int) -> float:
        """Per-decode-step attention time under this system's policy."""
        hw = self.hw
        tok = model.kv_bytes_per_token()
        kv_total = batch * context * tok
        read_frac = 1.0 / self.sparsity
        h0, d0, s0 = self._tier_split(model, kv_total)

        if self.kind == SystemKind.VLLM_OFFLOAD:
            # attention on NPU; resident-HBM KV reads sparsely at HBM bw;
            # offloaded KV must cross PCIe at FULL volume every step —
            # token selection is per-step/per-head dynamic, so offloaded
            # pages cannot be sparsity-filtered before the transfer
            # (DeepSpeed-Inference offloading, §2.3.3)
            t_hbm = h0 * read_frac / hw.npu_hbm_bw
            t_pcie = (d0 + s0) / hw.pcie_bw
            return t_hbm + t_pcie

        if self.kind == SystemKind.ATTACC:
            if kv_total > self._caps(model)[0]:
                return math.inf                     # OOM (Fig. 10)
            return kv_total * read_frac / hw.hbm.effective_bw

        if self.kind in (SystemKind.LPIM, SystemKind.LSPIM):
            # tiers compute in parallel; sparse reads are UNIFORM across
            # tiers (static placement — no locality exploitation):
            reads = (h0 * read_frac, d0 * read_frac, s0 * read_frac)
            times = (reads[0] / hw.hbm.effective_bw,
                     reads[1] / hw.ddr.effective_bw,
                     reads[2] / hw.ssd.effective_bw)
            return max(times)                        # SSD-Attn bottleneck

        # PAM: the sparse working set is concentrated on fast tiers by
        # importance placement (hit_rate on HBM), Alg. 2 keeps it there.
        ws = kv_total * read_frac                    # working set bytes
        h = self.pam_hit_rate
        caps = self._caps(model)
        hot = min(ws * h, caps[0])
        warm = min(ws - hot, caps[1])    # misses go to DDR; SSD only when
        cold = max(ws - hot - warm, 0.0)  # HBM+DDR truly overflow
        times = (hot * self.mapping_imbalance / hw.hbm.effective_bw,
                 warm * self.mapping_imbalance / hw.ddr.effective_bw,
                 cold / hw.ssd.effective_bw)
        t_local = max(times)
        # inter-tier migration (Alg. 2: ~0.1% of the working set per step,
        # over the HBM<->DDR link through the PAM interface) + RU overhead
        t_mig = self.migrate_fraction * ws / self.hw.hbm.link_bw
        return t_local * (1 + self.reduction_overhead) + t_mig

    def decode_step_time(self, model: ModelDesc, batch: int,
                         context: int) -> float:
        return (self.fc_time(model, batch)
                + self.attention_time(model, batch, context))

    # ------------------------------------------------------------- energy
    def decode_step_energy(self, model: ModelDesc, batch: int,
                           context: int) -> float:
        """Joules per decode step."""
        hw = self.hw
        tok = model.kv_bytes_per_token()
        kv_total = batch * context * tok
        read_frac = 1.0 / self.sparsity
        flops = 2.0 * model.params * batch
        e = flops * hw.pj_per_flop * 1e-12
        e += 2.0 * model.params * 3.5 * 1e-12        # weight read (HBM)
        h0, d0, s0 = self._tier_split(model, kv_total)
        if self.kind == SystemKind.VLLM_OFFLOAD:
            e += h0 * read_frac * 3.5e-12
            e += (d0 + s0) * (hw.pj_per_byte_pcie + 15.0) * 1e-12
        elif self.kind == SystemKind.ATTACC:
            e += kv_total * read_frac * hw.hbm.energy_pj_per_byte * 1e-12
        elif self.kind in (SystemKind.LPIM, SystemKind.LSPIM):
            for b, t in ((h0, hw.hbm), (d0, hw.ddr), (s0, hw.ssd)):
                e += b * read_frac * t.energy_pj_per_byte * 1e-12
        else:
            ws = kv_total * read_frac
            h = self.pam_hit_rate
            caps = self._caps(model)
            hot = min(ws * h, caps[0])
            warm = min(ws - hot, caps[1])
            cold = max(ws - hot - warm, 0.0)
            e += hot * hw.hbm.energy_pj_per_byte * 1e-12
            e += warm * hw.ddr.energy_pj_per_byte * 1e-12
            e += cold * hw.ssd.energy_pj_per_byte * 1e-12
            e += (self.migrate_fraction * ws * 15.0) * 1e-12
        return e


def make_system(kind: SystemKind | str, **kw) -> SystemModel:
    kind = SystemKind(kind)
    defaults = {
        # vLLM-offload: sparse reads only on the HBM-resident part (the
        # offload path transfers full pages); L-PIM: no sparsity (mimics
        # AttAcc placement, §7.1); LS-PIM/PAM/AttAcc: 8x retrieval sparsity.
        SystemKind.VLLM_OFFLOAD: dict(sparsity=8),
        SystemKind.ATTACC: dict(sparsity=8),
        SystemKind.LPIM: dict(sparsity=1),
        SystemKind.LSPIM: dict(sparsity=8),
        SystemKind.PAM: dict(sparsity=8),
    }[kind]
    defaults.update(kw)
    return SystemModel(kind=kind, **defaults)


# ------------------------------------------------------------ simulations
@dataclasses.dataclass(frozen=True)
class StepWorkload:
    model: ModelDesc
    batch: int
    context: int


def simulate_decode_step(system: SystemModel, wl: StepWorkload) -> dict:
    t = system.decode_step_time(wl.model, wl.batch, wl.context)
    e = system.decode_step_energy(wl.model, wl.batch, wl.context)
    return {"time_s": t, "energy_j": e,
            "throughput_tok_s": (wl.batch / t) if math.isfinite(t) else 0.0,
            "energy_per_token_j": (e / wl.batch)
            if math.isfinite(t) else math.inf}


def simulate_online(system: SystemModel, model: ModelDesc, *,
                    avg_context: int, slo_s: float,
                    max_batch: int = 1 << 17) -> dict:
    """Paper Fig. 9 protocol: largest batch whose per-token decode latency
    meets the SLO under the capacity limit; report throughput."""
    tok = model.kv_bytes_per_token()
    best = None
    b = 1
    while b <= max_batch:
        if b * avg_context * tok > system.kv_capacity(model):
            break
        t = system.decode_step_time(model, b, avg_context)
        if t <= slo_s:
            best = (b, b / t)
        b *= 2
    if best is None:
        return {"max_batch": 0, "throughput_tok_s": 0.0}
    # refine between best and 2*best
    lo, hi = best[0], min(best[0] * 2, max_batch)
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if (mid * avg_context * tok <= system.kv_capacity(model)
                and system.decode_step_time(model, mid, avg_context)
                <= slo_s):
            lo = mid
        else:
            hi = mid
    t = system.decode_step_time(model, lo, avg_context)
    return {"max_batch": lo, "throughput_tok_s": lo / t}


def simulate_offline(system: SystemModel, model: ModelDesc, *,
                     batch: int, context: int) -> dict:
    """Paper Fig. 10 protocol: fixed batch size; OOM if over capacity."""
    tok = model.kv_bytes_per_token()
    if batch * context * tok > system.kv_capacity(model):
        return {"oom": True, "throughput_tok_s": 0.0}
    t = system.decode_step_time(model, batch, context)
    return {"oom": not math.isfinite(t),
            "throughput_tok_s": (batch / t) if math.isfinite(t) else 0.0}
