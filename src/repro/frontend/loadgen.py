"""Trace-driven load generation + serving-latency scoring (PR 8).

The serving regime the paper targets is online arrivals, not a fixed
batch: requests arrive on a stochastic clock and the system is judged
on TTFT/TPOT tails and SLO attainment, not throughput alone. This
module generates seeded arrival traces in the three canonical shapes —

- ``poisson``: memoryless arrivals at ``rate_rps`` (the steady-state
  baseline every serving paper reports);
- ``gamma``: a Gamma-renewal process with the same mean rate but
  inter-arrival CV^2 = ``burstiness`` > 1 (heavy-tailed gaps: clumps
  of near-simultaneous arrivals separated by lulls);
- ``onoff``: a two-state modulated process — ON windows arriving at
  ``rate_rps / duty_cycle`` followed by silent OFF windows, same
  average rate (the diurnal/burst pattern that stresses admission).

— and scores the resulting streams: TTFT/TPOT p50/p95/p99 and SLO
attainment, plus a zero-lost/zero-duplicated streamed-token check.
Everything is host-side numpy on an explicit ``seed``; the same config
always produces byte-identical traces.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

from repro.obs.metrics import LATENCY_BUCKETS, Histogram
from repro.serving.engine import Request

TRACE_KINDS = ("poisson", "gamma", "onoff")


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """One seeded arrival trace. Lengths are inclusive integer ranges
    sampled uniformly per request."""

    kind: str = "poisson"
    n_requests: int = 64
    rate_rps: float = 50.0             # mean arrival rate (req/s)
    prompt_len: tuple[int, int] = (8, 48)
    max_new: tuple[int, int] = (4, 24)
    vocab: int = 32_000
    seed: int = 0
    first_id: int = 0
    # gamma: inter-arrival CV^2 (1.0 degenerates to poisson);
    # onoff: ON-window arrival rate is rate_rps / duty_cycle
    burstiness: float = 4.0
    duty_cycle: float = 0.25           # onoff: fraction of period ON
    period_s: float = 1.0              # onoff: ON+OFF cycle length


def _arrival_times(tcfg: TraceConfig, rng: np.random.Generator) -> np.ndarray:
    n, rate = tcfg.n_requests, tcfg.rate_rps
    if rate <= 0:
        raise ValueError(f"rate_rps must be positive, got {rate}")
    if tcfg.kind == "poisson":
        gaps = rng.exponential(1.0 / rate, n)
        return np.cumsum(gaps)
    if tcfg.kind == "gamma":
        if tcfg.burstiness <= 0:
            raise ValueError("burstiness must be positive")
        shape = 1.0 / tcfg.burstiness
        scale = tcfg.burstiness / rate     # mean = shape*scale = 1/rate
        gaps = rng.gamma(shape, scale, n)
        return np.cumsum(gaps)
    if tcfg.kind == "onoff":
        if not 0 < tcfg.duty_cycle <= 1:
            raise ValueError("duty_cycle must be in (0, 1]")
        on_s = tcfg.duty_cycle * tcfg.period_s
        out, t = [], 0.0
        while len(out) < n:
            t += float(rng.exponential(tcfg.duty_cycle / rate))
            # past this period's ON window: jump to the next period
            while t - (t // tcfg.period_s) * tcfg.period_s >= on_s:
                t = (t // tcfg.period_s + 1.0) * tcfg.period_s
            out.append(t)
        return np.asarray(out)
    raise ValueError(f"unknown trace kind {tcfg.kind!r}; "
                     f"expected one of {TRACE_KINDS}")


def make_trace(tcfg: TraceConfig) -> list[Request]:
    """Materialize the trace: time-ordered ``Request``s with seeded
    random prompts, ready for ``ClusterRouter.submit`` /
    ``AsyncServer.submit``."""
    rng = np.random.default_rng(tcfg.seed)
    arrivals = _arrival_times(tcfg, rng)
    plo, phi = tcfg.prompt_len
    glo, ghi = tcfg.max_new
    if not (1 <= plo <= phi and 1 <= glo <= ghi):
        raise ValueError("prompt_len / max_new ranges must be 1 <= lo <= hi")
    reqs = []
    for i in range(tcfg.n_requests):
        plen = int(rng.integers(plo, phi + 1))
        gen = int(rng.integers(glo, ghi + 1))
        prompt = rng.integers(0, tcfg.vocab, plen).astype(np.int32)
        reqs.append(Request(id=tcfg.first_id + i, prompt=prompt,
                            max_new_tokens=gen,
                            arrival=float(arrivals[i])))
    return reqs


# ----------------------------------------------------------------- scoring
def _pcts(xs: list[float]) -> dict[str, float]:
    """Percentiles through the registry's log-bucket histogram (PR 9):
    offline scoring and live export share one source of percentile
    math, so a scorecard p99 and the exported
    ``pam_frontend_ttft_seconds`` p99 agree bucket-for-bucket. An empty
    sample returns zeros WITH an explicit ``n=0`` marker — zeros then
    mean "no samples", never "zero latency"."""
    h = Histogram.standalone("score", LATENCY_BUCKETS)
    for x in xs:
        h.observe(float(x))
    s = h.summary()
    return {"p50": s["p50"], "p95": s["p95"], "p99": s["p99"],
            "n": s["n"]}


def stream_integrity(records: Iterable) -> tuple[int, int]:
    """(lost, duplicated) streamed-token counts across finished
    streams: every non-rejected done stream must have emitted exactly
    indices 0..n-1, each once. Both must be zero for a correct server
    loop (the router already dedups replay re-emissions)."""
    lost = dup = 0
    for rec in records:
        if rec.rejected:
            continue
        idx = list(rec.indices)
        dup += len(idx) - len(set(idx))
        if rec.done and idx:
            lost += len(set(range(max(idx) + 1)) - set(idx))
    return lost, dup


def score(records: Iterable, *, ttft_slo_s: float,
          tpot_slo_s: float) -> dict:
    """Serving-latency scorecard over finished stream records (the
    ``AsyncServer``'s per-request ``StreamRecord``s).

    TTFT is first-token emission minus arrival; TPOT is the mean
    decode-token gap (streams of one token have no gap and score 0);
    ``itl_s`` is the POOLED per-token gap distribution across all
    streams — per-request means hide a single long stall (one
    monolithic prefill blocking a neighbour's decode step), pooled
    gaps surface it, which is the tail chunked prefill exists to cut.
    A request ATTAINS its SLO iff it finished (not rejected, not
    truncated) with TTFT <= ttft_slo_s and TPOT <= tpot_slo_s —
    rejections and unfinished streams count against attainment, so
    shedding load is visible in the metric it protects."""
    records = list(records)
    ttfts, tpots, attained = [], [], 0
    all_gaps: list[float] = []
    finished = rejected = tokens = 0
    for rec in records:
        if rec.rejected:
            rejected += 1
            continue
        if not rec.done or not rec.times:
            continue
        finished += 1
        tokens += len(rec.tokens)
        ttft = rec.times[0] - rec.arrival
        # migration seams can resync clocks; clamp like the router does
        gaps = np.maximum(np.diff(rec.times), 0.0)
        all_gaps.extend(gaps.tolist())
        tpot = float(np.mean(gaps)) if len(rec.times) > 1 else 0.0
        ttfts.append(float(ttft))
        tpots.append(tpot)
        if ttft <= ttft_slo_s and tpot <= tpot_slo_s:
            attained += 1
    lost, dup = stream_integrity(records)
    return {
        "n": len(records),
        "finished": finished,
        "rejected": rejected,
        "tokens": tokens,
        "ttft_s": _pcts(ttfts),
        "tpot_s": _pcts(tpots),
        "itl_s": _pcts(all_gaps),
        "slo_attainment": attained / len(records) if records else 1.0,
        "lost_tokens": lost,
        "dup_tokens": dup,
    }
