"""End-to-end training driver with the full fault-tolerance loop:
sharded train step, periodic checkpoints, auto-resume, straggler
monitoring, elastic re-mesh on failure.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ck

On a real pod the same driver runs under ``jax.distributed.initialize``;
here it runs on however many devices the process sees.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.distributed import sharding as shd
from repro.distributed.elastic import StragglerMonitor
from repro.models.config import get_config, reduced
from repro.training import optim
from repro.training.optim import AdamWState
from repro.training.train_step import (TrainConfig, TrainState,
                                       build_train_step, init_train_state)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--wsd", action="store_true",
                    help="MiniCPM WSD schedule instead of cosine")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    lr = (optim.wsd_schedule(args.lr, warmup=10, stable=args.steps // 2,
                             decay=args.steps // 3) if args.wsd
          else optim.cosine_schedule(args.lr, warmup=10, total=args.steps))
    tcfg = TrainConfig(
        adamw=optim.AdamWConfig(lr=lr),
        microbatches=args.microbatches,
        compress_grads=args.compress_grads)
    step_fn = jax.jit(build_train_step(cfg, tcfg), donate_argnums=(0,))

    # data + state
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))

    # multi-device: shard params/opt over available devices
    n_dev = jax.device_count()
    if n_dev > 1:
        mesh = jax.make_mesh((1, n_dev), ("data", "model"))
        pspecs = shd.param_specs(cfg, mesh)
        ospecs = shd.opt_state_specs(cfg, mesh)

        def put(tree, specs):
            return jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                tree, specs, is_leaf=lambda x: isinstance(x, P))
        state = TrainState(
            params=put(state.params, pspecs),
            opt=AdamWState(step=state.opt.step,
                           mu=put(state.opt.mu, ospecs),
                           nu=put(state.opt.nu, ospecs)),
            error_feedback=state.error_feedback)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr is not None:
        latest, restored = mgr.restore_latest(state)
        if latest is not None:
            print(f"[resume] from step {latest}")
            state, start = restored, latest

    mon = StragglerMonitor()
    t_all = time.time()
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(s).items()}
        if args.microbatches > 1:
            batch = {k: v.reshape((args.microbatches,
                                   v.shape[0] // args.microbatches)
                                  + v.shape[1:]) for k, v in batch.items()}
        t0 = time.time()
        state, m = step_fn(state, batch)
        dt = time.time() - t0
        mon.record(jax.process_index(), dt)
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} {dt*1e3:.0f}ms",
                  flush=True)
        if mgr is not None and (s + 1) % args.ckpt_every == 0:
            mgr.save(s + 1, state)
            print(f"[ckpt] step {s+1}")
    tok_s = (args.steps - start) * args.batch * args.seq / (
        time.time() - t_all)
    print(f"done: {tok_s:.0f} tok/s")


if __name__ == "__main__":
    main()
