"""internvl2-1b [arXiv:2404.16821; hf] — InternViT frontend (STUB: patch
embeddings via input_specs) + Qwen2-0.5B-like LM backbone."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151655, d_head=64,
    rope_theta=1e6, tie_embeddings=True,
    num_patches=256, frontend_dim=1024,
))
