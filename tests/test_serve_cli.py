"""`python -m repro.launch.serve` end-to-end, one smoke per mode
(PR 8 satellite): the CLI is the repo's demo surface and its arg
wiring — chunk validation, serve-mode plumbing, the socket driver —
is exactly the code no other test exercises.

Each test calls ``main(argv)`` in-process and parses what it printed:
batch/cluster/chaos modes print a summary JSON doc followed by
``SLO ...`` attainment lines; serve mode prints ONE JSON payload.
"""

import json

import pytest

from repro.launch.serve import main

COMMON = ["--reduced", "--max-len", "64", "--prompt-len", "16",
          "--gen-len", "4"]


def _summary_and_slo(out: str):
    """Split batch-mode output: indent-1 JSON doc, then SLO lines."""
    lines = out.strip().splitlines()
    cut = next(i for i, ln in enumerate(lines) if ln.startswith("SLO "))
    return json.loads("\n".join(lines[:cut])), lines[cut:]


def test_single_device_mode(capsys):
    main(COMMON + ["--requests", "4"])
    summary, slo = _summary_and_slo(capsys.readouterr().out)
    assert summary["finished"] == 4
    assert summary["total_tokens"] == 16
    assert len(slo) == 3 and all("attainment" in ln for ln in slo)


def test_single_device_chunked_prefill(capsys):
    main(COMMON + ["--requests", "4", "--block-size", "8",
                   "--prefill-chunk", "8"])
    summary, _ = _summary_and_slo(capsys.readouterr().out)
    assert summary["finished"] == 4
    assert summary["chunked_admissions"] == 4      # 16-token prompts
    assert summary["max_chunk_slice_tokens"] <= 8


def test_chunk_without_paged_pool_is_an_argparse_error(capsys):
    with pytest.raises(SystemExit) as ei:
        main(COMMON + ["--prefill-chunk", "8"])
    assert ei.value.code == 2
    assert "--block-size" in capsys.readouterr().err


def test_cluster_mode(capsys):
    main(COMMON + ["--requests", "6", "--devices", "hbm:1,cxl:2",
                   "--block-size", "8"])
    summary, slo = _summary_and_slo(capsys.readouterr().out)
    assert summary["finished"] == 6 and summary["rejected"] == 0
    assert set(summary["devices"]) == {"hbm0", "cxl0", "cxl1"}
    assert len(slo) == 3


def test_chaos_mode(capsys):
    main(COMMON + ["--requests", "12", "--devices", "hbm:1,cxl:2",
                   "--block-size", "8", "--chaos", "kill:cxl1@6",
                   "--chaos-seed", "0"])
    summary, _ = _summary_and_slo(capsys.readouterr().out)
    # graceful degradation: the kill is detected, the fleet loses the
    # device, and every request still finishes
    assert summary["finished"] == 12
    assert summary["devices"]["cxl1"]["state"] == "dead"
    assert summary["fault_tolerance"]["kills_detected"] == 1


def test_serve_mode_in_process(capsys):
    main(COMMON + ["--serve", "--requests", "6", "--trace", "gamma",
                   "--rate", "200", "--block-size", "8",
                   "--prefill-chunk", "8", "--trace-seed", "1"])
    payload = json.loads(capsys.readouterr().out)
    assert payload["mode"] == "serve" and payload["trace"] == "gamma"
    assert payload["port"] is None
    sc = payload["score"]
    assert sc["finished"] + sc["rejected"] == 6
    assert sc["lost_tokens"] == 0 and sc["dup_tokens"] == 0
    assert payload["backend"]["finished"] == sc["finished"]
    assert {"shed", "forced_preemptions"} <= payload["admission"].keys()


def test_serve_mode_over_socket(capsys):
    main(COMMON + ["--serve", "--requests", "4", "--rate", "500",
                   "--block-size", "8", "--prefill-chunk", "8",
                   "--port", "0"])
    payload = json.loads(capsys.readouterr().out)
    assert payload["port"] > 0                     # ephemeral bind
    sc = payload["score"]
    assert sc["finished"] + sc["rejected"] == 4
    assert sc["lost_tokens"] == 0 and sc["dup_tokens"] == 0
