"""Token pipelines.

``SyntheticLM`` generates a deterministic, learnable pseudo-corpus (a
periodic Markov-ish stream) — loss measurably decreases in a few hundred
steps, which the end-to-end example uses as its acceptance check.
``FileCorpus`` memory-maps a flat .bin of token ids (numpy uint16/uint32)
and serves fixed-length windows. Both shard by (dp_rank, dp_size) and are
restart-safe: state is just (epoch, cursor).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.models.config import ModelConfig


def shard_for_rank(global_batch: int, dp_rank: int, dp_size: int
                   ) -> tuple[int, int]:
    """Contiguous per-rank slice of the global batch."""
    per = global_batch // dp_size
    return dp_rank * per, per


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic synthetic LM stream: next token depends on the previous
    two via a fixed random mixing table (so it is learnable but not
    trivial). Seeded per (rank, step) — reproducible across restarts."""
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab, 4096)
        self._table = rng.integers(0, v, size=(v, 8), dtype=np.int32)
        self._v = v

    def batch_at(self, step: int, rank: int = 0) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 131 + rank)
        B, S = self.batch, self.seq_len
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, self._v, size=B)
        noise = rng.integers(0, 8, size=(B, S))
        for t in range(1, S):
            toks[:, t] = self._table[toks[:, t - 1], noise[:, t]]
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -1                      # no target for last position
        return {"tokens": toks, "labels": labels}

    def batches(self, start_step: int = 0, rank: int = 0):
        step = start_step
        while True:
            yield self.batch_at(step, rank)
            step += 1


@dataclasses.dataclass
class FileCorpus:
    """Flat token-id binary, windowed. dtype inferred from file suffix
    (.u16.bin / .u32.bin)."""
    path: str
    seq_len: int
    batch: int

    def __post_init__(self):
        dtype = np.uint16 if ".u16" in self.path else np.uint32
        self._data = np.memmap(self.path, dtype=dtype, mode="r")
        self._n_windows = (len(self._data) - 1) // self.seq_len

    def batch_at(self, step: int, rank: int = 0, dp_size: int = 1
                 ) -> dict[str, np.ndarray]:
        idx0 = (step * dp_size + rank) * self.batch
        rows = [(idx0 + i) % self._n_windows for i in range(self.batch)]
        toks = np.stack([
            np.asarray(self._data[r * self.seq_len:(r + 1) * self.seq_len],
                       np.int32) for r in rows])
        labels = np.stack([
            np.asarray(self._data[r * self.seq_len + 1:
                                  (r + 1) * self.seq_len + 1], np.int32)
            for r in rows])
        return {"tokens": toks, "labels": labels}


def make_batch_specs(cfg: ModelConfig, batch: int, seq: int,
                     dtype=np.int32) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one training batch (dry-run input)."""
    import jax.numpy as jnp
    specs = {}
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct((batch, seq, cfg.frontend_dim),
                                               jnp.bfloat16)
        specs["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    else:
        text = seq - (cfg.num_patches if cfg.family == "vlm" else 0)
        specs["tokens"] = jax.ShapeDtypeStruct((batch, text), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((batch, text), jnp.int32)
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (batch, cfg.num_patches, cfg.frontend_dim), jnp.bfloat16)
    return specs
