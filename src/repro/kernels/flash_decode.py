"""Split-KV decode attention kernel — PAMattention's Local_Attention stage
(paper Alg. 1 lines 9-13) as a TPU Pallas kernel.

One decode step: each grid cell owns one KV *split* (the paper's bank group)
for one (batch, kv-head) pair and emits the partial triple
``(O, m, l)`` for the ``rep`` grouped query heads that share the kv head.
The intra-device reduction (the paper's per-bank-group RU chain) happens in
``merge_decode_partials`` (see ops.py), which is also what the inter-tier /
inter-device reduction reuses — same algebra, different scope.

A per-token boolean ``mask`` carries PAM's tier/sparsity participation:
tokens outside the current tier or unselected by retrieval sparsity simply
contribute exact-zero weight, so one kernel serves dense decode, tiered
PAMattention, and sparse attention.

Layout: KV is (B, H_kv, S, d) — sequence-major within a head so a split is
a contiguous VMEM block (the bank-aligned mapping of §6.1).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat  # noqa: F401  (backfills pltpu.CompilerParams on 0.4)

NEG_INF = float(-1e30)
DEFAULT_BLOCK_S = 512


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, *,
                   scale: float, block_s: int, kv_len: int):
    isplit = pl.program_id(2)

    q = q_ref[0, 0].astype(jnp.float32)            # (rep, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (block_s, d)
    v = v_ref[0, 0].astype(jnp.float32)            # (block_s, d)
    msk = mask_ref[0]                              # (block_s,) bool/int8

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = isplit * block_s + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    live = (pos < kv_len) & (msk[None, :] != 0)
    s = jnp.where(live, s, NEG_INF)

    m = jnp.max(s, axis=-1)                        # (rep,)
    p = jnp.exp(s - m[:, None])
    p = jnp.where(live, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # Dead split (all masked): emit the merge identity (m=NEG_INF, l=o=0).
    o_ref[0, 0, :, 0, :] = o
    m_ref[0, 0, :, 0] = m
    l_ref[0, 0, :, 0] = l


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 mask: jax.Array | None = None, *,
                 kv_len: int | None = None,
                 kv_lens: jax.Array | None = None,
                 scale: float | None = None,
                 block_s: int = DEFAULT_BLOCK_S,
                 interpret: bool = False
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """PAMattention local stage. Returns stacked partials over splits.

    q: (B, H, d); k, v: (B, H_kv, S, d); mask: (B, S) participation.
    ``kv_len`` is a static whole-batch length bound; ``kv_lens`` an optional
    per-sequence (B,) dynamic length (ragged continuous batching) that is
    folded into the participation mask without re-tracing per length.
    Returns (o, m, l): o (B, H, nsplit, d) fp32 unnormalized, m/l
    (B, H, nsplit) fp32. Merge with ``repro.kernels.ops.merge_decode``.
    """
    B, H, d = q.shape
    _, H_kv, S, _ = k.shape
    rep = H // H_kv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    if kv_len is None:
        kv_len = S
    if mask is None:
        mask = jnp.ones((B, S), jnp.int8)
    else:
        mask = mask.astype(jnp.int8)
    if kv_lens is not None:
        live = jnp.arange(S)[None, :] < kv_lens[:, None]
        mask = mask * live.astype(jnp.int8)

    block_s = min(block_s, max(S, 8))
    pad = (block_s - S % block_s) % block_s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    S_p = S + pad
    nsplit = S_p // block_s

    qg = q.reshape(B, H_kv, rep, d)

    kernel = functools.partial(_decode_kernel, scale=scale, block_s=block_s,
                               kv_len=kv_len)

    o, m, l = pl.pallas_call(
        kernel,
        grid=(B, H_kv, nsplit),
        in_specs=[
            pl.BlockSpec((1, 1, rep, d), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, d), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_s, d), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, block_s), lambda b, h, s: (b, s)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rep, 1, d), lambda b, h, s: (b, h, 0, s, 0)),
            pl.BlockSpec((1, 1, rep, 1), lambda b, h, s: (b, h, 0, s)),
            pl.BlockSpec((1, 1, rep, 1), lambda b, h, s: (b, h, 0, s)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H_kv, rep, nsplit, d), jnp.float32),
            jax.ShapeDtypeStruct((B, H_kv, rep, nsplit), jnp.float32),
            jax.ShapeDtypeStruct((B, H_kv, rep, nsplit), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(qg, k, v, mask)

    return (o.reshape(B, H, nsplit, d), m.reshape(B, H, nsplit),
            l.reshape(B, H, nsplit))
