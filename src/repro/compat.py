"""JAX version compatibility shims, applied once on import.

The codebase targets the 0.5+ public APIs; this module backfills them on
0.4.x so every call site can use the modern names. Importing it anywhere
(`from repro import compat  # noqa: F401`) is sufficient — all patches are
idempotent and no-ops on recent jax.

Owned here (do NOT copy-paste shims into individual modules):
  jax.shard_map            (0.4: jax.experimental.shard_map, check_rep kwarg)
  jax.set_mesh             (0.4: legacy ``with Mesh(...)`` context)
  pltpu.CompilerParams     (0.4: pltpu.TPUCompilerParams)
  abstract_mesh()          (0.4: thread-resources physical mesh)
"""

from __future__ import annotations

import contextlib

import jax

if not hasattr(jax, "shard_map"):           # public alias is 0.5+
    from jax.experimental.shard_map import shard_map as _shard_map

    def _compat_shard_map(f, **kw):
        if "check_vma" in kw:               # renamed from check_rep in 0.5
            kw["check_rep"] = kw.pop("check_vma")
        return _shard_map(f, **kw)

    jax.shard_map = _compat_shard_map

if not hasattr(jax, "set_mesh"):            # public in 0.5+
    # 0.4.x: entering the Mesh sets the ambient mesh for shard_map /
    # sharding constraints without 0.5's strict explicit-sharding mode
    @contextlib.contextmanager
    def _set_mesh(mesh):
        with mesh:
            yield mesh

    jax.set_mesh = _set_mesh

try:
    from jax.experimental.pallas import tpu as _pltpu
    if not hasattr(_pltpu, "CompilerParams"):   # renamed in 0.5
        _pltpu.CompilerParams = _pltpu.TPUCompilerParams  # type: ignore[attr-defined]
except ImportError:                             # pragma: no cover
    pass


def abstract_mesh():
    """Ambient mesh across jax versions: ``jax.sharding.get_abstract_mesh``
    is 0.5+; fall back to the thread-resources physical mesh (0.4.x)."""
    try:
        return jax.sharding.get_abstract_mesh()
    except AttributeError:                      # pragma: no cover
        from jax._src import mesh as _mesh_lib
        return _mesh_lib.thread_resources.env.physical_mesh
