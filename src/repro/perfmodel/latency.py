"""Engine-step latency models: map real ServingEngine step stats onto the
paper's hardware timing model (the simulator glue)."""

from __future__ import annotations

import numpy as np


def make_latency_model(system, model_desc, context_scale: int = 1):
    """engine step stats -> simulated seconds.

    ``context_scale``: each engine token stands for this many hardware
    tokens (lets a CPU-sized engine run exercise the paper-scale memory
    hierarchy: tier reads, contexts and prefill tokens are scaled)."""
    def latency(stats) -> float:
        b = max(int(stats["active"]), 0)
        t = 0.0
        if stats["prefill_tokens"]:
            # prefill on NPU: compute-bound
            t += (2.0 * model_desc.params * stats["prefill_tokens"]
                  * context_scale / system.hw.npu_flops)
        if b == 0:
            return t
        tok_bytes = model_desc.kv_bytes_per_token()
        reads = stats.get("tier_reads")
        if reads is not None and np.sum(reads) > 0:
            # REAL per-tier token reads from the PAM manager
            hw = system.hw
            tiers = (hw.hbm, hw.ddr, hw.ssd)
            t_attn = max(float(r) * context_scale * tok_bytes
                         / tier.effective_bw
                         for r, tier in zip(reads, tiers))
            t_attn *= (1 + system.reduction_overhead)
            t += t_attn
            t += (stats.get("moved_tokens", 0) * context_scale * tok_bytes
                  / hw.hbm.link_bw)
        else:
            ctx = (int(np.mean(stats["batch_lengths"])) or 1) * context_scale
            t += system.attention_time(model_desc, b, ctx)
        t += system.fc_time(model_desc, b)
        return t
    return latency
