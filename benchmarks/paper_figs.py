"""Benchmarks reproducing the paper's tables/figures from the analytical
system model (§7 methodology). Each function returns rows of
(name, us_per_call, derived) used by benchmarks.run."""

from __future__ import annotations

import math

from repro.perfmodel.model import (LLAMA3_70B, OPT_175B, QWEN25_32B,
                                   SystemKind, make_system,
                                   simulate_offline, simulate_online)

SYSTEMS = [SystemKind.VLLM_OFFLOAD, SystemKind.ATTACC, SystemKind.LPIM,
           SystemKind.LSPIM, SystemKind.PAM]

# dataset descriptors (paper §7.1): average context at decode time
DATASETS = {"sharegpt": 534, "wildchat": 738, "humaneval": 400}


def fig9_online_slo() -> list[tuple]:
    """Fig. 9: normalized online throughput under SLOs (100/150/200 ms)."""
    rows = []
    for model in (QWEN25_32B, LLAMA3_70B, OPT_175B):
        for ds, ctx in DATASETS.items():
            for slo_ms in (100, 150, 200):
                base = None
                for kind in SYSTEMS:
                    sys_m = make_system(kind)
                    r = simulate_online(sys_m, model, avg_context=ctx,
                                        slo_s=slo_ms / 1e3)
                    if kind == SystemKind.VLLM_OFFLOAD:
                        base = max(r["throughput_tok_s"], 1e-9)
                    norm = r["throughput_tok_s"] / base
                    step_us = (1e6 * r["max_batch"]
                               / max(r["throughput_tok_s"], 1e-9)
                               if r["max_batch"] else float("inf"))
                    rows.append((
                        f"fig9/{model.name}/{ds}/slo{slo_ms}ms/{kind.value}",
                        step_us,
                        f"norm_tput={norm:.2f}x batch={r['max_batch']}"))
    return rows


def fig10_offline() -> list[tuple]:
    """Fig. 10: offline throughput at fixed batch. Context 8000 — the
    upper end of the paper's summarization workloads (1500~8000), the
    regime where the KV set spills past HBM(+DDR)."""
    rows = []
    cases = [(LLAMA3_70B, b) for b in (256, 512, 1024)] + \
            [(OPT_175B, b) for b in (16, 32, 64)]
    for model, batch in cases:
        base = None
        for kind in SYSTEMS:
            sys_m = make_system(kind)
            r = simulate_offline(sys_m, model, batch=batch, context=8000)
            if kind == SystemKind.VLLM_OFFLOAD:
                base = max(r["throughput_tok_s"], 1e-9)
            norm = r["throughput_tok_s"] / base
            derived = ("OOM" if r["oom"]
                       else f"norm_tput={norm:.2f}x")
            us = (1e6 * batch / r["throughput_tok_s"]
                  if r["throughput_tok_s"] else float("inf"))
            rows.append((f"fig10/{model.name}/b{batch}/{kind.value}",
                         us, derived))
    return rows


def fig11_energy() -> list[tuple]:
    """Fig. 11: energy per output token (online + offline settings)."""
    rows = []
    cases = [(LLAMA3_70B, 8192, 738, "online"),
             (OPT_175B, 512, 738, "online"),
             (LLAMA3_70B, 1024, 4096, "offline"),
             (OPT_175B, 64, 4096, "offline")]
    for model, batch, ctx, tag in cases:
        base = None
        for kind in SYSTEMS:
            sys_m = make_system(kind)
            tok = model.kv_bytes_per_token()
            if batch * ctx * tok > sys_m.kv_capacity(model) or not math.isfinite(
                    sys_m.decode_step_time(model, batch, ctx)):
                rows.append((f"fig11/{tag}/{model.name}/{kind.value}",
                             float("inf"), "OOM"))
                continue
            e = sys_m.decode_step_energy(model, batch, ctx) / batch
            if kind == SystemKind.VLLM_OFFLOAD:
                base = e
            rows.append((f"fig11/{tag}/{model.name}/{kind.value}",
                         e * 1e6,
                         f"J_per_tok={e:.4f} vs_vllm={e/base:.3f}"))
    return rows


def fig12_ablation() -> list[tuple]:
    """Fig. 12: PAMattention / KV-mapping / KV-scheduling ablations,
    normalized to LS-PIM (paper protocol), attention time only."""
    rows = []
    # batch sizes chosen to bracket the SSD-pressure cliff (paper: 18.7x
    # small / 48.6x large over LS-PIM; ratios are cliff-sensitive — see
    # EXPERIMENTS.md)
    for model, batch, ctx, tag in ((LLAMA3_70B, 1024, 2048, "small-batch"),
                                   (LLAMA3_70B, 3072, 2048, "large-batch")):
        ls = make_system(SystemKind.LSPIM)
        t_ls = ls.attention_time(model, batch, ctx)
        variants = {
            "pam-full": make_system(SystemKind.PAM),
            # fixed-tiling attention, centralized (non-overlapped,
            # off-die) reduction: the §5.2 RU claims reversed — reduction
            # is no longer <2% but ~= the local attention time itself
            "w/o-pamattention": make_system(
                SystemKind.PAM, reduction_overhead=1.0),
            "w/o-kv-mapping": make_system(SystemKind.PAM,
                                          mapping_imbalance=2.0),
            # static placement: hit rate falls to capacity share
            "w/o-kv-scheduling": make_system(SystemKind.PAM,
                                             pam_hit_rate=0.30),
        }
        for name, sys_m in variants.items():
            t = sys_m.attention_time(model, batch, ctx)
            rows.append((f"fig12/{tag}/{name}", t * 1e6,
                         f"speedup_vs_lspim={t_ls/t:.2f}x "
                         f"pam_vs_variant={t/variants_t0:.2f}x"
                         if name != "pam-full" else
                         f"speedup_vs_lspim={t_ls/t:.2f}x"))
            if name == "pam-full":
                variants_t0 = t
    return rows


def fig13_scalability() -> list[tuple]:
    """Fig. 13: PAM vs L-PIM throughput across (TP, PP) scale-outs."""
    rows = []
    model, batch, ctx = LLAMA3_70B, 1024, 4096
    for (tp, pp) in ((1, 1), (2, 1), (2, 2), (4, 1), (4, 2), (8, 1)):
        n = tp * pp
        for kind in (SystemKind.LPIM, SystemKind.PAM):
            sys_m = make_system(kind)
            fc = sys_m.fc_time(model, batch) / n
            # TP all-reduce: 2 x activations per layer over nvlink
            ar = (2 * (tp - 1) / max(tp, 1) * batch * 8192 * 2
                  * model.n_layers / sys_m.hw.nvlink_bw)
            attn = sys_m.attention_time(model, batch // max(n, 1), ctx)
            bubble = (pp - 1) / (8 + pp - 1)       # 8 microbatches
            t = (fc + ar + attn) / (1 - bubble)
            if not math.isfinite(t):
                rows.append((f"fig13/tp{tp}_pp{pp}/{kind.value}",
                             float("inf"), "OOM"))
                continue
            rows.append((f"fig13/tp{tp}_pp{pp}/{kind.value}", t * 1e6,
                         f"tput={batch/t:.0f}tok/s n={n}"))
    return rows


def headline_claims() -> list[tuple]:
    """The paper's two headline numbers, recomputed from the model:
    12.88x (conversation) and 26.41x (long-context) vs vLLM-offloading."""
    rows = []
    # conversation: average over models x datasets x SLOs
    ratios = []
    for model in (QWEN25_32B, LLAMA3_70B, OPT_175B):
        for ctx in DATASETS.values():
            for slo_ms in (100, 150, 200):
                v = simulate_online(make_system(SystemKind.VLLM_OFFLOAD),
                                    model, avg_context=ctx,
                                    slo_s=slo_ms / 1e3)
                p = simulate_online(make_system(SystemKind.PAM), model,
                                    avg_context=ctx, slo_s=slo_ms / 1e3)
                if v["throughput_tok_s"] > 0:
                    ratios.append(p["throughput_tok_s"]
                                  / v["throughput_tok_s"])
    conv = sum(ratios) / len(ratios)
    rows.append(("headline/conversation_speedup", 0.0,
                 f"PAM_vs_vLLM={conv:.2f}x (paper: 12.88x)"))
    ratios = []
    for model, batches in ((LLAMA3_70B, (256, 512, 1024)),
                           (OPT_175B, (16, 32, 64))):
        for b in batches:
            v = simulate_offline(make_system(SystemKind.VLLM_OFFLOAD),
                                 model, batch=b, context=4096)
            p = simulate_offline(make_system(SystemKind.PAM), model,
                                 batch=b, context=4096)
            if v["throughput_tok_s"] > 0:
                ratios.append(p["throughput_tok_s"]
                              / v["throughput_tok_s"])
    lc = sum(ratios) / len(ratios)
    rows.append(("headline/long_context_speedup", 0.0,
                 f"PAM_vs_vLLM={lc:.2f}x (paper: 26.41x)"))
    return rows
