"""Tests for the end-to-end PAMattention step (Alg. 1 orchestration)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # hypothesis, or skip-stub fallback

from repro.core import online_softmax as osm
from repro.core.pam_attention import PAMAttentionConfig, pam_attention_step

jax.config.update("jax_platform_name", "cpu")


def _setup(seed, S, H, H_kv, d):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(key, 0), (H, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (S, H_kv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (S, H_kv, d))
    tier = jax.random.randint(jax.random.fold_in(key, 3), (S,), 0, 3)
    imp = jax.random.uniform(jax.random.fold_in(key, 4), (S,))
    return q, k, v, tier.astype(jnp.int32), imp


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       S=st.integers(8, 64),
       cfgs=st.sampled_from([(4, 4, 8), (8, 2, 16), (4, 1, 8)]))
def test_dense_pam_equals_reference(seed, S, cfgs):
    """With sparsity off, tier-partitioned PAMattention == full attention,
    regardless of how tokens are scattered across tiers."""
    H, H_kv, d = cfgs
    q, k, v, tier, imp = _setup(seed, S, H, H_kv, d)
    valid = jnp.ones((S,), bool)
    cfg = PAMAttentionConfig(use_sparsity=False)
    out = pam_attention_step(q, k, v, tier, valid, imp, cfg)

    rep = H // H_kv
    kh = jnp.moveaxis(jnp.repeat(k, rep, axis=1), 0, 1)  # (H, S, d)
    vh = jnp.moveaxis(jnp.repeat(v, rep, axis=1), 0, 1)
    ref = osm.reference_attention(q, kh, vh)
    np.testing.assert_allclose(np.asarray(out.out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_sparse_pam_equals_topk_subset():
    """With sparsity on, the result equals full attention over exactly the
    top-(S/c) most important tokens."""
    S, H, H_kv, d, c = 64, 4, 2, 8, 8
    q, k, v, tier, imp = _setup(11, S, H, H_kv, d)
    valid = jnp.ones((S,), bool)
    cfg = PAMAttentionConfig(use_sparsity=True, compression=c)
    out = pam_attention_step(q, k, v, tier, valid, imp, cfg)

    kkeep = S // c
    sel = np.argsort(-np.asarray(imp))[:kkeep]
    rep = H // H_kv
    kh = jnp.moveaxis(jnp.repeat(k, rep, axis=1), 0, 1)
    vh = jnp.moveaxis(jnp.repeat(v, rep, axis=1), 0, 1)
    ref = osm.reference_attention(q, kh[:, sel], vh[:, sel])
    np.testing.assert_allclose(np.asarray(out.out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_step_scores_sum_to_heads_mean_mass():
    """Step scores are a probability mass scaled by token count: the scores
    of participating tokens sum to ~S (count scaling of head-mean mass 1)."""
    S, H, H_kv, d = 32, 4, 4, 8
    q, k, v, tier, imp = _setup(5, S, H, H_kv, d)
    valid = jnp.ones((S,), bool)
    out = pam_attention_step(q, k, v, tier, valid, imp,
                             PAMAttentionConfig(use_sparsity=False))
    total = float(jnp.sum(out.step_scores))
    np.testing.assert_allclose(total, S, rtol=1e-4)


def test_importance_updates_toward_attended_tokens():
    """Tokens receiving attention mass gain importance (context locality
    feedback loop: eq. (7))."""
    S, H, H_kv, d = 32, 2, 2, 8
    q, k, v, tier, _ = _setup(9, S, H, H_kv, d)
    # make token 17's key strongly aligned with q so it dominates attention
    k = k.at[17].set(jnp.broadcast_to(q[0] * 5.0, (H_kv, d)))
    imp = jnp.zeros((S,))
    valid = jnp.ones((S,), bool)
    out = pam_attention_step(q, k, v, tier, valid, imp,
                             PAMAttentionConfig(use_sparsity=False))
    assert int(jnp.argmax(out.new_importance)) == 17


def test_invalid_tokens_excluded():
    S, H, H_kv, d = 24, 2, 2, 8
    q, k, v, tier, imp = _setup(3, S, H, H_kv, d)
    valid = jnp.arange(S) < 10
    out = pam_attention_step(q, k, v, tier, valid, imp,
                             PAMAttentionConfig(use_sparsity=False))
    kh = jnp.moveaxis(k[:10], 0, 1)
    vh = jnp.moveaxis(v[:10], 0, 1)
    ref = osm.reference_attention(q, kh, vh)
    np.testing.assert_allclose(np.asarray(out.out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert float(jnp.sum(jnp.where(~valid, out.step_scores, 0.0))) == 0.0
