"""Assert the engine-bench trajectory point is sane — perf regressions
fail loudly instead of silently landing.

    python scripts/check_bench.py BENCH.json [tok_s_floor]

Checks (engine section of ``benchmarks.run``):
  * one fused dispatch per decode step (the PR 1 invariant)
  * decode tokens/s above a catastrophic-regression floor
  * paged sparse read: pages touched < dense-window pages (PR 2)
  * hot-tier bytes/slot constant across max_len in {1k, 4k, 16k}
    (PR 5 ring invariant), and the ring within 10% of the full-window
    paged engine's tokens/s

Checks (chaos section, ``BENCH_pr6.json``):
  * zero tokens lost across every fault scenario (twin-exact recovery)
  * 1-kill goodput >= 0.8x the fault-free run of the same trace
"""

import json
import sys


def check_chaos(d: dict) -> None:
    lost = d["chaos_tokens_lost"]
    ratio = d["chaos_kill_goodput_ratio"]
    assert lost == 0, (
        f"{lost} tokens lost under injected faults — recovery is no "
        f"longer twin-exact")
    assert ratio >= 0.8, (
        f"1-kill goodput ratio {ratio:.3f} below the 0.8 floor")
    print(f"chaos bench OK: 0 tokens lost, 1-kill goodput "
          f"{ratio:.3f}x fault-free (floor 0.8), recovery mean "
          f"{d['chaos_kill_recovery_latency_mean_s'] * 1e3:.1f} ms sim")


def main(path: str, floor: float = 100.0) -> None:
    d = json.load(open(path))
    if "chaos_kill_goodput_ratio" in d:
        check_chaos(d)
        if "dispatches_per_step" not in d:
            return                       # chaos-only bench file
    assert d["dispatches_per_step"] == 1.0, d["dispatches_per_step"]
    assert d["decode_tok_s"] > floor, (
        f"decode tok/s {d['decode_tok_s']:.0f} below floor {floor:.0f}")
    assert d["paged_blocks_touched_per_step"] < \
        d["paged_blocks_window_per_step"]
    assert d["hot_bytes_constant_across_smax"] is True, \
        d.get("hot_window_scaling")
    ring, paged = d["ring_decode_tok_s"], d["paged_decode_tok_s"]
    # catastrophic-only guard: single-run wall-clock on shared runners
    # jitters well past 10%, so CI asserts the ring is in the same class
    # as the full-window paged engine; the tighter 10% comparison is the
    # BENCH_pr5.json acceptance check, taken on a quiet machine
    assert ring > 0.5 * paged, (
        f"ring decode {ring:.0f} tok/s collapsed vs the full-window "
        f"paged engine's {paged:.0f}")
    scaling = d["hot_window_scaling"]["points"]
    print(f"bench OK: {d['decode_tok_s']:.0f} tok/s (floor {floor:.0f}), "
          f"{d['dispatches_per_step']:.2f} dispatches/step, paged pages/"
          f"step {d['paged_blocks_touched_per_step']:.1f}"
          f"/{d['paged_blocks_window_per_step']:.1f}, ring "
          f"{ring:.0f} tok/s at {d['hot_bytes_per_slot']} hot bytes/slot "
          f"constant over Smax {sorted(scaling, key=int)}")


if __name__ == "__main__":
    main(sys.argv[1],
         float(sys.argv[2]) if len(sys.argv) > 2 else 100.0)
