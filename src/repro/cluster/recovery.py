"""Device-loss recovery and graceful degradation for the serving
cluster (the fault-tolerance layer ``ClusterRouter`` drives).

Detection reuses the training-side machinery (``distributed.elastic``)
adapted to serving sim-clocks:

- ``HeartbeatLedger`` runs on device SIM-CLOCK SECONDS: every alive
  device beats with its own clock each router tick; a killed device
  goes silent and is declared dead once the fleet frontier moves
  ``heartbeat_timeout_s`` past its last beat. When the hung device held
  the only in-flight work the router charges the timeout as explicit
  wait time — detection consumes simulated time, as on a real fleet.
- ``StragglerMonitor`` sees step times NORMALIZED by pricing each
  step's own stats through the device's unstalled latency model: a
  legitimately 4x-slower CXL device records ~1.0, a fully loaded fast
  device records ~1.0, a stalled device records exactly its slowdown
  factor. Heterogeneity and load are never mistaken for failure, and
  the monitor's leave-one-out median makes detection work even on a
  2-survivor fleet.

Recovery has two paths, both ending in a token stream BIT-IDENTICAL to
a failure-free twin (per-request sampling keys make this hold at any
temperature):

- graceful drain (device alive but degraded): running requests export
  as checksummed ``KVSnapshot``s and transfer to survivors with bounded
  retry/backoff (``transfer``): dropped transfers time out, corrupted
  ones fail the checksum — both re-send from the sender's pristine
  copy. Terminal failure rolls back to the source.
- replay (device dead, KV lost): the router re-submits the original
  request from scratch on a survivor; because per-slot computation and
  per-request sampling keys are batch/phase-independent, the stream
  regenerates exactly, and the router's event dedup suppresses the
  already-streamed prefix (verifying it token-by-token on the way).

Degradation: admission overload never raises — a starving queue head
triggers preemption-by-demotion (suspend the lowest-importance running
request into a host-held snapshot, resume after a cooldown when
capacity frees), and unserviceable submissions become rejection
``TokenEvent``s.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.cluster.faults import FaultInjector
from repro.cluster.migration import KVSnapshot
from repro.distributed.elastic import HeartbeatLedger, StragglerMonitor
from repro.obs import metrics as obs_metrics
from repro.serving.paged_kv import OutOfBlocks


class _MirroredStats(dict):
    """The recovery ``stats`` dict, with every increment mirrored into
    the ``pam_cluster_recovery_events_total{event=...}`` counter of the
    registry installed at construction. Increments happen both here and
    in the router (which owns placement decisions), so mirroring at the
    dict write is the one choke point that catches them all."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._counter = obs_metrics.get_registry().counter(
            "pam_cluster_recovery_events_total",
            "recovery-path events (detections, drains, replays, "
            "retries, suspensions), by kind", ("event",))

    def __setitem__(self, key: str, value: float) -> None:
        delta = value - self.get(key, 0)
        if delta > 0:
            self._counter.labels(event=key).inc(delta)
        super().__setitem__(key, value)


@dataclasses.dataclass(frozen=True)
class RecoveryConfig:
    heartbeat_timeout_s: float = 0.25    # sim-silence before presumed dead
    straggler_threshold: float = 1.75    # x peer-median slowdown
    straggler_patience: int = 3          # consecutive flagged observations
    transfer_retries: int = 3            # re-sends after a bad transfer
    transfer_backoff_s: float = 1e-3     # first retry wait; doubles
    link_bw: float = 64e9                # snapshot transfer bytes/s
    preempt_after_ticks: int = 48        # queue-head starvation fuse
    min_preempt_remaining: int = 2       # don't suspend nearly-done work
    resume_cooldown_ticks: int = 8       # suspended -> resume attempt


class RecoveryManager:
    """Watchdog state + transfer/suspension machinery for the router.

    The router calls ``observe_step`` after stepping a device,
    ``heartbeat``/``advance`` every tick, and asks ``dead_indices`` /
    ``straggler_indices`` for verdicts; recovery actions themselves
    (drain, replay, preempt) live in the router, which owns placement.
    """

    def __init__(self, cfg: RecoveryConfig = RecoveryConfig(),
                 injector: Optional[FaultInjector] = None):
        self.cfg = cfg
        self.injector = injector
        self.monitor = StragglerMonitor(
            threshold=cfg.straggler_threshold,
            patience=cfg.straggler_patience)
        self.ledger = HeartbeatLedger(dead_after=cfg.heartbeat_timeout_s)
        # host-held suspended snapshots: (KVSnapshot, suspend tick)
        self.suspended: list[tuple[KVSnapshot, int]] = []
        self.stats: dict[str, float] = _MirroredStats({
            "kills_detected": 0, "drains": 0, "replays": 0,
            "preemptions": 0, "resumes": 0, "transfer_retries": 0,
            "transfers_dropped": 0, "corruptions_detected": 0,
            "transfer_failures": 0, "abandoned": 0,
        })
        self.recovery_latencies: list[float] = []

    # ------------------------------------------------------------ detection
    def observe_step(self, idx: int, dev, step_time: float) -> None:
        """Record one device step for straggler detection, normalized so
        a healthy device reads ~1.0 regardless of class or load.

        Preferred normalizer: price the step's OWN stats through the
        device's unstalled latency model — then rel is exactly the
        slowdown factor, and a fully loaded fast device never reads as
        slow just because it carries more work than its idle peers.
        Falls back to the load-blind class prior when the engine has no
        decode stats yet."""
        if step_time <= 0.0:
            return
        base = getattr(dev, "base_latency", None)
        stats = getattr(dev.engine, "last_step_stats", None)
        if base is not None and stats is not None:
            expected = float(base(stats))
            if expected <= 0.0:
                return
            rel = step_time / expected
        else:
            prior = getattr(dev, "step_prior", 0.0)
            if prior <= 0.0:
                return              # wall-clock runs: no prior, no watch
            rel = step_time / prior
        self.monitor.record(idx, rel)
        self.monitor.observe_step()

    def heartbeat(self, idx: int, clock: float) -> None:
        self.ledger.beat(idx, clock)

    def advance(self, clock: float) -> None:
        self.ledger.advance(clock)

    def dead_indices(self) -> list[int]:
        return self.ledger.dead_hosts()

    def straggler_indices(self) -> list[int]:
        return self.monitor.stragglers()

    def note_recovery(self, latency_s: float) -> None:
        self.recovery_latencies.append(max(latency_s, 0.0))

    # ------------------------------------------------------------ transfers
    def transfer(self, snap: KVSnapshot, dst_engine,
                 charge: Callable[[float], None]) -> bool:
        """Deliver ``snap`` to ``dst_engine`` over the faulty link.

        Each attempt puts a fresh wire copy of the sender's pristine
        snapshot on the link; the injector may drop it (receiver times
        out) or corrupt it (checksum mismatch at commit). Failed
        attempts charge exponential backoff to the receiver's clock via
        ``charge`` and re-send, up to ``transfer_retries`` times.
        Returns True once committed; False on terminal failure (the
        caller rolls back or suspends — ``snap`` itself is untouched).
        Capacity errors (no slot / ``OutOfBlocks``) are not retried:
        the link is fine, the destination is full.
        """
        charge(snap.kv_bytes / self.cfg.link_bw)
        delay = self.cfg.transfer_backoff_s
        for attempt in range(self.cfg.transfer_retries + 1):
            if attempt:
                self.stats["transfer_retries"] += 1
                charge(delay + snap.kv_bytes / self.cfg.link_bw)
                delay *= 2
            verdict = (self.injector.transfer_verdict()
                       if self.injector is not None else "ok")
            if verdict == "drop":
                self.stats["transfers_dropped"] += 1
                continue
            wire = snap.clone()
            if verdict == "corrupt":
                self.injector.corrupt(wire)
            if not wire.verify():
                self.stats["corruptions_detected"] += 1
                continue
            try:
                wire.commit(dst_engine)
                return True
            except (OutOfBlocks, ValueError):
                break
        self.stats["transfer_failures"] += 1
        return False

    # ----------------------------------------------------------- suspension
    def suspend(self, engine, rid: int, tick: int) -> KVSnapshot:
        """Preemption-by-demotion: detach ``rid`` into a host-held
        checksummed snapshot and queue it for a cooled-down resume."""
        snap = KVSnapshot.export(engine, rid)
        self.suspended.append((snap, tick))
        self.stats["preemptions"] += 1
        return snap

    def resumable(self, tick: int) -> list[KVSnapshot]:
        """Suspended snapshots whose cooldown has elapsed (in suspend
        order; the router pops the ones it successfully resumes)."""
        return [s for s, t in self.suspended
                if tick - t >= self.cfg.resume_cooldown_ticks]

    def drop_suspended(self, snap: KVSnapshot) -> None:
        self.suspended = [(s, t) for s, t in self.suspended
                          if s is not snap]
