"""Multi-device cluster router (paper §4.3): one request stream served
across N heterogeneous ``ServingEngine`` instances.

The router owns a SHARED arrival queue and binds requests to devices as
late as possible: a queued request is dispatched only when some device
can admit it *right now*, to the device with the lowest admission cost

    cost = (queue + running + 1) * modeled_step_latency
           + occupancy_weight * pool_occupancy

— modeled load plus pool pressure, the paper's inter-device cost signal.
Each device keeps its own simulated clock (its perfmodel latency model
charges every step); the router advances the fleet EVENT-DRIVEN, always
stepping the busy device whose clock is furthest behind, so fast devices
take more steps per simulated second exactly as real hardware would.
Completed tokens stream out through ``drain_events`` as they are
emitted, and an attached ``KVBalancer`` periodically migrates running
requests off overloaded devices (``repro.cluster.migration``).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Iterable, Optional

import numpy as np

from repro.cluster.balancer import BalancerConfig, KVBalancer
from repro.perfmodel.devices import (DeviceClass, make_device_latency_model,
                                     step_time_prior)
from repro.serving.engine import DONE, Request, ServingEngine, ServingConfig


@dataclasses.dataclass
class TokenEvent:
    """One streamed completion token (the router's streaming API)."""
    time: float                  # device sim-clock at emission
    request_id: int
    token: int
    index: int                   # position in the request's output
    device: str
    done: bool                   # True on the request's final token


@dataclasses.dataclass
class ClusterDevice:
    """One engine + its device class inside the router."""
    name: str
    cls: DeviceClass
    engine: ServingEngine
    step_prior: float = 0.0      # a-priori step latency (cost signal seed)
    prefill_tok_prior: float = 0.0   # modeled seconds per prefill token
    tokens_emitted: int = 0
    steps: int = 0

    def has_work(self) -> bool:
        eng = self.engine
        return bool(eng.waiting) or any(s is not None for s in eng.slots)


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    occupancy_weight: float = 1e-3   # pool-pressure term in the cost
    max_ticks: int = 200_000


class ClusterRouter:
    """Route one request stream over heterogeneous serving engines."""

    def __init__(self, devices: list[ClusterDevice],
                 balancer: Optional[KVBalancer] = None,
                 rcfg: RouterConfig = RouterConfig()):
        if not devices:
            raise ValueError("cluster needs at least one device")
        names = [d.name for d in devices]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate device names: {names}")
        self.devices = devices
        self.balancer = balancer
        self.rcfg = rcfg
        self.arrivals: collections.deque[Request] = collections.deque()
        self.queue: collections.deque[Request] = collections.deque()
        self.ticks = 0
        self.finished: dict[int, Any] = {}       # rid -> RequestState
        self._events: list[TokenEvent] = []
        self._seen_tokens: dict[int, int] = {}   # rid -> emitted count
        self._shape: dict[int, tuple[int, int]] = {}  # rid -> (prompt, gen)

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        """Add a request to the shared stream (``req.arrival`` is its
        simulated arrival time; submissions must be time-ordered)."""
        window = len(req.prompt) + req.max_new_tokens
        if not any(d.engine.serviceable(window) for d in self.devices):
            raise ValueError(f"request {req.id}: window {window} fits no "
                             f"device in the cluster")
        if self.arrivals and req.arrival < self.arrivals[-1].arrival:
            raise ValueError("submit arrivals in nondecreasing time order")
        self.arrivals.append(req)
        self._shape[req.id] = (len(req.prompt), req.max_new_tokens)

    def submit_to(self, req: Request, device_name: str) -> None:
        """Pin a request to one device, bypassing cost-based dispatch
        (tests/demos use this to pre-load a device; real traffic should
        go through ``submit``). Registers the router bookkeeping so
        completions, events and migrations track the request normally."""
        dev = self._by_name(device_name)
        window = len(req.prompt) + req.max_new_tokens
        if not dev.engine.serviceable(window):
            raise ValueError(f"request {req.id}: window {window} does not "
                             f"fit device {device_name}")
        self._shape[req.id] = (len(req.prompt), req.max_new_tokens)
        dev.engine.submit(req)

    # ------------------------------------------------------------ signals
    def now(self) -> float:
        """Cluster frontier: the slowest busy device's clock (all-idle:
        the max clock — nothing is in flight before it)."""
        busy = [d.engine.clock for d in self.devices if d.has_work()]
        if busy:
            return min(busy)
        return max(d.engine.clock for d in self.devices)

    def admission_cost(self, dev: ClusterDevice, prompt_len: int,
                       gen_len: int, pending: int = 0) -> float:
        """Expected completion cost of placing one request on ``dev``:
        its full service time there (modeled prefill of the prompt +
        ``gen_len`` modeled decode steps), multiplied by the admission
        waves already ahead of it (device queue, ``pending`` shared-queue
        requests deferred toward it this round, and half the mid-flight
        running batch), plus pool pressure. Pricing the *whole* service
        — prefill included — is what stops bursts from sinking onto a
        slow device whose queue-free slots look temptingly open."""
        sig = dev.engine.load_signal()
        step = sig["last_step_time"] or dev.step_prior
        service = prompt_len * dev.prefill_tok_prior + gen_len * step
        ahead = (sig["queue_depth"] + pending + 0.5 * sig["running"])
        waves = -(-int(ahead + 1) // max(dev.engine.scfg.max_batch, 1))
        return (waves * service
                + self.rcfg.occupancy_weight * sig["pool_occupancy"])

    # ----------------------------------------------------------- dispatch
    def _release_arrivals(self) -> None:
        horizon = self.now()
        while self.arrivals and self.arrivals[0].arrival <= horizon:
            self.queue.append(self.arrivals.popleft())

    def _dispatch(self) -> None:
        """Cost-based late binding. Each queued request is priced on
        every serviceable device — including busy ones it would have to
        WAIT for — and bound to the cheapest. If the winner cannot admit
        it right now the request stays in the shared queue (deferred:
        queueing for a fast device beats sinking a burst onto a slow
        one), with a virtual-depth mark so the rest of the round prices
        that device as one deeper."""
        still: collections.deque[Request] = collections.deque()
        virtual = {d.name: 0 for d in self.devices}
        while self.queue:
            req = self.queue.popleft()
            prompt_len, gen_len = self._shape[req.id]
            window = prompt_len + gen_len
            cands = [d for d in self.devices
                     if d.engine.serviceable(window)]
            best = min(cands, key=lambda d: self.admission_cost(
                d, prompt_len, gen_len, pending=virtual[d.name]))
            # can_accept nets out the device's own waiting queue, so one
            # dispatch round cannot over-assign a device
            if best.engine.can_accept(window):
                # an idle device may have an old clock; it cannot serve
                # a request before the request exists
                best.engine.clock = max(best.engine.clock, req.arrival)
                best.engine.submit(req)
            else:
                virtual[best.name] += 1
                still.append(req)
        self.queue = still

    # ------------------------------------------------------------ stepping
    def _collect(self, dev: ClusterDevice) -> None:
        """Diff the device's request states into stream events and pick
        up completions."""
        eng = dev.engine
        done_rids = []
        for rid, rs in eng.requests.items():
            seen = self._seen_tokens.get(rid, 0)
            for i in range(seen, len(rs.outputs)):
                t = (rs.token_times[i] if i < len(rs.token_times)
                     else eng.clock)
                self._events.append(TokenEvent(
                    time=t, request_id=rid, token=rs.outputs[i], index=i,
                    device=dev.name,
                    done=(rs.status == DONE and i == len(rs.outputs) - 1)))
                dev.tokens_emitted += 1
            self._seen_tokens[rid] = len(rs.outputs)
            if rs.status == DONE:
                done_rids.append(rid)
        for rid in done_rids:
            self.finished[rid] = eng.requests.pop(rid)

    def tick(self) -> bool:
        """One router iteration. Returns False when the stream is fully
        served (no arrivals, no queue, no running work)."""
        # idle fleet + future arrivals: jump the fleet to the next event
        if (self.arrivals and not self.queue
                and not any(d.has_work() for d in self.devices)):
            t = self.arrivals[0].arrival
            for d in self.devices:
                d.engine.clock = max(d.engine.clock, t)
        self._release_arrivals()
        self._dispatch()
        busy = [d for d in self.devices if d.has_work()]
        if not busy:
            return bool(self.arrivals or self.queue)
        # event-driven: advance the furthest-behind busy device
        dev = min(busy, key=lambda d: d.engine.clock)
        dev.engine.step()
        dev.steps += 1
        self._collect(dev)
        self.ticks += 1
        if (self.balancer is not None
                and self.ticks % self.balancer.cfg.rebalance_interval == 0):
            # migrated requests carry their outputs with them; pending
            # tokens surface at the destination's next _collect
            self.balancer.rebalance(self.devices, self.ticks)
        return True

    def run(self, max_ticks: Optional[int] = None) -> dict[str, Any]:
        limit = max_ticks if max_ticks is not None else self.rcfg.max_ticks
        for _ in range(limit):
            if not self.tick():
                break
        else:
            raise RuntimeError(f"cluster did not drain in {limit} ticks")
        return self.summary()

    def _by_name(self, name: str) -> ClusterDevice:
        return next(d for d in self.devices if d.name == name)

    # ----------------------------------------------------------- streaming
    def drain_events(self) -> list[TokenEvent]:
        """Streaming completion API: token events emitted since the last
        drain, in emission order."""
        out, self._events = self._events, []
        return out

    # ------------------------------------------------------------- metrics
    def summary(self) -> dict[str, Any]:
        makespan = max(d.engine.clock for d in self.devices)
        total_tokens = sum(len(rs.outputs) for rs in self.finished.values())
        per_device = {}
        for d in self.devices:
            per_device[d.name] = {
                "class": d.cls.name,
                "steps": d.steps,
                "tokens_emitted": d.tokens_emitted,
                "busy_time_s": d.engine.busy_time,
                "utilization": (d.engine.busy_time / makespan
                                if makespan > 0 else 0.0),
                "decode_dispatches": d.engine.decode_dispatches,
                "decode_device_steps": d.engine.decode_device_steps,
                "migrations_in": d.engine.migrations_in,
                "migrations_out": d.engine.migrations_out,
            }
        out = {
            "finished": len(self.finished),
            "total_tokens": total_tokens,
            "makespan_s": makespan,
            "throughput_tok_s": (total_tokens / makespan
                                 if makespan > 0 else 0.0),
            "migrations": (self.balancer.migrations
                           if self.balancer is not None else 0),
            "migrated_bytes": (self.balancer.moved_bytes
                               if self.balancer is not None else 0),
            "ticks": self.ticks,
            "devices": per_device,
        }
        return out

    def slo_attainment(self, slo_s: float) -> float:
        """Fraction of decode-token gaps within the SLO, fleet-wide
        (migration seams clamp at 0 — clocks resync on transfer)."""
        gaps: list[float] = []
        for rs in self.finished.values():
            if len(rs.token_times) > 1:
                gaps.extend(np.maximum(np.diff(rs.token_times), 0.0)
                            .tolist())
        if not gaps:
            return 1.0
        return float(np.mean(np.asarray(gaps) <= slo_s))


# ------------------------------------------------------------ construction
def build_cluster(cfg, params, device_classes: Iterable[DeviceClass], *,
                  scfg: ServingConfig, model_desc=None,
                  balancer: Optional[KVBalancer] = None,
                  bcfg: Optional[BalancerConfig] = None,
                  rcfg: RouterConfig = RouterConfig(),
                  wallclock: bool = False) -> ClusterRouter:
    """Build a heterogeneous cluster serving one model.

    ``scfg`` is the per-engine template; each device class overrides
    ``max_batch``/``pool_blocks`` from its own capacity profile and gets
    its own perfmodel latency model (``wallclock=True`` disables modeled
    timing — used by wall-clock benches). Engines share ``params`` (one
    replica per device, as on real fleets)."""
    from repro.perfmodel.model import PAM_LLAMA_7B
    model_desc = model_desc or PAM_LLAMA_7B
    devices: list[ClusterDevice] = []
    counts: dict[str, int] = {}
    for dc in device_classes:
        idx = counts.get(dc.name, 0)
        counts[dc.name] = idx + 1
        name = f"{dc.name}{idx}"
        dev_scfg = dataclasses.replace(
            scfg, max_batch=dc.max_batch,
            pool_blocks=(dc.pool_blocks(scfg.max_len, scfg.block_size)
                         if scfg.block_size else None))
        lat = None if wallclock else make_device_latency_model(dc,
                                                               model_desc)
        eng = ServingEngine(cfg, params, dev_scfg, latency_model=lat,
                            name=name)
        prior = (step_time_prior(dc, model_desc) if not wallclock else 0.0)
        ppt = (float(lat({"prefill_tokens": 1, "active": 0}))
               if lat is not None else 0.0)
        devices.append(ClusterDevice(name=name, cls=dc, engine=eng,
                                     step_prior=prior,
                                     prefill_tok_prior=ppt))
    if balancer is None and bcfg is not None:
        balancer = KVBalancer(bcfg)
    if balancer is not None and not wallclock and not balancer.token_bytes:
        # charge migrations for the MODELED per-token KV volume
        balancer.token_bytes = model_desc.kv_bytes_per_token()
    return ClusterRouter(devices, balancer=balancer, rcfg=rcfg)
