"""Training substrate: optimizer, schedules, train step, microbatching."""
