"""Paged KV storage (paper §4.2.2: "PAM adopts PagedAttention, using a
block table to record the physical locations of KV tokens").

Two layers of machinery live here:

``BlockAllocator`` — host-side bookkeeping (free list, per-sequence block
tables), the analogue of vLLM's block manager. Allocation happens at
admission time (one host decision per request, never per decode step), so
the fused decode dispatch stays a single device call.

``PagedKVPool`` + the module-level pure functions — the device side. One
pool per hierarchy holds every block of every tier; *tier membership is
metadata* (the per-token tier tags in ``PAMState``), so an Alg. 2
migration between warm and cold is a table/tag edit with zero tensor
movement (see ``repro.core.pam_interface``). Pool arrays are shaped

    (L, num_blocks + 1, block_size, H_kv, d_head)

where the final physical block is a *sentinel*: unmapped block-table
entries point at it, so masked scatters/gathers need no dynamic shapes —
writes to unmapped logical blocks land in the sentinel and reads from it
are masked out by the participation mask.

The serving engine embeds the pool arrays directly in the model's
``DecodeCache`` (fields ``pk``/``pv``) so they ride the donated fused
decode dispatch; ``PagedKVPool`` is the standalone container used by
tests, examples and host-side tools. Gather/scatter between the paged and
dense layouts goes through ``repro.core.pam_interface`` (the hardware
re-layout unit of §6.2).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


class OutOfBlocks(RuntimeError):
    """Raised when an allocation cannot be served from the free list.

    The serving engine treats this as admission backpressure: the request
    stays queued until finished sequences return blocks to the pool.
    """


class BlockAllocator:
    """Free-list block allocator with per-sequence block tables.

    Host-side only. ``allocate(seq_id, n_tokens)`` grows ``seq_id``'s
    table to cover ``n_tokens`` logical tokens (idempotent for already-
    covered prefixes) and returns the table — a list of *physical* block
    ids in logical order. ``free(seq_id)`` returns every block of the
    sequence to the free list; physical ids are recycled verbatim, so the
    next owner overwrites stale KV on its prefill commit
    (``check_no_double_mapping`` certifies the invariant that a physical
    block never appears in two live tables).
    """

    def __init__(self, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(num_blocks - 1, -1, -1))
        self.tables: dict[int, list[int]] = {}

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def occupancy(self) -> float:
        """Fraction of the pool currently mapped to live sequences."""
        return self.used_blocks / max(self.num_blocks, 1)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def allocate(self, seq_id: int, n_tokens: int) -> list[int]:
        need = self.blocks_for(n_tokens) - len(self.tables.get(seq_id, []))
        if need > len(self._free):
            raise OutOfBlocks(
                f"need {need} blocks, {len(self._free)} free")
        tbl = self.tables.setdefault(seq_id, [])
        for _ in range(max(need, 0)):
            tbl.append(self._free.pop())
        return tbl

    def free(self, seq_id: int) -> None:
        """Return every block of the sequence to the free list. Also the
        free-WITHOUT-finish primitive of inter-device migration: the
        exporter gathers the blocks' KV into a snapshot first, then
        frees; the importing engine allocates fresh blocks on its own
        pool (physical ids never travel)."""
        for b in self.tables.pop(seq_id, []):
            self._free.append(b)

    def table(self, seq_id: int) -> list[int]:
        return self.tables.get(seq_id, [])

    def padded_table(self, seq_id: int, n_logical: int,
                     sentinel: int) -> np.ndarray:
        """Device-ready table row: ``(n_logical,)`` int32, physical ids in
        logical order, ``sentinel`` for unmapped logical blocks."""
        row = np.full((n_logical,), sentinel, np.int32)
        tbl = self.tables.get(seq_id, [])
        row[:len(tbl)] = tbl
        return row

    def check_no_double_mapping(self) -> bool:
        used = [b for t in self.tables.values() for b in t]
        return len(used) == len(set(used)) and \
            not (set(used) & set(self._free))


# ------------------------------------------------- device-side primitives
# Pure functions over raw pool arrays so they can be inlined into the
# engine's donated fused dispatches. All take a PER-LAYER-STACKED pool
# (L, NB+1, bs, Hkv, dh) unless noted; the decode scan peels the L axis.

def token_block_mask(mask: jax.Array, block_size: int) -> jax.Array:
    """(B, S) token mask -> (B, S//block_size) "block touched" mask.

    A block participates in the paged gather iff ANY of its tokens does —
    this is the operand that lets the kernel skip untouched pages.
    """
    B, S = mask.shape
    return mask.reshape(B, S // block_size, block_size).any(axis=-1)


def sequence_to_blocks(kv: jax.Array, block_size: int) -> jax.Array:
    """Dense cache layout -> pool block layout for one batch row.

    kv: (L, Hkv, S, dh) -> (L, S//bs, bs, Hkv, dh). Used by the admission
    commit to scatter a prefilled sequence into its allocated blocks.
    """
    L, Hkv, S, dh = kv.shape
    kv = jnp.moveaxis(kv, 1, 2)                       # (L, S, Hkv, dh)
    return kv.reshape(L, S // block_size, block_size, Hkv, dh)


def write_prefill(pool: jax.Array, kv: jax.Array,
                  table_row: jax.Array, block_size: int) -> jax.Array:
    """Scatter one prefilled sequence into the pool through its table.

    pool: (L, NB+1, bs, Hkv, dh); kv: (L, Hkv, S, dh) dense layout with
    the prompt in positions [0, prompt_len); table_row: (S//bs,) physical
    ids (sentinel for unmapped). Whole logical blocks are written — zeros
    past the prompt are overwritten later by per-step appends; unmapped
    entries land in the sentinel block.
    """
    return pool.at[:, table_row].set(sequence_to_blocks(kv, block_size))


def gather_logical(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """Reference block-table gather: pool -> logical dense layout.

    pool: (NB+1, bs, Hkv, dh) single-layer slice; block_table: (B, nb)
    physical ids. Returns (B, Hkv, nb*bs, dh) with tokens in logical
    order — the jnp mirror of the Pallas kernel's in-grid gather (the
    kernel additionally skips dead blocks; this reference touches all of
    them and relies on masking). Delegates to the §6.2 re-layout unit.
    """
    from repro.core.pam_interface import paged_gather_logical
    return paged_gather_logical(pool, block_table)


def gather_sequence(pool: jax.Array, table_row: jax.Array) -> jax.Array:
    """Inverse of ``write_prefill``: gather one sequence's blocks back
    into the dense cache layout.

    pool: (L, NB+1, bs, Hkv, dh); table_row: (nb,) physical ids in
    logical order (sentinel for unmapped — those positions gather the
    trash block and are masked by validity downstream). Returns
    (L, Hkv, nb*bs, dh) — the export half of the §6.2 re-layout
    interface, used to build inter-device migration snapshots.
    """
    g = pool[:, table_row]                            # (L, nb, bs, Hkv, dh)
    L, nb, bs, Hkv, dh = g.shape
    return jnp.moveaxis(g.reshape(L, nb * bs, Hkv, dh), 2, 1)


@dataclasses.dataclass
class PagedKVPool:
    """Device-side paged KV storage for the memory hierarchy.

    K and V pools are shaped ``(L, num_blocks + 1, block_size, H_kv,
    d_head)``; the trailing physical block (index ``num_blocks``) is the
    write/read sentinel for unmapped block-table entries. One pool holds
    the blocks of *every* tier — tier residency is metadata
    (``PAMState.tier``), which is what makes Alg. 2 migration a table
    edit instead of a copy.

    Registered as a pytree (``block_size`` is static aux data) so whole
    pools can cross jit boundaries in tests and tools; the serving engine
    instead embeds ``k``/``v`` directly in ``DecodeCache.pk/pv``.
    """
    k: jax.Array
    v: jax.Array
    block_size: int

    @classmethod
    def create(cls, n_layers: int, num_blocks: int, block_size: int,
               n_kv: int, d_head: int, dtype=jnp.bfloat16) -> "PagedKVPool":
        shape = (n_layers, num_blocks + 1, block_size, n_kv, d_head)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   block_size=block_size)

    @property
    def num_blocks(self) -> int:
        """Allocatable blocks (excludes the sentinel)."""
        return self.k.shape[1] - 1

    @property
    def sentinel(self) -> int:
        """Physical id of the trash block unmapped table entries use."""
        return self.k.shape[1] - 1

    def write_prefill(self, layer_k: jax.Array, layer_v: jax.Array,
                      table_row: jax.Array) -> "PagedKVPool":
        """Scatter a prefilled sequence (dense (L, Hkv, S, dh) layout)
        into the blocks named by ``table_row`` ((S//bs,) physical ids)."""
        return PagedKVPool(
            k=write_prefill(self.k, layer_k, table_row, self.block_size),
            v=write_prefill(self.v, layer_v, table_row, self.block_size),
            block_size=self.block_size)

    def gather_logical(self, block_table: jax.Array
                       ) -> tuple[jax.Array, jax.Array]:
        """Logical-order gather of all layers: returns K and V shaped
        (L, B, Hkv, nb*bs, dh) for the given (B, nb) block table."""
        gk = jax.vmap(gather_logical, in_axes=(0, None))(self.k,
                                                         block_table)
        gv = jax.vmap(gather_logical, in_axes=(0, None))(self.v,
                                                         block_table)
        return gk, gv

    def write_tokens(self, layer_k: jax.Array, layer_v: jax.Array,
                     block_ids: np.ndarray, slot_ids: np.ndarray
                     ) -> "PagedKVPool":
        """Scatter individual tokens into (block, slot) positions.

        layer_k/v: (L, T, Hkv, dh); block_ids/slot_ids: (T,).
        """
        bi = jnp.asarray(block_ids)
        si = jnp.asarray(slot_ids)
        return PagedKVPool(k=self.k.at[:, bi, si].set(layer_k),
                           v=self.v.at[:, bi, si].set(layer_v),
                           block_size=self.block_size)

    def gather_tokens(self, block_ids: np.ndarray, slot_ids: np.ndarray
                      ) -> tuple[jax.Array, jax.Array]:
        """Gather (L, T, Hkv, dh) for the given token positions."""
        bi = jnp.asarray(block_ids)
        si = jnp.asarray(slot_ids)
        return self.k[:, bi, si], self.v[:, bi, si]


def _pool_flatten(p: PagedKVPool):
    return (p.k, p.v), p.block_size


def _pool_unflatten(aux, children):
    return PagedKVPool(k=children[0], v=children[1], block_size=aux)


jax.tree_util.register_pytree_node(PagedKVPool, _pool_flatten,
                                   _pool_unflatten)


def token_to_block_slot(positions: np.ndarray, table: list[int],
                        block_size: int) -> tuple[np.ndarray, np.ndarray]:
    """Map logical token positions -> (physical block id, slot) via table."""
    pos = np.asarray(positions)
    logical = pos // block_size
    phys = np.asarray(table, np.int32)[logical]
    return phys, pos % block_size
