"""End-to-end engine benchmark: the REAL serving engine (control flow,
continuous batching, PAM importance/scheduling state) accounted with the
paper's hardware timing model — the closest analogue of the paper's
simulator runs, with the actual algorithm state (tier reads, hit rates,
migrations) driving the clock."""

from __future__ import annotations

import time

import numpy as np

from repro.perfmodel.model import (PAM_LLAMA_7B, SystemKind, make_system)
from repro.perfmodel.latency import make_latency_model


def bench_engine() -> list[tuple]:
    import jax
    import jax.numpy as jnp  # noqa: F401
    from repro.models import transformer as tf
    from repro.models.config import get_config, reduced
    from repro.serving import (PAMManagerConfig, Request, ServingConfig,
                               ServingEngine)

    cfg = reduced(get_config("pam-llama-7b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    rows = []
    results = {}
    for name, kind, pam_on in (
            ("pam", SystemKind.PAM, True),
            ("ls-pim", SystemKind.LSPIM, True),
            ("vllm-offload", SystemKind.VLLM_OFFLOAD, False)):
        system = make_system(kind)
        pam_cfg = PAMManagerConfig(
            max_tokens=96, hot_capacity=16, warm_capacity=32,
            compression=4, recency_window=4,
            schedule_interval=2,
            use_tiering=(kind == SystemKind.PAM)) if pam_on else None
        eng = ServingEngine(
            cfg, params,
            ServingConfig(max_batch=4, max_len=96, pam=pam_cfg),
            # 16384 hardware tokens per engine token: exercises the tiered
            # hierarchy at paper scale (see perfmodel.latency)
            latency_model=make_latency_model(system, PAM_LLAMA_7B,
                                             context_scale=16384))
        for i in range(8):
            eng.submit(Request(id=i,
                               prompt=rng.integers(0, cfg.vocab, 24),
                               max_new_tokens=16))
        summary = eng.run()
        results[name] = summary
        rows.append((f"engine/{name}",
                     summary["p50_tpot_s"] * 1e6,
                     f"sim_tput={summary['throughput_tok_s']:.0f}tok/s "
                     f"p99_tpot_us={summary['p99_tpot_s']*1e6:.0f}"))
    ratio = (results["vllm-offload"]["p50_tpot_s"]
             / max(results["pam"]["p50_tpot_s"], 1e-9))
    rows.append(("engine/pam_vs_vllm", 0.0,
                 f"p50_tpot_speedup={ratio:.2f}x"))
    return rows


def bench_decode_wallclock(micro_steps: int = 8) -> dict:
    """REAL wall-clock decode throughput of the serving engine on the
    current backend (no latency model): the fused-dispatch fast path's
    tokens/s and device dispatches per decode step. PAM config, batch 4."""
    import jax
    from repro.models import transformer as tf
    from repro.models.config import get_config, reduced
    from repro.serving import (PAMManagerConfig, Request, ServingConfig,
                               ServingEngine)

    cfg = reduced(get_config("pam-llama-7b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    pam_cfg = PAMManagerConfig(
        max_tokens=96, hot_capacity=16, warm_capacity=32,
        compression=4, recency_window=4, schedule_interval=2)

    def one_run(micro: int) -> dict:
        rng = np.random.default_rng(0)
        eng = ServingEngine(cfg, params,
                            ServingConfig(max_batch=4, max_len=96,
                                          pam=pam_cfg, micro_steps=micro))
        for i in range(8):
            eng.submit(Request(id=i, prompt=rng.integers(0, cfg.vocab, 24),
                               max_new_tokens=16))
        t0 = time.perf_counter()
        summary = eng.run()
        wall = time.perf_counter() - t0
        return {
            "micro_steps": micro,
            "wall_s": wall,
            "decode_tok_s": summary["total_tokens"] / wall,
            "decode_dispatches": summary["decode_dispatches"],
            "decode_device_steps": summary["decode_device_steps"],
            "dispatches_per_step": (summary["decode_dispatches"]
                                    / max(summary["decode_device_steps"],
                                          1)),
        }

    one_run(1)                                 # warm the jit caches
    one_run(micro_steps)
    return {"fused": one_run(1), "micro": one_run(micro_steps),
            "backend": jax.default_backend()}


def wallclock_rows(result: dict) -> list[tuple]:
    rows = []
    for name in ("fused", "micro"):
        r = result[name]
        rows.append((f"engine/wallclock_{name}_k{r['micro_steps']}",
                     r["wall_s"] * 1e6 / max(r["decode_device_steps"], 1),
                     f"decode_tok_s={r['decode_tok_s']:.0f} "
                     f"dispatches_per_step={r['dispatches_per_step']:.3f}"))
    return rows
