"""KV-centric serving engine (paper §4): request pool, continuous batching
with prefill priority, paged + tiered KV management, PAM decode loop."""

from repro.serving.paged_kv import (BlockAllocator, OutOfBlocks,
                                    PagedKVPool, PrefixTrie)
from repro.serving.pam_manager import PAMManager, PAMManagerConfig
from repro.serving.engine import (PAMEngine, Request, RequestState,
                                  ServingConfig, ServingEngine)
from repro.serving.events import ServeEvent
from repro.serving.spec import EngineSpec

__all__ = ["BlockAllocator", "EngineSpec", "OutOfBlocks", "PagedKVPool",
           "PAMEngine", "PAMManager", "PAMManagerConfig", "PrefixTrie",
           "Request", "RequestState", "ServeEvent", "ServingConfig",
           "ServingEngine"]
