"""qwen3-14b [hf:Qwen/Qwen3-8B family; hf] — dense GQA w/ qk-norm."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936, d_head=128, qk_norm=True,
    rope_theta=1e6,
))
