"""mamba2-780m [arXiv:2405.21060; unverified] — pure SSD (attention-free).
d_inner=3072, P=64 -> 48 ssm heads, N=128."""
from repro.models.config import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1,
                  conv_kernel=4, chunk=128),
))
