"""Paged KV storage (paper §4.2.2: "PAM adopts PagedAttention, using a
block table to record the physical locations of KV tokens").

Two layers of machinery live here:

``BlockAllocator`` — host-side bookkeeping (free list, per-sequence block
tables), the analogue of vLLM's block manager. Allocation happens at
admission time (one host decision per request, never per decode step), so
the fused decode dispatch stays a single device call.

``PagedKVPool`` + the module-level pure functions — the device side. One
pool per hierarchy holds every block of every tier; *tier membership is
metadata* (the per-token tier tags in ``PAMState``), so an Alg. 2
migration between warm and cold is a table/tag edit with zero tensor
movement (see ``repro.core.pam_interface``). Pool arrays are shaped

    (L, num_blocks + 1, block_size, H_kv, d_head)

where the final physical block is a *sentinel*: unmapped block-table
entries point at it, so masked scatters/gathers need no dynamic shapes —
writes to unmapped logical blocks land in the sentinel and reads from it
are masked out by the participation mask.

The serving engine embeds the pool arrays directly in the model's
``DecodeCache`` (fields ``pk``/``pv``) so they ride the donated fused
decode dispatch; ``PagedKVPool`` is the standalone container used by
tests, examples and host-side tools. Gather/scatter between the paged and
dense layouts goes through ``repro.core.pam_interface`` (the hardware
re-layout unit of §6.2).
"""

from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


class OutOfBlocks(RuntimeError):
    """Raised when an allocation cannot be served from the free list.

    The serving engine treats this as admission backpressure: the request
    stays queued until finished sequences return blocks to the pool (or
    the prefix trie evicts idle cached blocks).
    """


class BlockAllocator:
    """Refcounted free-list block allocator with per-sequence tables.

    Host-side only. ``allocate(seq_id, n_tokens)`` grows ``seq_id``'s
    table to cover ``n_tokens`` logical tokens (idempotent for already-
    covered prefixes) and returns the table — a list of *physical* block
    ids in logical order.

    Prefix sharing (PR 7) makes physical blocks REFERENCE-COUNTED: a
    block may be mapped by several live tables at once (a shared prompt
    prefix) and additionally pinned by the ``PrefixTrie``. ``free`` /
    release therefore DECREFS: a block returns to the free list only
    when its last reference drops. ``adopt``/``admit_shared`` map
    existing blocks into a new table (increffing them) instead of
    popping fresh ones; ``incref``/``decref`` are the raw primitives the
    trie uses for its own pins.

    Explicit failure behaviour (hardened in PR 7): ``free`` of an
    unknown or already-freed ``seq_id`` is a no-op returning 0 (double
    release during teardown/migration races must not crash the engine),
    while ``decref`` of a block with no outstanding references raises
    ``ValueError`` — that is always a real double-free bug.

    ``check_refcounts`` certifies conservation: every block's refcount
    equals its appearances across live tables plus external pins, the
    free list holds exactly the zero-ref blocks, and no table maps the
    same block twice.
    """

    def __init__(self, num_blocks: int, block_size: int):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(num_blocks - 1, -1, -1))
        self.tables: dict[int, list[int]] = {}
        self.refcount: dict[int, int] = {}   # physical id -> live refs

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Blocks with at least one reference (tables OR trie pins) —
        with sharing this is NOT the sum of table lengths."""
        return self.num_blocks - len(self._free)

    @property
    def occupancy(self) -> float:
        """Fraction of the pool currently referenced. Shared blocks
        count ONCE however many tables map them, which is exactly the
        capacity win prefix sharing buys."""
        return self.used_blocks / max(self.num_blocks, 1)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def allocate(self, seq_id: int, n_tokens: int) -> list[int]:
        need = self.blocks_for(n_tokens) - len(self.tables.get(seq_id, []))
        if need > len(self._free):
            raise OutOfBlocks(
                f"need {need} blocks, {len(self._free)} free")
        tbl = self.tables.setdefault(seq_id, [])
        for _ in range(max(need, 0)):
            b = self._free.pop()
            self.refcount[b] = 1
            tbl.append(b)
        return tbl

    def adopt(self, seq_id: int, shared: list[int]) -> list[int]:
        """Map already-live physical blocks (a trie-matched prefix, in
        logical order) into ``seq_id``'s table, increffing each. The
        blocks must currently be referenced — adopting a free-listed id
        would alias recycled storage."""
        tbl = self.tables.setdefault(seq_id, [])
        for b in shared:
            self.incref(b)
            tbl.append(b)
        return tbl

    def admit_shared(self, seq_id: int, shared: list[int],
                     n_tokens: int) -> list[int]:
        """Atomic shared admission: map ``shared`` prefix blocks plus
        enough fresh blocks to cover ``n_tokens``, or raise
        ``OutOfBlocks`` with the allocator state untouched."""
        have = len(self.tables.get(seq_id, []))
        need = self.blocks_for(n_tokens) - have - len(shared)
        if need > len(self._free):
            raise OutOfBlocks(
                f"need {need} fresh blocks, {len(self._free)} free")
        self.adopt(seq_id, shared)
        return self.allocate(seq_id, n_tokens)

    def incref(self, block: int) -> None:
        if self.refcount.get(block, 0) <= 0:
            raise ValueError(f"incref of unreferenced block {block}: "
                             f"only live blocks can gain references")
        self.refcount[block] += 1

    def decref(self, block: int) -> bool:
        """Drop one reference; returns True iff the block hit zero refs
        and went back on the free list. Raises ``ValueError`` on a
        double-free (no outstanding references)."""
        rc = self.refcount.get(block, 0)
        if rc <= 0:
            raise ValueError(f"double free of block {block}")
        self.refcount[block] = rc - 1
        if rc == 1:
            del self.refcount[block]
            self._free.append(block)
            return True
        return False

    def free(self, seq_id: int) -> int:
        """Drop the sequence's reference on every block of its table
        (free-WITHOUT-finish is the same primitive: inter-device
        migration gathers the blocks' KV into a snapshot first, then
        frees; the importing engine allocates on its own pool — physical
        ids never travel). With prefix sharing this is a DECREF: blocks
        still mapped by another live request, or pinned by the trie,
        stay out of the free list. Unknown / already-freed ``seq_id`` is
        an explicit no-op. Returns the number of blocks actually
        recycled."""
        tbl = self.tables.pop(seq_id, None)
        if tbl is None:
            return 0
        return sum(self.decref(b) for b in tbl)

    # Back-compat alias: PR 4's free-without-finish entry point.
    release = free

    def table(self, seq_id: int) -> list[int]:
        return self.tables.get(seq_id, [])

    def padded_table(self, seq_id: int, n_logical: int,
                     sentinel: int) -> np.ndarray:
        """Device-ready table row: ``(n_logical,)`` int32, physical ids in
        logical order, ``sentinel`` for unmapped logical blocks."""
        row = np.full((n_logical,), sentinel, np.int32)
        tbl = self.tables.get(seq_id, [])
        row[:len(tbl)] = tbl
        return row

    def check_refcounts(self, extra_refs: dict[int, int] | None = None
                        ) -> bool:
        """Refcount conservation, callable from any test.

        ``extra_refs`` are references held outside the tables (pass
        ``PrefixTrie.block_refs()``). Certifies, for the whole pool:

        * per-block refcount == appearances across live tables + extras
        * no table maps the same physical block twice
        * free list ∩ referenced blocks == ∅ (and holds no duplicates)
        * every block is either referenced or free — nothing leaks
        """
        refs: collections.Counter = collections.Counter()
        for t in self.tables.values():
            if len(t) != len(set(t)):
                return False            # one table maps a block twice
            refs.update(t)
        for b, n in (extra_refs or {}).items():
            refs[b] += n
        if any(not 0 <= b < self.num_blocks for b in refs):
            return False
        free = set(self._free)
        if len(free) != len(self._free):
            return False                # duplicate free-list entry
        if free & set(refs):
            return False                # referenced block on free list
        if len(refs) + len(free) != self.num_blocks:
            return False                # leaked (or phantom) blocks
        return all(self.refcount.get(b, 0) == n for b, n in refs.items()) \
            and all(refs.get(b, 0) == n for b, n in self.refcount.items())

    def check_no_double_mapping(self,
                                extra_refs: dict[int, int] | None = None
                                ) -> bool:
        """PR 2's invariant, generalized refcount-aware (PR 7): with
        sharing, a block legitimately appears in several tables — what
        must hold instead is refcount conservation. Kept under the old
        name so every existing call site picks up the stronger check."""
        return self.check_refcounts(extra_refs)


# ----------------------------------------------------------- prefix trie
def _lcp(a, b) -> int:
    """Length of the longest common prefix of two token sequences."""
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


@dataclasses.dataclass
class _TrieNode:
    """One cached FULL block of prompt tokens. The path root -> node
    spells the token prefix in ``block_size`` chunks; ``block`` is the
    physical pool block holding its KV. ``partials`` index cached
    partially-filled tail blocks published below this prefix: token
    tuple (shorter than a block) -> ``[physical id, lru stamp]``."""
    block: int
    children: dict = dataclasses.field(default_factory=dict)
    partials: dict = dataclasses.field(default_factory=dict)
    stamp: int = 0


class PrefixTrie:
    """Prompt-prefix cache index over the paged pool (PR 7).

    Keyed on token ids at block granularity: a lookup walks full-block
    token chunks and returns the longest cached prefix plus the physical
    blocks holding its KV, so an admission maps those blocks instead of
    recomputing prefill for them. Partially-filled tail blocks are
    indexed too — a sharer may map one only via COPY-ON-WRITE (the
    engine duplicates it into a fresh block before any scatter), because
    the publisher keeps appending decode tokens into slots past the
    published fill.

    The trie holds ONE allocator reference per block it indexes, so
    cached prefixes survive their publisher finishing (that is the whole
    point of a prefix cache) yet are reclaimable: ``evict`` drops
    LRU entries whose blocks have no other reference (refcount 1 =
    trie-only), leaf-first so every surviving path stays contiguous from
    the root. The serving engine calls it when the free list cannot
    cover an admission — cache pressure degrades to recompute, never to
    failure.
    """

    def __init__(self, block_size: int, allocator: BlockAllocator):
        self.block_size = block_size
        self.allocator = allocator
        self.root = _TrieNode(block=-1)
        self._tick = 0
        self.hits = 0                   # lookups matching > 0 tokens
        self.evictions = 0              # blocks reclaimed under pressure

    # ------------------------------------------------------------- lookup
    def lookup(self, tokens) -> tuple[int, list[int]]:
        """Longest cached prefix of ``tokens``: returns ``(matched,
        phys_ids)`` where ``phys_ids`` cover logical blocks
        ``[0, ceil(matched / block_size))`` in order. When ``matched``
        is not a block multiple, the LAST id is a partially-covered
        block — the caller must copy-on-write it before writing."""
        toks = [int(t) for t in tokens]
        bs = self.block_size
        self._tick += 1
        node, ids, i = self.root, [], 0
        while i + bs <= len(toks):
            child = node.children.get(tuple(toks[i:i + bs]))
            if child is None:
                break
            child.stamp = self._tick
            ids.append(child.block)
            i += bs
            node = child
        # partial tail: longest common prefix with any published partial
        # OR with the leading tokens of a cached FULL block (both are
        # partially-covered matches the caller must copy-on-write)
        rest, best_len, best_blk, best_hit = toks[i:], 0, -1, None
        for ptoks, entry in node.partials.items():
            lcp = _lcp(rest, ptoks)
            if lcp > best_len:
                best_len, best_blk, best_hit = lcp, entry[0], entry
        for key, child in node.children.items():
            lcp = _lcp(rest, key)
            if lcp > best_len:
                best_len, best_blk, best_hit = lcp, child.block, child
        if best_len:
            ids.append(best_blk)
            if isinstance(best_hit, _TrieNode):
                best_hit.stamp = self._tick
            else:
                best_hit[1] = self._tick
        matched = i + best_len
        if matched:
            self.hits += 1
        return matched, ids

    # ------------------------------------------------------------ publish
    def insert(self, tokens, table: list[int]) -> int:
        """Publish an admitted prompt's blocks (call AFTER the commit
        dispatch lands their KV in the pool). ``table`` is the owner's
        physical ids in logical order. Already-cached chunks are left in
        place; each newly indexed block gains one trie reference.
        Returns the number of blocks published."""
        toks = [int(t) for t in tokens]
        bs = self.block_size
        self._tick += 1
        node, published = self.root, 0
        for j in range(len(toks) // bs):
            key = tuple(toks[j * bs:(j + 1) * bs])
            child = node.children.get(key)
            if child is None:
                child = _TrieNode(block=table[j], stamp=self._tick)
                self.allocator.incref(table[j])
                node.children[key] = child
                published += 1
            child.stamp = self._tick
            node = child
        rem = len(toks) % bs
        if rem:
            key = tuple(toks[-rem:])
            if key not in node.partials:
                node.partials[key] = [table[len(toks) // bs], self._tick]
                self.allocator.incref(table[len(toks) // bs])
                published += 1
        return published

    # ------------------------------------------------------------ evict
    def _evictable(self):
        """(stamp, remover, block) for every entry whose block is
        trie-only (refcount 1): all partials, plus LEAF full nodes —
        interior nodes stay so surviving paths remain root-contiguous."""
        out = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            for key, entry in list(node.partials.items()):
                if self.allocator.refcount.get(entry[0], 0) == 1:
                    out.append((entry[1], (node.partials, key), entry[0]))
            for key, child in node.children.items():
                if (not child.children and not child.partials
                        and self.allocator.refcount.get(child.block,
                                                        0) == 1):
                    out.append((child.stamp, (node.children, key),
                                child.block))
                stack.append(child)
        return out

    def evict(self, need: int) -> int:
        """Reclaim at least ``need`` blocks by dropping LRU trie-only
        entries (leaf-first). Returns how many blocks were actually
        freed — fewer than ``need`` when live requests pin the rest."""
        freed = 0
        while freed < need:
            cands = self._evictable()
            if not cands:
                break
            _, (container, key), block = min(cands, key=lambda c: c[0])
            del container[key]
            freed += self.allocator.decref(block)
            self.evictions += 1
        return freed

    # ------------------------------------------------------------- stats
    def block_refs(self) -> dict[int, int]:
        """Trie-held references per block — the ``extra_refs`` operand
        of ``BlockAllocator.check_refcounts``."""
        refs: dict[int, int] = {}
        stack = [self.root]
        while stack:
            node = stack.pop()
            for entry in node.partials.values():
                refs[entry[0]] = refs.get(entry[0], 0) + 1
            for child in node.children.values():
                refs[child.block] = refs.get(child.block, 0) + 1
                stack.append(child)
        return refs

    @property
    def num_blocks(self) -> int:
        return len(self.block_refs())


# ------------------------------------------------- device-side primitives
# Pure functions over raw pool arrays so they can be inlined into the
# engine's donated fused dispatches. All take a PER-LAYER-STACKED pool
# (L, NB+1, bs, Hkv, dh) unless noted; the decode scan peels the L axis.

def token_block_mask(mask: jax.Array, block_size: int) -> jax.Array:
    """(B, S) token mask -> (B, S//block_size) "block touched" mask.

    A block participates in the paged gather iff ANY of its tokens does —
    this is the operand that lets the kernel skip untouched pages.
    """
    B, S = mask.shape
    return mask.reshape(B, S // block_size, block_size).any(axis=-1)


def sequence_to_blocks(kv: jax.Array, block_size: int) -> jax.Array:
    """Dense cache layout -> pool block layout for one batch row.

    kv: (L, Hkv, S, dh) -> (L, S//bs, bs, Hkv, dh). Used by the admission
    commit to scatter a prefilled sequence into its allocated blocks.
    """
    L, Hkv, S, dh = kv.shape
    kv = jnp.moveaxis(kv, 1, 2)                       # (L, S, Hkv, dh)
    return kv.reshape(L, S // block_size, block_size, Hkv, dh)


def write_prefill(pool: jax.Array, kv: jax.Array,
                  table_row: jax.Array, block_size: int) -> jax.Array:
    """Scatter one prefilled sequence into the pool through its table.

    pool: (L, NB+1, bs, Hkv, dh); kv: (L, Hkv, S, dh) dense layout with
    the prompt in positions [0, prompt_len); table_row: (S//bs,) physical
    ids (sentinel for unmapped). Whole logical blocks are written — zeros
    past the prompt are overwritten later by per-step appends; unmapped
    entries land in the sentinel block.
    """
    return pool.at[:, table_row].set(sequence_to_blocks(kv, block_size))


def copy_block(pool: jax.Array, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Copy-on-write duplicate: clone physical block ``src`` into ``dst``.

    pool: (L, NB+1, bs, Hkv, dh); src/dst: scalar physical ids. Runs
    inside the donated admission commit BEFORE the sharer's suffix
    scatter, so a partially-filled tail block published in the prefix
    trie is never written through a shared mapping — the publisher keeps
    appending into the original, the sharer diverges in its own copy.
    """
    return pool.at[:, dst].set(pool[:, src])


def gather_logical(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """Reference block-table gather: pool -> logical dense layout.

    pool: (NB+1, bs, Hkv, dh) single-layer slice; block_table: (B, nb)
    physical ids. Returns (B, Hkv, nb*bs, dh) with tokens in logical
    order — the jnp mirror of the Pallas kernel's in-grid gather (the
    kernel additionally skips dead blocks; this reference touches all of
    them and relies on masking). Delegates to the §6.2 re-layout unit.
    """
    from repro.core.pam_interface import paged_gather_logical
    return paged_gather_logical(pool, block_table)


def gather_sequence(pool: jax.Array, table_row: jax.Array) -> jax.Array:
    """Inverse of ``write_prefill``: gather one sequence's blocks back
    into the dense cache layout.

    pool: (L, NB+1, bs, Hkv, dh); table_row: (nb,) physical ids in
    logical order (sentinel for unmapped — those positions gather the
    trash block and are masked by validity downstream). Returns
    (L, Hkv, nb*bs, dh) — the export half of the §6.2 re-layout
    interface, used to build inter-device migration snapshots.
    """
    g = pool[:, table_row]                            # (L, nb, bs, Hkv, dh)
    L, nb, bs, Hkv, dh = g.shape
    return jnp.moveaxis(g.reshape(L, nb * bs, Hkv, dh), 2, 1)


def shard_block_ranges(total_blocks: int, shard: int
                       ) -> list[tuple[int, int]]:
    """Physical-block ownership ranges under PR 10's sharded layout.

    The pool's block axis (``NB + 1`` physical blocks, sentinel
    included) splits evenly over the mesh's ``model`` axis: shard ``r``
    owns the contiguous half-open range ``[r*nb_loc, (r+1)*nb_loc)``.
    Block TABLES keep replicated global ids — each shard localizes a
    global id by subtracting its range start and masks out non-owned
    blocks (``kernels.ops.paged_decode_attention_partial`` with
    ``block_offset``), so the allocator, trie and migration snapshots
    never see shard coordinates. The sentinel (global id ``NB``) lands
    on the LAST shard; writes routed to it stay shard-local.

    ``total_blocks`` counts the sentinel (i.e. pass ``NB + 1``) and
    must be divisible by ``shard`` — ``EngineSpec.validate`` enforces
    this with an actionable message.
    """
    if total_blocks % shard:
        raise ValueError(f"{total_blocks} physical blocks (sentinel "
                         f"included) do not split over {shard} shards")
    nb_loc = total_blocks // shard
    return [(r * nb_loc, (r + 1) * nb_loc) for r in range(shard)]


@dataclasses.dataclass
class PagedKVPool:
    """Device-side paged KV storage for the memory hierarchy.

    K and V pools are shaped ``(L, num_blocks + 1, block_size, H_kv,
    d_head)``; the trailing physical block (index ``num_blocks``) is the
    write/read sentinel for unmapped block-table entries. One pool holds
    the blocks of *every* tier — tier residency is metadata
    (``PAMState.tier``), which is what makes Alg. 2 migration a table
    edit instead of a copy.

    Registered as a pytree (``block_size`` is static aux data) so whole
    pools can cross jit boundaries in tests and tools; the serving engine
    instead embeds ``k``/``v`` directly in ``DecodeCache.pk/pv``.
    """
    k: jax.Array
    v: jax.Array
    block_size: int

    @classmethod
    def create(cls, n_layers: int, num_blocks: int, block_size: int,
               n_kv: int, d_head: int, dtype=jnp.bfloat16) -> "PagedKVPool":
        shape = (n_layers, num_blocks + 1, block_size, n_kv, d_head)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   block_size=block_size)

    @property
    def num_blocks(self) -> int:
        """Allocatable blocks (excludes the sentinel)."""
        return self.k.shape[1] - 1

    @property
    def sentinel(self) -> int:
        """Physical id of the trash block unmapped table entries use."""
        return self.k.shape[1] - 1

    def write_prefill(self, layer_k: jax.Array, layer_v: jax.Array,
                      table_row: jax.Array) -> "PagedKVPool":
        """Scatter a prefilled sequence (dense (L, Hkv, S, dh) layout)
        into the blocks named by ``table_row`` ((S//bs,) physical ids)."""
        return PagedKVPool(
            k=write_prefill(self.k, layer_k, table_row, self.block_size),
            v=write_prefill(self.v, layer_v, table_row, self.block_size),
            block_size=self.block_size)

    def gather_logical(self, block_table: jax.Array
                       ) -> tuple[jax.Array, jax.Array]:
        """Logical-order gather of all layers: returns K and V shaped
        (L, B, Hkv, nb*bs, dh) for the given (B, nb) block table."""
        gk = jax.vmap(gather_logical, in_axes=(0, None))(self.k,
                                                         block_table)
        gv = jax.vmap(gather_logical, in_axes=(0, None))(self.v,
                                                         block_table)
        return gk, gv

    def write_tokens(self, layer_k: jax.Array, layer_v: jax.Array,
                     block_ids: np.ndarray, slot_ids: np.ndarray
                     ) -> "PagedKVPool":
        """Scatter individual tokens into (block, slot) positions.

        layer_k/v: (L, T, Hkv, dh); block_ids/slot_ids: (T,).
        """
        bi = jnp.asarray(block_ids)
        si = jnp.asarray(slot_ids)
        return PagedKVPool(k=self.k.at[:, bi, si].set(layer_k),
                           v=self.v.at[:, bi, si].set(layer_v),
                           block_size=self.block_size)

    def gather_tokens(self, block_ids: np.ndarray, slot_ids: np.ndarray
                      ) -> tuple[jax.Array, jax.Array]:
        """Gather (L, T, Hkv, dh) for the given token positions."""
        bi = jnp.asarray(block_ids)
        si = jnp.asarray(slot_ids)
        return self.k[:, bi, si], self.v[:, bi, si]


def _pool_flatten(p: PagedKVPool):
    return (p.k, p.v), p.block_size


def _pool_unflatten(aux, children):
    return PagedKVPool(k=children[0], v=children[1], block_size=aux)


jax.tree_util.register_pytree_node(PagedKVPool, _pool_flatten,
                                   _pool_unflatten)


def token_to_block_slot(positions: np.ndarray, table: list[int],
                        block_size: int) -> tuple[np.ndarray, np.ndarray]:
    """Map logical token positions -> (physical block id, slot) via table."""
    pos = np.asarray(positions)
    logical = pos // block_size
    phys = np.asarray(table, np.int32)[logical]
    return phys, pos % block_size
