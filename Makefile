PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test verify bench quickstart

test:            ## tier-1 test suite
	python -m pytest -x -q

verify:          ## tier-1 tests + fast bench smoke (scripts/verify.sh)
	bash scripts/verify.sh

bench:           ## full benchmark harness -> BENCH.json
	python -m benchmarks.run --out BENCH.json

quickstart:      ## run the examples/quickstart.py walkthrough
	python examples/quickstart.py
