"""GQA attention (train + decode) with optional qk-norm and RoPE.

Train path uses memory-friendly q-chunked attention (peak intermediate
(B, H, chunk, S) instead of (B, H, S, S)); on TPU the Pallas
``fused_attention`` kernel replaces it via the ``use_kernel`` flag.

Decode attention is injectable: the serving/distributed layer passes a
``decode_attn_fn`` (e.g. PAMattention over tier pools or the shard_map
sequence-sharded form); default is dense local attention.
"""

from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, init_linear, rms_norm

DecodeAttnFn = Callable[..., jax.Array]


class AttnParams(NamedTuple):
    wq: jax.Array               # (d, H*dh)
    wk: jax.Array               # (d, Hkv*dh)
    wv: jax.Array               # (d, Hkv*dh)
    wo: jax.Array               # (H*dh, d)
    q_norm: Optional[jax.Array]  # (dh,) or None
    k_norm: Optional[jax.Array]


def init_attn(key, d: int, n_heads: int, n_kv: int, d_head: int,
              qk_norm: bool, dtype) -> AttnParams:
    ks = jax.random.split(key, 4)
    return AttnParams(
        wq=init_linear(ks[0], d, n_heads * d_head, dtype),
        wk=init_linear(ks[1], d, n_kv * d_head, dtype),
        wv=init_linear(ks[2], d, n_kv * d_head, dtype),
        wo=init_linear(ks[3], n_heads * d_head, d, dtype),
        q_norm=jnp.ones((d_head,), dtype) if qk_norm else None,
        k_norm=jnp.ones((d_head,), dtype) if qk_norm else None,
    )


def _project_qkv(p: AttnParams, x: jax.Array, positions: jax.Array,
                 n_heads: int, n_kv: int, d_head: int, rope_theta: float,
                 rms_eps: float):
    B, S, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, p.wq).reshape(B, S, n_heads, d_head)
    k = jnp.einsum("bsd,de->bse", x, p.wk).reshape(B, S, n_kv, d_head)
    v = jnp.einsum("bsd,de->bse", x, p.wv).reshape(B, S, n_kv, d_head)
    if p.q_norm is not None:
        q = rms_norm(q, p.q_norm, rms_eps)
        k = rms_norm(k, p.k_norm, rms_eps)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, chunk: int = 512,
                      scale: float | None = None) -> jax.Array:
    """q: (B, S, H, dk); k: (B, S, Hkv, dk); v: (B, S, Hkv, dv).
    fp32 softmax, q-chunked; d_v may differ from d_k (MLA)."""
    B, S, H, dh = q.shape
    Hkv, dv = k.shape[2], v.shape[-1]
    rep = H // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(dh)
    kh = jnp.moveaxis(k, 2, 1)                         # (B, Hkv, S, dh)
    vh = jnp.moveaxis(v, 2, 1)
    qh = jnp.moveaxis(q, 2, 1).reshape(B, Hkv, rep, S, dh)

    chunk = min(chunk, S)
    pad = (chunk - S % chunk) % chunk
    if pad:
        qh = jnp.pad(qh, ((0, 0),) * 3 + ((0, pad), (0, 0)))
    nchunk = (S + pad) // chunk
    qh = qh.reshape(B, Hkv, rep, nchunk, chunk, dh)
    qh = jnp.moveaxis(qh, 3, 0)                        # (nc, B, Hkv, rep, c, dh)

    kpos = jnp.arange(S)

    def one_chunk(ic, qc):
        # qc: (B, Hkv, rep, chunk, dh)
        s = jnp.einsum("bgrcd,bgsd->bgrcs", qc.astype(jnp.float32),
                       kh.astype(jnp.float32)) * scale
        if causal:
            qpos = ic * chunk + jnp.arange(chunk)
            mask = kpos[None, :] <= qpos[:, None]      # (chunk, S)
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p)
        from repro.models import perf_flags
        if perf_flags.enabled("bf16_probs"):
            # §Perf: fp32 max/sum for stability, bf16 for the PV matmul —
            # halves the dominant score-materialization bytes
            return jnp.einsum("bgrcs,bgsd->bgrcd", p.astype(jnp.bfloat16),
                              vh.astype(jnp.bfloat16)).astype(q.dtype)
        return jnp.einsum("bgrcs,bgsd->bgrcd", p,
                          vh.astype(jnp.float32)).astype(q.dtype)

    out = jax.lax.map(lambda args: one_chunk(*args),
                      (jnp.arange(nchunk), qh))        # (nc, B, Hkv, rep, c, dv)
    out = jnp.moveaxis(out, 0, 3).reshape(B, Hkv, rep, S + pad, dv)
    if pad:
        out = out[..., :S, :]
    out = out.reshape(B, H, S, dv)
    return jnp.moveaxis(out, 1, 2)                     # (B, S, H, dv)


def sp_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 causal: bool) -> jax.Array:
    """§Perf ``sp_attn``: q-sequence-sharded attention (ring-attention
    layout under GSPMD). Queries stay sharded on the sequence axis over
    "model"; the (small, GQA) K/V are gathered once; scores/softmax/PV are
    fully LOCAL and S-sharded — per layer the only collectives are the K/V
    gather instead of multi-GB score/activation reshards. q: (B,S,H,dk),
    k/v: (B,S,Hkv,d*)."""
    from jax.sharding import PartitionSpec as P
    from repro.models import perf_flags
    B, S, H, dh = q.shape
    Hkv, dv = k.shape[2], v.shape[-1]
    rep = H // Hkv
    scale = 1.0 / math.sqrt(dh)
    mesh = perf_flags.abstract_mesh()
    if "model" in mesh.axis_names:
        dp = tuple(a for a in mesh.axis_names
                   if a in ("pod", "data")) or None
        q = jax.lax.with_sharding_constraint(q, P(dp, "model", None, None))
        k = jax.lax.with_sharding_constraint(k, P(dp, None, None, None))
        v = jax.lax.with_sharding_constraint(v, P(dp, None, None, None))
    qg = q.reshape(B, S, Hkv, rep, dh)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        pos = jnp.arange(S)
        s = jnp.where(pos[None, :] <= pos[:, None], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    pr = jnp.where(jnp.isnan(pr), 0.0, pr)
    from repro.models import perf_flags
    if perf_flags.enabled("bf16_probs"):
        pr = pr.astype(jnp.bfloat16)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", pr, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, dv).astype(q.dtype)


def attention_train(p: AttnParams, x: jax.Array, *, n_heads: int, n_kv: int,
                    d_head: int, causal: bool, rope_theta: float,
                    rms_eps: float, use_kernel: bool = False,
                    q_chunk: int = 512) -> jax.Array:
    """Full-sequence attention for train/prefill. x: (B, S, d)."""
    from repro.models import perf_flags
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(p, x, positions, n_heads, n_kv, d_head,
                           rope_theta, rms_eps)
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.fused_attention(jnp.moveaxis(q, 2, 1),
                                   jnp.moveaxis(k, 2, 1),
                                   jnp.moveaxis(v, 2, 1), causal=causal)
        out = jnp.moveaxis(out, 1, 2)
    elif perf_flags.enabled("sp_attn"):
        out = sp_attention(q, k, v, causal=causal)
    else:
        out = chunked_attention(q, k, v, causal=causal, chunk=q_chunk)
    out = out.reshape(B, S, n_heads * d_head)
    return jnp.einsum("bse,ed->bsd", out, p.wo)


def attention_prefill(p: AttnParams, x: jax.Array, *, n_heads: int,
                      n_kv: int, d_head: int, causal: bool,
                      rope_theta: float, rms_eps: float,
                      q_chunk: int = 512):
    """Like ``attention_train`` but also returns the roped K/V in cache
    layout (B, Hkv, S, dh) so serving can seed the decode cache."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(p, x, positions, n_heads, n_kv, d_head,
                           rope_theta, rms_eps)
    out = chunked_attention(q, k, v, causal=causal, chunk=q_chunk)
    out = out.reshape(B, S, n_heads * d_head)
    out = jnp.einsum("bse,ed->bsd", out, p.wo)
    return out, jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1)


def attention_prefill_with_prefix(p: AttnParams, x: jax.Array,
                                  prefix_k: jax.Array, prefix_v: jax.Array,
                                  prefix_len: jax.Array, *, n_heads: int,
                                  n_kv: int, d_head: int, rope_theta: float,
                                  rms_eps: float):
    """Suffix prefill for prefix-cache admissions (chunked-prefill core).

    ``x`` holds only the NOVEL tail of a prompt whose first
    ``prefix_len`` tokens already have cache-resident K/V. Queries are
    roped at absolute positions ``prefix_len + i`` and attend over the
    cached prefix (masked to its live length) concatenated with the
    suffix's own causal window — by causality this reproduces exactly
    what a from-scratch prefill would compute for these positions.

    x: (B, S, d) suffix activations; prefix_k/v: (B, Hkv, P, dh)
    logical cache layout (post-RoPE, live below ``prefix_len``);
    prefix_len: (B,). Returns (out (B, S, d), k, v) with k/v the
    suffix's roped K/V in cache layout (B, Hkv, S, dh) — position
    ``prefix_len + i`` at index i, ready for the pool scatter.
    """
    B, S, _ = x.shape
    positions = prefix_len[:, None] + jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, x, positions, n_heads, n_kv, d_head,
                           rope_theta, rms_eps)
    P = prefix_k.shape[2]
    rep = n_heads // n_kv
    scale = 1.0 / math.sqrt(d_head)
    qg = jnp.moveaxis(q, 2, 1).reshape(B, n_kv, rep, S, d_head)
    kh = jnp.moveaxis(k, 2, 1)                         # (B, Hkv, S, dh)
    vh = jnp.moveaxis(v, 2, 1)
    s_pre = jnp.einsum("bgrsd,bgpd->bgrsp", qg.astype(jnp.float32),
                       prefix_k.astype(jnp.float32)) * scale
    live = jnp.arange(P)[None, :] < prefix_len[:, None]           # (B, P)
    s_pre = jnp.where(live[:, None, None, None, :], s_pre, -jnp.inf)
    s_suf = jnp.einsum("bgrsd,bgtd->bgrst", qg.astype(jnp.float32),
                       kh.astype(jnp.float32)) * scale
    causal = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]     # (Sq, Sk)
    s_suf = jnp.where(causal[None, None, None], s_suf, -jnp.inf)
    pr = jax.nn.softmax(jnp.concatenate([s_pre, s_suf], axis=-1), axis=-1)
    pr = jnp.where(jnp.isnan(pr), 0.0, pr)
    out = jnp.einsum("bgrsp,bgpd->bgrsd", pr[..., :P],
                     prefix_v.astype(jnp.float32)) + \
        jnp.einsum("bgrst,bgtd->bgrsd", pr[..., P:],
                   vh.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, n_heads, S, d_head)
    out = jnp.moveaxis(out, 1, 2).reshape(B, S, n_heads * d_head)
    return jnp.einsum("bse,ed->bsd", out, p.wo), kh, vh


def grouped_decode_attn(q: jax.Array, k_cache: jax.Array,
                        v_cache: jax.Array, live: jax.Array,
                        scale: float | None = None
                        ) -> tuple[jax.Array, jax.Array]:
    """Repeat-free GQA masked decode attention.

    q: (B, H, dh); caches (B, Hkv, Smax, dh); live: (B, Smax) bool — the
    tokens that participate (length mask already folded in). Returns
    (out (B, H, dh), mass (B, Smax)).

    Query heads are grouped (B, Hkv, rep, dh) against their shared kv head,
    so QK^T is computed once per kv head with no ``jnp.repeat``
    materialization of the cache — the same grouping the Pallas
    ``flash_decode`` kernel uses.
    """
    B, H, dh = q.shape
    Hkv, Smax = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, Hkv, rep, dh)
    s = jnp.einsum("bgrd,bgsd->bgrs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    s = jnp.where(live[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bgrs,bgsd->bgrd", p, v_cache.astype(jnp.float32))
    n_live = jnp.sum(live, axis=-1, keepdims=True).astype(jnp.float32)
    mass = jnp.mean(p, axis=(1, 2)) * n_live
    return out.reshape(B, H, dh).astype(q.dtype), mass


def dense_decode_attn(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                      kv_lens: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Default decode attention. q: (B, H, dh); caches (B, Hkv, Smax, dh);
    kv_lens: (B,). Returns (out (B, H, dh), mass (B, Smax)).

    ``mass`` is the per-token attention probability mass (head-mean, scaled
    by live-token count) — the per-step score S_i(j) that feeds PAM's
    importance EMA (paper eq. 7). It falls out of the softmax for free.
    """
    Smax = k_cache.shape[2]
    live = jnp.arange(Smax)[None, :] < kv_lens[:, None]          # (B, Smax)
    return grouped_decode_attn(q, k_cache, v_cache, live)


def attention_decode(p: AttnParams, x: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, kv_lens: jax.Array, *,
                     n_heads: int, n_kv: int, d_head: int, rope_theta: float,
                     rms_eps: float,
                     decode_attn_fn: DecodeAttnFn = dense_decode_attn,
                     paged: Optional[tuple] = None):
    """One decode step. x: (B, d) current-token activations.

    Writes the new token's K/V at position ``kv_lens`` (per-sequence) and
    attends over ``kv_lens + 1`` tokens. Returns (out (B, d),
    mass (B, Smax), k_cache, v_cache) with updated caches.

    ``paged=(pk, pv, dst_block, dst_slot)`` additionally mirrors the
    appended token into this layer's paged KV pool slice ((NB+1, bs,
    Hkv, dh); dst_block/dst_slot (B,) physical coordinates, inactive rows
    routed to the sentinel block) and calls ``decode_attn_fn`` with the
    pool operands ``(q, k_cache, v_cache, pk, pv, kv_lens)``; the return
    grows to (out, mass, k_cache, v_cache, pk, pv). Keys are cached
    post-RoPE, so pool storage order is free — the block table alone
    recovers logical order.
    """
    B, d = x.shape
    q = jnp.einsum("bd,de->be", x, p.wq).reshape(B, n_heads, d_head)
    k = jnp.einsum("bd,de->be", x, p.wk).reshape(B, n_kv, d_head)
    v = jnp.einsum("bd,de->be", x, p.wv).reshape(B, n_kv, d_head)
    if p.q_norm is not None:
        q = rms_norm(q, p.q_norm, rms_eps)
        k = rms_norm(k, p.k_norm, rms_eps)
    pos = kv_lens                                       # (B,)
    q = apply_rope(q[:, None], pos[:, None], rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos[:, None], rope_theta)[:, 0]

    from repro.models import perf_flags
    if perf_flags.enabled("pam_shard_decode"):
        if paged is not None:
            raise ValueError("paged KV pools and the pam_shard_decode "
                             "perf flag are mutually exclusive")
        # §Perf: fused shard_map — masked local cache write + PAMattention
        # psum merge; avoids GSPMD gathering the sequence-sharded cache for
        # the dynamic scatter
        from repro.distributed.pam_shard import fused_update_decode
        out, mass, k_cache, v_cache = fused_update_decode(
            q, k_cache, v_cache, k, v, kv_lens)
    else:
        # scatter new kv at per-sequence position — modulo the buffer's
        # slot count: a hot-window RING cache (slots < Smax) wraps, so
        # this one write is also the ring eviction (the overwritten
        # token's bytes live on in its mapped pool block); a full-window
        # buffer reduces to the absolute position
        bidx = jnp.arange(B)
        slot = pos % k_cache.shape[2]
        k_cache = k_cache.at[bidx, :, slot].set(k)
        v_cache = v_cache.at[bidx, :, slot].set(v)
        if paged is not None:
            pk, pv, dst_block, dst_slot = paged
            pk = pk.at[dst_block, dst_slot].set(k)
            pv = pv.at[dst_block, dst_slot].set(v)
            out, mass = decode_attn_fn(q, k_cache, v_cache, pk, pv,
                                       kv_lens + 1)
            out = out.reshape(B, n_heads * d_head)
            return (jnp.einsum("be,ed->bd", out, p.wo), mass,
                    k_cache, v_cache, pk, pv)
        out, mass = decode_attn_fn(q, k_cache, v_cache, kv_lens + 1)
    out = out.reshape(B, n_heads * d_head)
    return jnp.einsum("be,ed->bd", out, p.wo), mass, k_cache, v_cache
