"""Serving-engine tests: paged allocator invariants (hypothesis), PAM
manager behaviour, end-to-end engine runs (dense + PAM), and equivalence of
the engine's masked attention with the model's dense decode."""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # hypothesis, or skip-stub fallback
from conftest import build_model, make_pam

from repro.core.tiers import COLD, HOT, WARM
from repro.models import transformer as tf
from repro.serving import (BlockAllocator, EngineSpec, PagedKVPool,
                           PAMManager, PAMManagerConfig, Request,
                           ServingConfig)
from repro.serving.paged_kv import OutOfBlocks, token_to_block_slot
from repro.serving.pam_manager import init_pam_state

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------------------- paged blocks
@settings(max_examples=20, deadline=None)
@given(st.data())
def test_block_allocator_invariants(data):
    alloc = BlockAllocator(num_blocks=16, block_size=4)
    live = set()
    for i in range(data.draw(st.integers(1, 12))):
        action = data.draw(st.sampled_from(["alloc", "grow", "free"]))
        if action == "alloc":
            n = data.draw(st.integers(1, 12))
            try:
                alloc.allocate(i, n)
                live.add(i)
            except OutOfBlocks:
                pass
        elif action == "grow" and live:
            sid = data.draw(st.sampled_from(sorted(live)))
            n = data.draw(st.integers(1, 24))
            try:
                alloc.allocate(sid, n)
            except OutOfBlocks:
                pass
        elif action == "free" and live:
            sid = data.draw(st.sampled_from(sorted(live)))
            alloc.free(sid)
            live.remove(sid)
        assert alloc.check_no_double_mapping()
    for sid in list(live):
        alloc.free(sid)
    assert alloc.free_blocks == 16


def test_paged_pool_roundtrip():
    pool = PagedKVPool.create(n_layers=2, num_blocks=8, block_size=4,
                              n_kv=2, d_head=8, dtype=jnp.float32)
    alloc = BlockAllocator(8, 4)
    table = alloc.allocate(0, 10)
    key = jax.random.PRNGKey(0)
    k = jax.random.normal(key, (2, 10, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 1), (2, 10, 2, 8))
    bids, slots = token_to_block_slot(np.arange(10), table, 4)
    pool = pool.write_tokens(k, v, bids, slots)
    k2, v2 = pool.gather_tokens(bids, slots)
    np.testing.assert_allclose(np.asarray(k2), np.asarray(k))
    np.testing.assert_allclose(np.asarray(v2), np.asarray(v))


# --------------------------------------------------------------- PAM manager
def _mgr(smax=64, hot=8, warm=16, **kw):
    return PAMManager(PAMManagerConfig(
        max_tokens=smax, hot_capacity=hot, warm_capacity=warm, **kw))


def test_participation_budget_and_recency():
    mgr = _mgr(smax=64, compression=8, recency_window=4)
    state = init_pam_state(2, 64)
    state = state._replace(
        importance=jax.random.uniform(jax.random.PRNGKey(0), (2, 64)))
    lengths = jnp.array([48, 16])
    sel = mgr.participation(state, lengths)
    n0 = int(jnp.sum(sel[0]))
    # budget = 48//8 = 6, recency adds up to 4 extra
    assert 6 <= n0 <= 10
    # recency window always included
    assert bool(jnp.all(sel[0, 44:48]))
    assert not bool(jnp.any(sel[0, 48:]))


def test_observe_appends_hot_and_respects_capacity():
    mgr = _mgr(smax=32, hot=4, warm=8, schedule_interval=1000)
    state = init_pam_state(1, 32)
    lengths = jnp.array([10])
    state = mgr.place_prefill(state, jnp.int32(0), jnp.int32(10))
    scores = jnp.ones((1, 32))
    for step in range(5):
        lengths = lengths + 1
        state = mgr.observe(state, scores, lengths,
                            jnp.ones((1, 32), bool))
    tier = np.asarray(state.tier[0])
    valid = np.arange(32) < 15
    assert (tier[valid] == HOT).sum() <= 4
    assert (tier[valid] == WARM).sum() <= 8
    assert tier[14] == HOT                  # newest token is hot


def test_scheduling_promotes_important_cold_tokens():
    mgr = _mgr(smax=32, hot=4, warm=8, schedule_interval=1,
               use_sparsity=False)
    state = init_pam_state(1, 32)
    state = mgr.place_prefill(state, jnp.int32(0), jnp.int32(24))
    # token 2 (currently COLD by recency placement) becomes super important
    scores = jnp.zeros((1, 32)).at[0, 2].set(50.0)
    assert int(state.tier[0, 2]) == COLD
    lengths = jnp.array([24])
    for _ in range(6):
        lengths = lengths + 1
        state = mgr.observe(state, scores, lengths, jnp.ones((1, 32), bool))
    assert int(state.tier[0, 2]) != COLD   # promoted by Alg. 2


# ------------------------------------------------------------------- engine
def _engine(arch="qwen3-0.6b", pam=True, max_batch=3, max_len=64):
    cfg, params = build_model(arch)
    pam_cfg = make_pam(max_len=max_len, hot=16, warm=24) if pam else None
    scfg = ServingConfig(max_batch=max_batch, max_len=max_len, pam=pam_cfg)
    return cfg, params, EngineSpec(model=cfg,
                                   serving=scfg).build(params)


def test_engine_end_to_end_pam():
    cfg, params, eng = _engine(pam=True)
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(id=i, prompt=rng.integers(0, cfg.vocab, size=6),
                           max_new_tokens=8))
    summary = eng.run()
    assert summary["finished"] == 5
    for rs in eng.requests.values():
        assert len(rs.outputs) == 8
    assert summary["throughput_tok_s"] > 0


def test_engine_continuous_batching_admits_midstream():
    cfg, params, eng = _engine(pam=True, max_batch=2)
    rng = np.random.default_rng(1)
    eng.submit(Request(id=0, prompt=rng.integers(0, cfg.vocab, 4),
                       max_new_tokens=12))
    eng.submit(Request(id=1, prompt=rng.integers(0, cfg.vocab, 4),
                       max_new_tokens=3))
    eng.submit(Request(id=2, prompt=rng.integers(0, cfg.vocab, 4),
                       max_new_tokens=3))   # waits for a slot
    s1 = eng.step()
    assert s1["active"] == 2
    done = eng.run()
    assert done["finished"] == 3


def test_engine_dense_equals_direct_decode():
    """Engine with PAM disabled reproduces the raw model decode exactly."""
    cfg, params, eng = _engine(pam=False, max_batch=1, max_len=32)
    prompt = np.asarray([3, 5, 7, 11], np.int32)
    eng.submit(Request(id=0, prompt=prompt, max_new_tokens=6))
    eng.run()
    got = eng.requests[0].outputs

    # direct: prefill + greedy decode
    logits, cache = tf.prefill(cfg, params, jnp.asarray(prompt[None]), 32)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(5):
        lg, cache, _ = tf.decode_step(
            cfg, params, jnp.asarray([toks[-1]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0])))
    assert got == toks


def test_engine_pam_stats_present():
    cfg, params, eng = _engine(pam=True, max_batch=2, max_len=64)
    rng = np.random.default_rng(2)
    for i in range(2):
        eng.submit(Request(id=i, prompt=rng.integers(0, cfg.vocab, 20),
                           max_new_tokens=6))
    reads = np.zeros(3, np.int64)
    hit = []
    for _ in range(6):
        s = eng.step()
        reads += s["tier_reads"]
        if "hit_rate" in s:
            hit.append(s["hit_rate"])
    assert reads.sum() > 0           # tiered reads observed
    assert any(h > 0.3 for h in hit)  # context locality materializes


def test_engine_mamba_arch_serves():
    """Attention-free arch serves through the same engine (PAM pieces
    inapplicable -> recency scores), per DESIGN §Arch-applicability."""
    cfg, params, eng = _engine(arch="mamba2-780m", pam=True, max_batch=2,
                               max_len=32)
    rng = np.random.default_rng(3)
    eng.submit(Request(id=0, prompt=rng.integers(0, cfg.vocab, 5),
                       max_new_tokens=4))
    out = eng.run()
    assert out["finished"] == 1
