"""Distributed tests. Multi-device checks run in a subprocess so the fake
8-device XLA flag never leaks into this session (smoke tests & benches must
see 1 device). Host-side elastic logic is tested inline."""

import os
import subprocess
import sys

import pytest

from repro.distributed.elastic import (HeartbeatLedger, StragglerMonitor,
                                       plan_recovery, rescale_batch)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_multi_device_suite():
    """shard_map PAMattention, sharded train step, pipeline, elastic
    restore — all on 8 fake devices in a subprocess."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests",
                                      "distributed_checks.py")],
        env=env, capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    assert "ALL DISTRIBUTED CHECKS PASSED" in out.stdout


# ------------------------------------------------------------ host logic
def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(threshold=2.0, patience=2)
    for step in range(4):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 5.0)
        flagged = mon.stragglers()
    assert flagged == [2]


def test_straggler_monitor_forgives_transient():
    mon = StragglerMonitor(threshold=2.0, patience=3)
    for h in range(4):
        mon.record(h, 1.0 if h != 1 else 10.0)   # one bad step
    assert mon.stragglers() == []
    for h in range(4):
        mon.record(h, 1.0)
    assert mon.stragglers() == []


def test_heartbeat_ledger():
    hb = HeartbeatLedger(dead_after=3)
    for s in range(5):
        hb.beat(0, s)
        if s < 2:
            hb.beat(1, s)
    assert hb.dead_hosts() == [1]


def test_plan_recovery_truncates_to_replicas():
    devices = list(range(32))           # 4 hosts x 8
    kept, info = plan_recovery(devices, failed_hosts={3},
                               model_parallel=16, devices_per_host=8)
    assert len(kept) == 16              # 24 survivors -> 1 replica of 16
    assert info["new_dp"] == 1
    assert info["lost_devices"] == 8
    assert info["idle_devices"] == 8


def test_plan_recovery_raises_when_too_small():
    with pytest.raises(RuntimeError):
        plan_recovery(list(range(8)), failed_hosts={0},
                      model_parallel=16, devices_per_host=8)


def test_rescale_batch_keeps_global():
    per, accum = rescale_batch(global_batch=256, old_dp=16, new_dp=8)
    assert per == 16 and accum == 2     # same global via 2x accumulation
