"""Fault tolerance & elasticity for 1000+-node deployments.

Pieces (each unit-tested; wired together by ``launch.train``):

1. ``StragglerMonitor`` — tracks per-step wall times, flags hosts whose
   steps exceed ``threshold x`` the rolling median for ``patience``
   consecutive steps (paper-scale systems: slow HBM, thermal throttle,
   failing NIC).
2. ``plan_recovery`` — given the surviving device list after a failure (or
   after evicting a straggler), produce the largest (data, model) mesh that
   keeps the model-parallel degree, dropping at most one DP replica's worth
   of devices. The checkpoint manager's mesh-elastic restore
   (``repro.checkpoint``) then reshards onto it.
3. ``HeartbeatLedger`` — liveness bookkeeping a multi-host launcher drives:
   hosts report steps; hosts silent for ``dead_after`` steps are presumed
   failed and excluded from the next recovery plan.

The recovery loop is: detect (1 or 3) -> checkpoint (if possible) ->
``plan_recovery`` -> rebuild mesh -> ``restore_pytree(..., shardings)`` ->
resume. The end-to-end path is exercised in tests/test_distributed.py with
fake CPU devices.

The SERVING cluster reuses 1 and 3 for its fault-tolerance watchdog
(``repro.cluster.recovery``): step times are normalized to slowdown
factors via each device's own latency model before they enter the
monitor (so a legitimately 4x-slower CXL device is not a straggler,
but a stalled one is), and the ledger is driven with device sim-clock
seconds instead of step counts.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0          # x median
    patience: int = 3
    window: int = 32

    def __post_init__(self):
        self._times: dict[int, deque] = {}
        self._strikes: dict[int, int] = {}

    def record(self, host: int, step_time: float) -> None:
        dq = self._times.setdefault(host, deque(maxlen=self.window))
        dq.append(step_time)

    def observe_step(self) -> None:
        """Close one observation step: compare every host's latest step
        time against the leave-one-out median of its PEERS and update
        strike counters (the ONLY mutating evaluation — call exactly
        once per step). Excluding the host from its own reference
        matters on small fleets: with 2 hosts a shared median sits
        halfway up the straggler's slowdown, hiding anything below
        ~2x threshold. ``stragglers()`` is a pure query so callers may
        poll it freely; historically the query itself bumped strikes,
        so polling twice per step double-counted and halved the
        effective patience."""
        latest = {h: dq[-1] for h, dq in self._times.items() if dq}
        if len(latest) < 2:
            return
        for h, t in latest.items():
            peers = [v for g, v in latest.items() if g != h]
            med = float(np.median(peers))
            if t > self.threshold * max(med, 1e-9):
                self._strikes[h] = self._strikes.get(h, 0) + 1
            else:
                self._strikes[h] = 0

    def stragglers(self) -> list[int]:
        """Hosts currently flagged (pure — safe to poll repeatedly).
        A host is a straggler after ``patience`` consecutive
        ``observe_step`` evaluations above ``threshold x`` the
        cross-host median."""
        return [h for h, s in self._strikes.items() if s >= self.patience]


@dataclasses.dataclass
class HeartbeatLedger:
    """Liveness ledger. ``dead_after`` is in whatever units ``beat`` is
    driven with — training drives it with integer step counts, the
    serving cluster watchdog with device sim-clock seconds
    (``repro.cluster.recovery``); the silence arithmetic is identical.
    A presumed-dead host that reports again leaves ``dead_hosts()`` on
    its next beat."""
    dead_after: float = 5

    def __post_init__(self):
        self._last_seen: dict[int, float] = {}
        self._step = 0.0

    def beat(self, host: int, step: float) -> None:
        self._last_seen[host] = step
        self._step = max(self._step, step)

    def advance(self, step: float) -> None:
        """Advance the ledger clock without any host reporting (the
        serving watchdog's wait-on-a-hung-device path)."""
        self._step = max(self._step, step)

    def dead_hosts(self) -> list[int]:
        return [h for h, s in self._last_seen.items()
                if self._step - s >= self.dead_after]


def plan_recovery(all_devices: Sequence, failed_hosts: set[int],
                  model_parallel: int, devices_per_host: int = 8
                  ) -> tuple[list, dict]:
    """Surviving-device mesh plan after failures.

    Drops every device on a failed host, truncates to a whole number of
    DP replicas (each replica = ``model_parallel`` devices), and reports
    what was sacrificed. Returns (devices_for_new_mesh, info)."""
    survivors = [d for i, d in enumerate(all_devices)
                 if (i // devices_per_host) not in failed_hosts]
    replicas = len(survivors) // model_parallel
    if replicas == 0:
        raise RuntimeError("not enough devices for one model replica")
    kept = survivors[: replicas * model_parallel]
    info = {
        "lost_devices": len(all_devices) - len(survivors),
        "idle_devices": len(survivors) - len(kept),
        "new_dp": replicas,
        "model_parallel": model_parallel,
    }
    return kept, info


def rescale_batch(global_batch: int, old_dp: int, new_dp: int,
                  keep_global: bool = True) -> tuple[int, int]:
    """Elastic batch policy: keep the global batch (more grad-accum per
    replica) or keep per-replica batch (smaller global). Returns
    (per_replica_batch, accum_steps)."""
    per = global_batch // old_dp
    if keep_global:
        total_per_replica = global_batch // new_dp
        accum = max(1, -(-total_per_replica // per))
        return per, accum
    return per, 1
