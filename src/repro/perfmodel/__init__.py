"""Analytical performance/energy model of PAM and its baselines —
the reproduction of the paper's simulator methodology (§7.1)."""

from repro.perfmodel.model import (SystemModel, SystemKind, StepWorkload,
                                   make_system, simulate_decode_step,
                                   simulate_offline, simulate_online)
from repro.perfmodel.latency import make_latency_model
from repro.perfmodel.devices import (DEVICE_CLASSES, DeviceClass,
                                     get_device_class,
                                     make_device_latency_model,
                                     parse_devices, step_time_prior)

__all__ = ["SystemModel", "SystemKind", "StepWorkload", "make_system",
           "simulate_decode_step", "simulate_offline", "simulate_online",
           "make_latency_model", "DEVICE_CLASSES", "DeviceClass",
           "get_device_class", "make_device_latency_model",
           "parse_devices", "step_time_prior"]
