"""Declarative cluster construction (PR 10): ``ClusterSpec``.

``build_cluster``'s growing kwarg list is replaced by a frozen spec the
caller can construct, inspect, serialize and validate BEFORE committing
device memory: WHAT the fleet is (model, device classes, replica
groups, serving template) and WHICH policies run on it (balancer,
router, recovery, timing) are dataclass fields; runtime INSTANCES
(params, a chaos injector, a pre-built balancer) are arguments of
``build``.

Replica groups are the spec-level face of the sharded engine
(``EngineSpec.shard``): ``ReplicaGroup(cls, devices=g)`` declares ``g``
same-class physical devices serving ONE request stream from ONE
g-way-sharded param replica — 1/g of the params and KV per device —
instead of ``g`` independent engines with full copies. ``from_cli``
keeps the launcher syntax: ``--devices hbm:1,cxl:2 --shard 2`` forms a
2-way cxl group next to a lone unsharded hbm engine.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

from repro.cluster.balancer import BalancerConfig, KVBalancer
from repro.cluster.recovery import RecoveryConfig, RecoveryManager
from repro.cluster.router import ClusterDevice, ClusterRouter, RouterConfig
from repro.models.config import ModelConfig
from repro.perfmodel.devices import (DeviceClass, make_device_latency_model,
                                     parse_devices, replica_group_class,
                                     step_time_prior)
from repro.serving.engine import ServingConfig
from repro.serving.spec import EngineSpec


@dataclasses.dataclass(frozen=True)
class ReplicaGroup:
    """``devices`` same-class physical devices backing ONE logical
    engine (one shared, ``devices``-way-sharded param replica)."""
    cls: DeviceClass
    devices: int = 1

    def __post_init__(self):
        if self.devices < 1:
            raise ValueError(f"replica group needs >= 1 device, got "
                             f"{self.devices}")


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Declarative description of a heterogeneous serving fleet.

    ``groups`` is the device topology (ordered); ``serving`` the
    per-engine template each group specializes by its capacity profile;
    the policy fields are plain configs — ``build`` turns them into the
    live balancer/recovery instances. ``wallclock`` disables modeled
    timing (wall-clock benches)."""
    model: ModelConfig
    groups: tuple[ReplicaGroup, ...]
    serving: ServingConfig
    model_desc: Optional[object] = None
    balancer: Optional[BalancerConfig] = None
    router: RouterConfig = RouterConfig()
    recovery: Optional[RecoveryConfig] = None
    wallclock: bool = False

    def __post_init__(self):
        if not self.groups:
            raise ValueError("cluster spec needs at least one replica "
                             "group (try ClusterSpec.from_cli('hbm:1', "
                             "model=..., serving=...))")

    # ------------------------------------------------------- constructors
    @classmethod
    def of(cls, model: ModelConfig,
           device_classes: Iterable[DeviceClass], *,
           serving: ServingConfig, shard: int = 1,
           **kw) -> "ClusterSpec":
        """Spec from a flat device list (one entry per physical device,
        ``parse_devices`` order). ``shard`` groups CONSECUTIVE runs of
        the same class into ``shard``-way replica groups; a run shorter
        than ``shard`` forms one group of its own size, and a longer
        run must divide evenly — the error says what to change."""
        if shard < 1:
            raise ValueError(f"shard must be >= 1, got {shard}")
        entries = list(device_classes)
        groups: list[ReplicaGroup] = []
        i = 0
        while i < len(entries):
            dc = entries[i]
            run = 1
            while i + run < len(entries) and entries[i + run] == dc:
                run += 1
            g = min(shard, run)
            if run % g:
                want = -(-run // shard) * shard
                raise ValueError(
                    f"device class {dc.name!r} has a run of {run} "
                    f"devices, which does not split into {shard}-way "
                    f"replica groups; use {dc.name}:{want} or a shard "
                    f"that divides {run}")
            groups.extend([ReplicaGroup(dc, g)] * (run // g))
            i += run
        return cls(model=model, groups=tuple(groups), serving=serving,
                   **kw)

    @classmethod
    def from_cli(cls, devices: str, *, model: ModelConfig,
                 serving: ServingConfig, shard: int = 1,
                 **kw) -> "ClusterSpec":
        """Launcher syntax: ``from_cli("hbm:1,cxl:2", ..., shard=2)``.
        Bad class names / counts / shard raise ``ValueError`` with the
        corrected spelling in the message."""
        return cls.of(model, parse_devices(devices), serving=serving,
                      shard=shard, **kw)

    def cli(self) -> str:
        """Canonical ``--devices`` string for this topology (physical
        devices, consecutive same-class groups merged): the round-trip
        twin of ``from_cli``."""
        parts: list[tuple[str, int]] = []
        for grp in self.groups:
            if parts and parts[-1][0] == grp.cls.name:
                parts[-1] = (grp.cls.name, parts[-1][1] + grp.devices)
            else:
                parts.append((grp.cls.name, grp.devices))
        return ",".join(f"{n}:{c}" for n, c in parts)

    @property
    def physical_devices(self) -> int:
        return sum(g.devices for g in self.groups)

    # ------------------------------------------------------------- build
    def build(self, params, *, balancer: Optional[KVBalancer] = None,
              faults=None, recovery: Optional[RecoveryManager] = None
              ) -> ClusterRouter:
        """Materialize the fleet: one engine per replica group (sharded
        when the group has > 1 device), perfmodel latency per class,
        balancer/recovery instances from the spec's configs. Runtime
        instances passed here override the spec's declarative configs;
        a bare ``faults`` injector implies a default recovery manager
        (injected faults without a watchdog would hang the stream)."""
        from repro.perfmodel.model import PAM_LLAMA_7B
        model_desc = self.model_desc or PAM_LLAMA_7B
        scfg = self.serving
        devices: list[ClusterDevice] = []
        counts: dict[str, int] = {}
        for grp in self.groups:
            dc, g = grp.cls, grp.devices
            idx = counts.get(dc.name, 0)
            counts[dc.name] = idx + 1
            name = f"{dc.name}{idx}"
            gdc = replica_group_class(dc, g)
            pool = (gdc.pool_blocks(scfg.max_len, scfg.block_size)
                    if scfg.block_size else None)
            if pool is not None and g > 1:
                # the pool's block axis (sentinel included) shards over
                # the group — round up to the next multiple of g
                pool = -(-(pool + 1) // g) * g - 1
            dev_scfg = dataclasses.replace(scfg, max_batch=gdc.max_batch,
                                           pool_blocks=pool)
            lat = (None if self.wallclock
                   else make_device_latency_model(gdc, model_desc))
            eng = EngineSpec(model=self.model, serving=dev_scfg,
                             shard=g, name=name).build(
                                 params, latency_model=lat)
            prior = (step_time_prior(gdc, model_desc)
                     if not self.wallclock else 0.0)
            ppt = (float(lat({"prefill_tokens": 1, "active": 0}))
                   if lat is not None else 0.0)
            devices.append(ClusterDevice(name=name, cls=gdc, engine=eng,
                                         step_prior=prior,
                                         prefill_tok_prior=ppt,
                                         base_latency=lat))
        if balancer is None and self.balancer is not None:
            balancer = KVBalancer(self.balancer)
        if (balancer is not None and not self.wallclock
                and not balancer.token_bytes):
            balancer.token_bytes = model_desc.kv_bytes_per_token()
        rec = recovery
        if rec is None:
            if self.recovery is not None:
                rec = RecoveryManager(self.recovery, injector=faults)
            elif faults is not None:
                rec = RecoveryManager(injector=faults)
        return ClusterRouter(devices, balancer=balancer,
                             rcfg=self.router, recovery=rec,
                             faults=faults)
