import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, and extract the roofline inputs.

MUST be run as its own process (the XLA flag above must precede any jax
init — which is why those are the first two lines of this file). The
``--all`` driver therefore spawns one subprocess per cell and aggregates
the per-cell JSONs under ``experiments/dryrun/``.

Per cell we record:
  - compile success (the deliverable gate), compile seconds
  - cost_analysis: per-device HLO FLOPs + bytes accessed
  - memory_analysis: argument/output/temp bytes per device (proves fit)
  - per-collective byte counts parsed from the compiled SPMD module
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) — cost_analysis does not expose these
  - MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (serve) for the
    useful-compute ratio

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k \
      --mesh single --out experiments/dryrun        # one cell
  python -m repro.launch.dryrun --all [--mesh both] # driver (subprocesses)
"""

import argparse
import json
import re
import sys
import time
import traceback


SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

ARCHS = ["qwen3-14b", "deepseek-67b", "qwen3-0.6b", "minicpm-2b",
         "internvl2-1b", "deepseek-v2-lite-16b", "qwen3-moe-235b-a22b",
         "zamba2-7b", "hubert-xlarge", "mamba2-780m"]

# HLO result-shape parser: "bf16[16,128]{1,0}" etc.
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def skip_reason(cfg, shape_name: str) -> str | None:
    info = SHAPES[shape_name]
    if info["kind"] == "decode" and not cfg.has_decode:
        return "encoder-only arch: no autoregressive decode step"
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: 512k dense-KV decode is "
                "quadratic-history; run only for SSM/hybrid "
                "(DESIGN.md §Arch-applicability)")
    return None


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-kind {count, bytes} from the compiled SPMD module.

    Bytes = result-shape bytes of each collective instruction (per-device
    traffic proxy; all-reduce counted 2x for the ring reduce+broadcast).
    ``-start`` variants counted, ``-done`` skipped (same transfer).
    """
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "-done" in ls.split("=")[0]:
            continue
        for kind in _COLLECTIVES:
            # match "= TYPE[dims]... kind(" or " kind-start("
            m = re.search(rf"=\s+(.+?)\s+{kind}(?:-start)?\(", ls)
            if m:
                shapes = _SHAPE_RE.findall(m.group(1))
                nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
                mult = 2 if kind == "all-reduce" else 1
                out[kind]["count"] += 1
                out[kind]["bytes"] += nbytes * mult
                break
    return out


class _UnrolledLoops:
    """Context manager: force every lax.scan/lax.map in the model to unroll
    during lowering. XLA-CPU's cost_analysis counts while-loop bodies ONCE
    (verified: flops identical for n_layers=7/14/28), so the calibration
    pass lowers small-layer-count UNROLLED variants to extract exact
    per-layer (body) and fixed (outside) costs."""

    def __enter__(self):
        import jax
        import jax.numpy as jnp
        self._scan = jax.lax.scan
        self._map = jax.lax.map
        orig_scan = self._scan

        def scan_unrolled(f, init=None, xs=None, length=None, reverse=False,
                          unroll=1, **kw):
            return orig_scan(f, init, xs, length=length, reverse=reverse,
                             unroll=True, **kw)

        def map_unrolled(f, xs, *, batch_size=None):
            import jax as _jax
            n = _jax.tree.leaves(xs)[0].shape[0]
            ys = [f(_jax.tree.map(lambda a: a[i], xs)) for i in range(n)]
            return _jax.tree.map(lambda *zs: jnp.stack(zs), *ys)

        jax.lax.scan = scan_unrolled
        jax.lax.map = map_unrolled
        return self

    def __exit__(self, *exc):
        import jax
        jax.lax.scan = self._scan
        jax.lax.map = self._map
        return False


def _reduced_layers(cfg, k: int):
    import dataclasses
    from repro.models.config import HybridConfig
    if cfg.family == "hybrid":
        hb = cfg.hybrid
        return dataclasses.replace(
            cfg, hybrid=HybridConfig(n_groups=k,
                                     mamba_per_group=hb.mamba_per_group,
                                     tail_mamba=1))
    return dataclasses.replace(cfg, n_layers=k)


def layer_trips(cfg) -> int:
    """Loop trip count the calibration body corresponds to."""
    return cfg.hybrid.n_groups if cfg.family == "hybrid" else cfg.n_layers


def model_flops(cfg, shape_name: str) -> float:
    """6·N·D for train, 2·N_active·D for serve-step (decode: D = batch
    tokens; prefill: D = batch x seq)."""
    info = SHAPES[shape_name]
    n = cfg.active_param_count()
    if info["kind"] == "train":
        return 6.0 * n * info["batch"] * info["seq"]
    if info["kind"] == "prefill":
        return 2.0 * n * info["batch"] * info["seq"]
    return 2.0 * n * info["batch"]          # decode: one token per seq


def _lower_cell(cfg, info, mesh, fsdp: bool):
    """Build + lower the cell's jitted step. Returns the Lowered object.
    Must run inside ``jax.set_mesh(mesh)``."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.data.pipeline import make_batch_specs
    from repro.distributed import sharding as shd
    from repro.launch.mesh import dp_axes
    from repro.models import transformer as tf
    from repro.training import optim
    from repro.training.optim import AdamWState
    from repro.training.train_step import (TrainConfig, TrainState,
                                           build_train_step)

    dp = dp_axes(mesh)
    if True:
        if info["kind"] == "train":
            tcfg = TrainConfig(adamw=optim.AdamWConfig(), remat=True,
                               activation_spec=P(dp, "model", None))
            pspecs = shd.param_specs(cfg, mesh, fsdp=fsdp)
            ospecs = shd.opt_state_specs(cfg, mesh, fsdp=fsdp)
            bspecs = shd.batch_specs(cfg, info["batch"], mesh)
            pshapes = jax.eval_shape(
                lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
            mu = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32),
                pshapes)
            state = TrainState(
                params=pshapes,
                opt=AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                               mu=mu, nu=mu),
                error_feedback=None)
            state_sh = TrainState(
                params=jax.tree.map(lambda s: NamedSharding(mesh, s),
                                    pspecs,
                                    is_leaf=lambda x: isinstance(x, P)),
                opt=AdamWState(
                    step=NamedSharding(mesh, P()),
                    mu=jax.tree.map(lambda s: NamedSharding(mesh, s),
                                    ospecs,
                                    is_leaf=lambda x: isinstance(x, P)),
                    nu=jax.tree.map(lambda s: NamedSharding(mesh, s),
                                    ospecs,
                                    is_leaf=lambda x: isinstance(x, P))),
                error_feedback=None)
            batch = make_batch_specs(cfg, info["batch"], info["seq"])
            batch_sh = {k: NamedSharding(mesh, bspecs[k]) for k in batch}
            step = build_train_step(cfg, tcfg)
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                              donate_argnums=(0,)).lower(state, batch)

        elif info["kind"] == "prefill":
            pspecs = shd.param_specs(cfg, mesh)
            pshapes = jax.eval_shape(
                lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
            psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                               is_leaf=lambda x: isinstance(x, P))
            bspec = shd.batch_dp_spec(info["batch"], mesh)
            B, S = info["batch"], info["seq"]
            if cfg.family == "audio":
                frames = jax.ShapeDtypeStruct((B, S, cfg.frontend_dim),
                                              jnp.bfloat16)

                def fn(params, frames):
                    logits, _ = tf.forward(cfg, params, {"frames": frames})
                    return logits

                lowered = jax.jit(fn, in_shardings=(
                    psh, NamedSharding(mesh, P(bspec, None, None)))
                ).lower(pshapes, frames)
            else:
                n_text = S - (cfg.num_patches if cfg.family == "vlm" else 0)
                toks = jax.ShapeDtypeStruct((B, n_text), jnp.int32)
                args = [toks]
                in_sh = [NamedSharding(mesh, P(bspec, None))]
                if cfg.family == "vlm":
                    args.append(jax.ShapeDtypeStruct(
                        (B, cfg.num_patches, cfg.frontend_dim),
                        jnp.bfloat16))
                    in_sh.append(NamedSharding(mesh, P(bspec, None, None)))

                    def fn(params, tokens, patches):
                        return tf.prefill(cfg, params, tokens, S,
                                          patches=patches)
                else:
                    def fn(params, tokens):
                        return tf.prefill(cfg, params, tokens, S)

                cspecs = shd.decode_cache_specs(cfg, B, mesh)
                csh = jax.tree.map(
                    lambda s: NamedSharding(mesh, s), cspecs,
                    is_leaf=lambda x: isinstance(x, P))
                lowered = jax.jit(
                    fn, in_shardings=(psh, *in_sh),
                    out_shardings=(NamedSharding(mesh, P(bspec, None)),
                                   csh)).lower(pshapes, *args)

        else:  # decode
            pspecs = shd.param_specs(cfg, mesh)
            pshapes = jax.eval_shape(
                lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
            psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                               is_leaf=lambda x: isinstance(x, P))
            B, S = info["batch"], info["seq"]
            cache = jax.eval_shape(
                lambda: tf.init_decode_cache(cfg, B, S))
            cspecs = shd.decode_cache_specs(cfg, B, mesh)
            csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                               is_leaf=lambda x: isinstance(x, P))
            bspec = shd.batch_dp_spec(B, mesh)
            toks = jax.ShapeDtypeStruct((B,), jnp.int32)

            def fn(params, tokens, cache):
                logits, cache, _ = tf.decode_step(cfg, params, tokens,
                                                  cache)
                return logits, cache

            lowered = jax.jit(
                fn,
                in_shardings=(psh, NamedSharding(mesh, P(bspec)), csh),
                out_shardings=(NamedSharding(mesh, P(bspec, None)), csh),
                donate_argnums=(2,)).lower(pshapes, toks, cache)

    return lowered


def _measure(compiled) -> dict:
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    return {
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        },
        "cost": {"flops": float(ca.get("flops", 0.0)),
                 "bytes_accessed": float(ca.get("bytes accessed", 0.0))},
        "collectives": collective_bytes(txt),
        "hlo_chars": len(txt),
    }


# §Perf variants: dry-run variant name -> trace-time perf flags
VARIANT_FLAGS = {
    "baseline": (),
    "sp-pin": ("sp_pin",),
    "sp-attn": ("sp_attn",),
    "sp-attn-bf16": ("sp_attn", "bf16_probs"),
    "sp-bf16": ("sp_pin", "bf16_probs"),
    "bf16-probs": ("bf16_probs",),
    "remat-dots": ("remat_dots",),
    "train-opt": ("sp_attn", "bf16_probs", "remat_dots"),
    "moe-opt": ("sp_attn", "bf16_probs", "remat_dots", "moe_pin"),
    "moe-pin": ("moe_pin",),
    "pam-shard": ("pam_shard_decode",),
}


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             variant: str = "baseline") -> dict:
    import jax
    jax.config.update("jax_platform_name", "cpu")
    from repro.launch.mesh import make_production_mesh
    from repro.models import perf_flags
    from repro.models.config import get_config

    perf_flags.set_flags(*VARIANT_FLAGS.get(variant, ()))

    cfg = get_config(arch)
    info = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "variant": variant, "status": "unknown"}
    reason = skip_reason(cfg, shape_name)
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec["chips"] = mesh.size
    # FSDP for training when TP-only params exceed ~4GB/device
    fsdp = (2.0 * cfg.param_count() / mesh.shape["model"]) > 4e9
    rec["fsdp"] = fsdp

    t0 = time.time()
    with jax.set_mesh(mesh):
        lowered = _lower_cell(cfg, info, mesh, fsdp)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        rec.update(_measure(compiled))
    rec["model_flops_global"] = model_flops(cfg, shape_name)
    rec["status"] = "ok"
    return rec


def run_calibration(arch: str, shape_name: str, mesh_kind: str,
                    variant: str = "baseline") -> dict:
    """Extract exact per-layer (body) and fixed (outside) costs by lowering
    UNROLLED variants at 2 and 4 layers:  body=(v4-v2)/2, outside=v2-2*body.
    Corrected full-model cost = outside + n_layers * body (roofline.py)."""
    import jax
    jax.config.update("jax_platform_name", "cpu")
    from repro.launch.mesh import make_production_mesh
    from repro.models import perf_flags
    from repro.models.config import get_config

    perf_flags.set_flags(*VARIANT_FLAGS.get(variant, ()))
    cfg = get_config(arch)
    info = SHAPES[shape_name]
    tag = "calib" if variant == "baseline" else f"calib-{variant}"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "variant": tag, "status": "unknown"}
    reason = skip_reason(cfg, shape_name)
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    fsdp = (2.0 * cfg.param_count() / mesh.shape["model"]) > 4e9

    vals = {}
    t0 = time.time()
    for k in (2, 4):
        cfg_k = _reduced_layers(cfg, k)
        with _UnrolledLoops(), jax.set_mesh(mesh):
            compiled = _lower_cell(cfg_k, info, mesh, fsdp).compile()
            m = _measure(compiled)
        vals[k] = {
            "flops": m["cost"]["flops"],
            "bytes": m["cost"]["bytes_accessed"],
            "coll": sum(v["bytes"] for v in m["collectives"].values()),
        }

    def split(key):
        body = (vals[4][key] - vals[2][key]) / 2.0
        outside = vals[2][key] - 2.0 * body
        return {"body": body, "outside": max(outside, 0.0)}

    rec.update(status="ok",
               trips=layer_trips(cfg),
               calib_s=round(time.time() - t0, 2),
               flops=split("flops"), bytes=split("bytes"),
               coll=split("coll"))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--all", action="store_true",
                    help="driver mode: all cells via subprocesses")
    ap.add_argument("--calibrate", action="store_true",
                    help="per-layer cost calibration instead of full cell")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)
    if args.calibrate:
        args.variant = ("calib" if args.variant == "baseline"
                        else f"calib-{args.variant}")

    if args.all:
        import subprocess
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        todo = [(a, s, m) for a in ARCHS for s in SHAPES for m in meshes]
        for arch, shape, mesh_kind in todo:
            tag = f"{arch}__{shape}__{mesh_kind}__{args.variant}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[skip-done] {tag}", flush=True)
                continue
            print(f"[run] {tag}", flush=True)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                   "--out", args.out, "--variant", args.variant] + \
                (["--calibrate"] if args.calibrate else [])
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=3600)
            if r.returncode != 0:
                err = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                       "variant": args.variant, "status": "error",
                       "error": (r.stderr or r.stdout)[-3000:]}
                with open(path, "w") as f:
                    json.dump(err, f, indent=1)
                print(f"[FAIL] {tag}", flush=True)
            else:
                print(f"[ok] {tag}", flush=True)
        return

    assert args.arch and args.shape
    tag = f"{args.arch}__{args.shape}__{args.mesh}__{args.variant}"
    try:
        if args.calibrate:
            base_variant = (args.variant[len("calib-"):]
                            if args.variant.startswith("calib-")
                            else "baseline")
            rec = run_calibration(args.arch, args.shape, args.mesh,
                                  base_variant)
        else:
            rec = run_cell(args.arch, args.shape, args.mesh, args.out,
                           args.variant)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "variant": args.variant, "status": "error",
               "error": traceback.format_exc()[-3000:]}
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("error",)}, indent=1))
    if rec["status"] == "error":
        print(rec.get("error", ""), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
