"""On-device sampling + EOS in the fused dispatch (ROADMAP item):
sampling keys are derived PER REQUEST inside the dispatch as
``fold_in(fold_in(PRNGKey(seed), rid), position)`` — a request's stream
is a pure function of (seed, rid, positions, logits), independent of
batch composition / slot / step phase (the invariant that makes
migration and failure replay bit-exact) — temperature=0 is exactly
argmax, top_k=1 is greedy at any temperature, sampling is
seed-reproducible, and batched same-bucket admissions commit in one
prefill + one donated dispatch."""

import jax
import numpy as np

from repro.models import transformer as tf
from repro.models.config import get_config, reduced
from repro.serving import (EngineSpec, PAMManagerConfig, Request,
                           ServingConfig)

jax.config.update("jax_platform_name", "cpu")

_CFG = reduced(get_config("qwen3-0.6b"))
_PARAMS = tf.init_params(_CFG, jax.random.PRNGKey(0))


def _engine(**kw):
    pam = PAMManagerConfig(max_tokens=64, hot_capacity=8, warm_capacity=16,
                           compression=4, recency_window=4,
                           schedule_interval=2)
    scfg = ServingConfig(max_batch=3, max_len=64, pam=pam, **kw)
    return EngineSpec(model=_CFG, serving=scfg).build(_PARAMS)


def _run(eng, n=3, seed=0, max_new=8):
    rng = np.random.default_rng(seed)
    for i in range(n):
        eng.submit(Request(id=i, prompt=rng.integers(0, _CFG.vocab, 6),
                           max_new_tokens=max_new))
    eng.run()
    return {rid: rs.outputs for rid, rs in eng.requests.items()}


def test_temperature_zero_is_argmax():
    """temperature=0 (the default) compiles to the exact greedy fast
    path — identical streams whether stated or defaulted."""
    assert _run(_engine()) == _run(_engine(temperature=0.0))


def test_top_k_one_equals_greedy_at_any_temperature():
    """top_k=1 leaves a single live logit, so categorical sampling
    degenerates to argmax regardless of temperature or seed."""
    greedy = _run(_engine())
    assert greedy == _run(_engine(temperature=1.0, top_k=1))
    assert greedy == _run(_engine(temperature=3.0, top_k=1,
                                  sample_seed=123))


def test_sampling_reproducible_and_seed_sensitive():
    a = _run(_engine(temperature=1.0, sample_seed=7))
    b = _run(_engine(temperature=1.0, sample_seed=7))
    c = _run(_engine(temperature=1.0, sample_seed=8))
    assert a == b                       # same seed -> same streams
    assert a != c                       # different key -> diverges
    for outs in a.values():
        assert all(0 <= t < _CFG.vocab for t in outs)


def test_first_token_is_sampled_too():
    """The PREFILL token obeys the sampling policy (it is drawn in the
    admission commit, not argmaxed): at high temperature different seeds
    produce different first tokens, while temperature=0 keeps the greedy
    first token."""
    greedy_first = {rid: outs[0] for rid, outs in _run(_engine()).items()}
    firsts = []
    for seed in (1, 2, 3):
        out = _run(_engine(temperature=5.0, sample_seed=seed))
        firsts.append({rid: o[0] for rid, o in out.items()})
    assert any(f != firsts[0] for f in firsts[1:])   # seed-sensitive
    assert any(f != greedy_first for f in firsts)    # not just argmax


def test_prefill_eos_finishes_request_without_decode():
    """A request whose FIRST (prefill-sampled) token is the EOS finishes
    at admission: one output token, no decode steps for it."""
    probe = _engine()
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, _CFG.vocab, 6)
    probe.submit(Request(id=0, prompt=prompt, max_new_tokens=8))
    probe.run()
    eos = probe.requests[0].outputs[0]          # greedy prefill token

    eng = _engine(eos_token=int(eos))
    eng.submit(Request(id=0, prompt=prompt, max_new_tokens=8))
    eng.run()
    rs = eng.requests[0]
    assert rs.status == "done"
    assert rs.outputs == [eos]
    assert eng.decode_dispatches == 0           # never decoded


def test_prefill_eos_wave_does_not_strand_waiting_requests():
    """micro-loop path: when an ENTIRE admission wave finishes at
    prefill (EOS first tokens), the fast loop admits the next wave
    instead of breaking with requests still queued."""
    probe = _engine()
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, _CFG.vocab, 6)
    probe.submit(Request(id=0, prompt=prompt, max_new_tokens=4))
    probe.run()
    eos = probe.requests[0].outputs[0]

    eng = _engine(eos_token=int(eos), micro_steps=4)
    for i in range(5):                  # 5 identical prompts, batch 3
        eng.submit(Request(id=i, prompt=prompt, max_new_tokens=4))
    summary = eng.run()
    assert summary["finished"] == 5
    assert not eng.waiting
    for rs in eng.requests.values():
        assert rs.outputs == [eos]


def test_max_new_tokens_one_emits_exactly_one():
    eng = _engine()
    rng = np.random.default_rng(5)
    eng.submit(Request(id=0, prompt=rng.integers(0, _CFG.vocab, 6),
                       max_new_tokens=1))
    eng.run()
    assert len(eng.requests[0].outputs) == 1
    assert eng.requests[0].status == "done"


def test_sampled_stream_independent_of_batch_mix_and_phase():
    """Per-request keys: request 0's sampled stream is identical whether
    it runs alone, shares the batch with other requests, or is submitted
    late (different step phase / slot). The old threaded-key scheme
    violated all three — any batch-mix change reshuffled every draw."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, _CFG.vocab, 6) for _ in range(3)]

    solo = _engine(temperature=1.0, sample_seed=7)
    solo.submit(Request(id=0, prompt=prompts[0], max_new_tokens=8))
    solo.run()
    ref = solo.requests[0].outputs

    mixed = _engine(temperature=1.0, sample_seed=7)
    for i, p in enumerate(prompts):
        mixed.submit(Request(id=i, prompt=p, max_new_tokens=8))
    mixed.run()
    assert mixed.requests[0].outputs == ref

    late = _engine(temperature=1.0, sample_seed=7)
    late.submit(Request(id=1, prompt=prompts[1], max_new_tokens=8))
    for _ in range(3):                  # phase-shift: rid 0 joins mid-run
        late.step()
    late.submit(Request(id=0, prompt=prompts[0], max_new_tokens=8))
    late.run()
    assert late.requests[0].outputs == ref


def test_sampled_stream_depends_on_rid():
    """Identical prompts under the same seed draw DIFFERENT streams when
    their request ids differ — the rid fold_in is live."""
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, _CFG.vocab, 6)
    eng = _engine(temperature=2.0, sample_seed=3)
    for rid in (0, 1):
        eng.submit(Request(id=rid, prompt=prompt, max_new_tokens=10))
    eng.run()
    assert eng.requests[0].outputs != eng.requests[1].outputs


def test_sampled_eos_on_micro_loop():
    """Sampling + on-device EOS + the k-step micro-loop compose: the
    micro engine reproduces the synchronous sampled stream, EOS cuts
    included."""
    sync = _engine(temperature=1.0, sample_seed=11)
    outs = _run(sync, max_new=12)
    eos = outs[0][3]                    # an actually-sampled token
    streams = []
    for micro in (1, 4):
        eng = _engine(temperature=1.0, sample_seed=11,
                      eos_token=int(eos), micro_steps=micro)
        streams.append(_run(eng, max_new=12))
    assert streams[0] == streams[1]
    assert streams[0][0][-1] == eos and len(streams[0][0]) <= 4


# ------------------------------------------------- batched admission
def test_same_bucket_admissions_commit_in_one_dispatch():
    """A burst of same-bucket prompts admits with ONE prefill dispatch
    and ONE donated commit dispatch (ROADMAP batched multi-admission),
    and the streams equal the one-by-one admission path."""
    eng = _engine()
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, _CFG.vocab, n) for n in (5, 6, 7)]

    calls = {"admit": 0}
    admit_real = eng._admit_jit
    eng._admit_jit = (
        lambda *a, **k: (calls.__setitem__("admit", calls["admit"] + 1),
                         admit_real(*a, **k))[1])
    for i, p in enumerate(prompts):     # 5/6/7 share the pow-2 bucket 8
        eng.submit(Request(id=i, prompt=p, max_new_tokens=6))
    eng.step()
    assert calls["admit"] == 1          # one commit for the whole burst
    assert eng.prefill_dispatches == 1  # one batched prefill
    assert eng.admit_dispatches == 1
    eng.run()

    one_by_one = _engine()
    for i, p in enumerate(prompts):
        one_by_one.submit(Request(id=i, prompt=p, max_new_tokens=6))
        one_by_one.step()               # admit each alone
    one_by_one.run()
    for i in range(3):
        assert eng.requests[i].outputs == one_by_one.requests[i].outputs


def test_mixed_bucket_burst_groups_by_bucket():
    eng = _engine()
    rng = np.random.default_rng(3)
    for i, n in enumerate((5, 7, 20)):  # buckets 8, 8, 32
        eng.submit(Request(id=i, prompt=rng.integers(0, _CFG.vocab, n),
                           max_new_tokens=4))
    eng.step()
    assert eng.prefill_dispatches == 2  # one per bucket group
    assert eng.admit_dispatches == 2
    eng.run()
    assert all(len(rs.outputs) == 4 for rs in eng.requests.values())
