"""Multi-head Latent Attention (DeepSeek-V2) — train + absorbed decode.

The KV cache stores only the rank-r latent ``c_kv`` (+ the shared RoPE key),
so PAM's tiering/importance/scheduling operate on *latent* tokens — noted in
DESIGN.md §Arch-applicability. Decode uses the absorbed form: W_uk is folded
into the query and W_uv applied after attention, making the cached latent
both K and V (MQA-like, d_k = r + rope_dim, d_v = r).
"""

from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.attention import chunked_attention
from repro.models.config import MLAConfig
from repro.models.layers import apply_rope, init_linear, rms_norm


class MLAParams(NamedTuple):
    wq: jax.Array       # (d, H*(nope+rope))
    w_dkv: jax.Array    # (d, r)
    kv_norm: jax.Array  # (r,)
    w_kr: jax.Array     # (d, rope_dim)  shared per-token rope key
    w_uk: jax.Array     # (r, H*nope)
    w_uv: jax.Array     # (r, H*vd)
    wo: jax.Array       # (H*vd, d)


def init_mla(key, d: int, n_heads: int, cfg: MLAConfig, dtype) -> MLAParams:
    ks = jax.random.split(key, 6)
    H = n_heads
    return MLAParams(
        wq=init_linear(ks[0], d, H * (cfg.qk_nope_head_dim
                                      + cfg.qk_rope_head_dim), dtype),
        w_dkv=init_linear(ks[1], d, cfg.kv_lora_rank, dtype),
        kv_norm=jnp.ones((cfg.kv_lora_rank,), dtype),
        w_kr=init_linear(ks[2], d, cfg.qk_rope_head_dim, dtype),
        w_uk=init_linear(ks[3], cfg.kv_lora_rank, H * cfg.qk_nope_head_dim,
                         dtype),
        w_uv=init_linear(ks[4], cfg.kv_lora_rank, H * cfg.v_head_dim, dtype),
        wo=init_linear(ks[5], H * cfg.v_head_dim, d, dtype),
    )


def mla_train(p: MLAParams, x: jax.Array, cfg: MLAConfig, *, n_heads: int,
              rope_theta: float, rms_eps: float, causal: bool = True,
              q_chunk: int = 512) -> jax.Array:
    B, S, d = x.shape
    H = n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    q = jnp.einsum("bsd,de->bse", x, p.wq).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, rope_theta)

    c_kv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p.w_dkv), p.kv_norm, rms_eps)
    k_nope = jnp.einsum("bsr,re->bse", c_kv, p.w_uk).reshape(B, S, H, dn)
    v = jnp.einsum("bsr,re->bse", c_kv, p.w_uv).reshape(B, S, H, dv)
    k_rope = apply_rope(jnp.einsum("bsd,de->bse", x, p.w_kr)[:, :, None, :],
                        positions, rope_theta)          # (B, S, 1, dr)
    k_rope = jnp.broadcast_to(k_rope, (B, S, H, dr))

    qh = jnp.concatenate([q_nope, q_rope], axis=-1)
    kh = jnp.concatenate([k_nope, k_rope], axis=-1)
    scale = 1.0 / math.sqrt(dn + dr)
    out = chunked_attention(qh, kh, v, causal=causal, chunk=q_chunk,
                            scale=scale)                # (B, S, H, dv)
    return jnp.einsum("bse,ed->bsd", out.reshape(B, S, H * dv), p.wo)


def mla_prefill(p: MLAParams, x: jax.Array, cfg: MLAConfig, *, n_heads: int,
                rope_theta: float, rms_eps: float, causal: bool = True,
                q_chunk: int = 512):
    """``mla_train`` + the latent cache (c_kv, k_rope) for decode."""
    B, S, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = mla_train(p, x, cfg, n_heads=n_heads, rope_theta=rope_theta,
                    rms_eps=rms_eps, causal=causal, q_chunk=q_chunk)
    c_kv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p.w_dkv), p.kv_norm, rms_eps)
    k_rope = apply_rope(jnp.einsum("bsd,de->bse", x, p.w_kr)[:, :, None, :],
                        positions, rope_theta)[:, :, 0]     # (B, S, dr)
    return out, c_kv, k_rope


def mla_latent_decode_attn(q_eff: jax.Array, kv_latent: jax.Array,
                           k_rope: jax.Array, kv_lens: jax.Array, *,
                           scale: float) -> tuple[jax.Array, jax.Array]:
    """Absorbed-MLA decode attention over the latent cache.

    q_eff: (B, H, r + dr); kv_latent: (B, Smax, r); k_rope: (B, Smax, dr);
    returns (latent output (B, H, r), mass (B, Smax)). Injectable — the
    distributed PAM form shard-maps this same function over sequence
    shards. ``mass`` scores *latent* tokens (PAM tiering for MLA operates
    in latent space, see DESIGN.md §Arch-applicability).
    """
    B, Smax = kv_latent.shape[0], kv_latent.shape[1]
    k_eff = jnp.concatenate([kv_latent, k_rope], axis=-1)   # (B, S, r+dr)
    live = jnp.arange(Smax)[None, :] < kv_lens[:, None]
    s = jnp.einsum("bhd,bsd->bhs", q_eff.astype(jnp.float32),
                   k_eff.astype(jnp.float32)) * scale
    s = jnp.where(live[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhs,bsr->bhr", p, kv_latent.astype(jnp.float32))
    mass = jnp.mean(p, axis=1) * kv_lens[:, None].astype(jnp.float32)
    return out.astype(q_eff.dtype), mass


def mla_decode(p: MLAParams, x: jax.Array, ckv_cache: jax.Array,
               krope_cache: jax.Array, kv_lens: jax.Array, cfg: MLAConfig, *,
               n_heads: int, rope_theta: float, rms_eps: float,
               latent_attn_fn: Callable = mla_latent_decode_attn):
    """One decode step. x: (B, d). Caches: ckv (B, Smax, r),
    krope (B, Smax, dr). Returns (out (B, d), mass (B, Smax), ckv_cache,
    krope_cache)."""
    B, d = x.shape
    H = n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    r, dv = cfg.kv_lora_rank, cfg.v_head_dim
    pos = kv_lens

    q = jnp.einsum("bd,de->be", x, p.wq).reshape(B, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope.reshape(B, 1, H, dr), pos[:, None],
                        rope_theta).reshape(B, H, dr)

    c_kv = rms_norm(jnp.einsum("bd,dr->br", x, p.w_dkv), p.kv_norm, rms_eps)
    k_rope = apply_rope(jnp.einsum("bd,de->be", x, p.w_kr)[:, None, :],
                        pos[:, None], rope_theta)[:, 0]      # (B, dr)

    bidx = jnp.arange(B)
    ckv_cache = ckv_cache.at[bidx, pos].set(c_kv)
    krope_cache = krope_cache.at[bidx, pos].set(k_rope)

    # absorb W_uk into the query: q_lat[h] = q_nope[h] @ W_uk[:, h]^T
    w_uk = p.w_uk.reshape(r, H, dn)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk)
    q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)        # (B, H, r+dr)

    scale = 1.0 / math.sqrt(dn + dr)
    o_lat, mass = latent_attn_fn(q_eff, ckv_cache, krope_cache, kv_lens + 1,
                                 scale=scale)                # (B, H, r)
    w_uv = p.w_uv.reshape(r, H, dv)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, w_uv).reshape(B, H * dv)
    return (jnp.einsum("be,ed->bd", o, p.wo), mass, ckv_cache,
            krope_cache)
