"""Checkpointing for fault-tolerant multi-pod training.

Design (no orbax dependency):
  * a checkpoint is a directory ``step_<n>/`` of one ``.npy`` per pytree
    leaf + a ``manifest.json`` (treedef, shapes, dtypes, step, mesh shape);
  * writes go to ``step_<n>.tmp`` and are atomically ``rename``d — a crash
    mid-write never corrupts the latest checkpoint (restart safety);
  * restore is *mesh-elastic*: leaves are host-loaded then ``device_put``
    with whatever sharding the CURRENT mesh dictates, so a job restarted on
    fewer/more pods (elastic scaling, node failure) resharding-restores
    transparently;
  * ``CheckpointManager`` keeps the newest K checkpoints, exposes
    ``latest_step()`` for auto-resume, and tolerates partially-deleted
    directories (crash during GC).

On a real multi-host pod, each host writes only the shards it owns
(``process_index`` prefix) — single-process here, noted where relevant.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any


def _leaf_paths(tree: Pytree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path).replace("'", "").replace("[", ".") \
            .replace("]", "").strip(".")
        out.append((name or "leaf", leaf))
    return out


def save_pytree(tree: Pytree, directory: str) -> None:
    """Atomic checkpoint write (tmp dir + rename)."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"leaves": []}
    for i, (name, leaf) in enumerate(_leaf_paths(tree)):
        arr = np.asarray(jax.device_get(leaf))
        dtype_str = str(arr.dtype)
        if arr.dtype not in (np.float32, np.float64, np.int32, np.int64,
                             np.int8, np.uint8, np.bool_, np.int16,
                             np.uint16, np.uint32, np.uint64):
            arr = arr.astype(np.float32)   # bf16/fp8 etc: widen for storage
        fname = f"{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape),
             "dtype": dtype_str})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def restore_pytree(template: Pytree, directory: str,
                   shardings: Optional[Pytree] = None) -> Pytree:
    """Restore into the structure of ``template``. If ``shardings`` is
    given (pytree of jax.sharding.Sharding), leaves are placed with it —
    the elastic-rescale path: same bytes, new mesh."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t, treedef = jax.tree_util.tree_flatten(template)
    assert len(flat_t) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, "
        f"template has {len(flat_t)}")
    flat_s = (treedef.flatten_up_to(shardings)
              if shardings is not None else [None] * len(flat_t))
    leaves = []
    for meta, tleaf, sh in zip(manifest["leaves"], flat_t, flat_s):
        arr = np.load(os.path.join(directory, meta["file"]))
        want_shape = tuple(tleaf.shape)
        assert tuple(arr.shape) == want_shape, (
            f"{meta['name']}: ckpt {arr.shape} vs template {want_shape}")
        out = jax.numpy.asarray(arr).astype(tleaf.dtype)  # jax casts bf16 &c
        leaves.append(jax.device_put(out, sh) if sh is not None else out)
    return treedef.unflatten(leaves)


class CheckpointManager:
    """Step-indexed checkpoints with retention + auto-resume."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.root, d,
                                                "manifest.json")):
                out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Pytree) -> str:
        d = self._dir(step)
        save_pytree(tree, d)
        self._gc()
        return d

    def restore(self, step: int, template: Pytree,
                shardings: Optional[Pytree] = None) -> Pytree:
        return restore_pytree(template, self._dir(step), shardings)

    def restore_latest(self, template: Pytree,
                       shardings: Optional[Pytree] = None
                       ) -> tuple[Optional[int], Pytree]:
        step = self.latest_step()
        if step is None:
            return None, template
        return step, self.restore(step, template, shardings)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._dir(s), ignore_errors=True)
        # clean up orphaned tmp dirs from crashed writes
        for d in os.listdir(self.root):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.root, d),
                              ignore_errors=True)
