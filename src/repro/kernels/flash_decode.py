"""Split-KV decode attention kernels — PAMattention's Local_Attention stage
(paper Alg. 1 lines 9-13) as TPU Pallas kernels.

``flash_decode`` (dense): each grid cell owns one contiguous KV *split*
(the paper's bank group) for one (batch, kv-head) pair and emits the
partial triple ``(O, m, l)`` for the ``rep`` grouped query heads that share
the kv head. The intra-device reduction (the paper's per-bank-group RU
chain) happens in ``merge_decode`` (see ops.py), which is also what the
inter-tier / inter-device reduction reuses — same algebra, different scope.

``flash_decode_paged`` (paged): the warm/cold tiers store KV in a shared
block pool (``serving.paged_kv``), and each grid cell owns one *logical
block* of one sequence. The per-request **block table is a kernel
operand** (scalar-prefetched, so it is resident before the grid cell's DMA
is issued) and the index map dereferences it to pick the physical pool
block — the in-kernel analogue of PagedAttention's table walk, in the
spirit of TokenStack's heterogeneous HBM-PIM runtime. A per-block
``block_live`` operand lets cells whose block has no participating token
emit the merge identity without touching the data: sparse tier reads skip
untouched pages (callers additionally remap dead table entries onto the
pool's sentinel block so their DMAs all alias one trash page).

A per-token boolean ``mask`` carries PAM's tier/sparsity participation on
both kernels: tokens outside the current tier or unselected by retrieval
sparsity contribute exact-zero weight, so the same kernels serve dense
decode, tiered PAMattention, and sparse attention.

Layouts: dense KV is (B, H_kv, S, d) — sequence-major within a head so a
split is a contiguous VMEM block (the bank-aligned mapping of §6.1); the
paged pool is (num_blocks + 1, block_size, H_kv, d) per layer, sentinel
block last.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat  # noqa: F401  (backfills pltpu.CompilerParams on 0.4)

NEG_INF = float(-1e30)
DEFAULT_BLOCK_S = 512


def ring_position_map(lengths: jax.Array, window: int, *,
                      start: jax.Array | int = 0,
                      size: int | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Rotated position map of the hot-window ring buffer (PR 5).

    The hot tier stores only the last ``window`` tokens of each sequence
    in a ring: absolute position ``p`` lives at ring slot ``p % window``,
    so the per-step append (one write at ``lengths % window``) implicitly
    evicts position ``lengths - window``. This map is the address-
    generation step every ring consumer shares — the hot partial's mask
    gather, the admission-commit scatter, and migration export.

    lengths: (B,) int32 current cache lengths. Returns
    ``(ring_pos (B, size) int32, valid (B, size) bool)`` where
    ``ring_pos[b, j]`` is the absolute position resident in slot
    ``start + j`` (some value ``< lengths[b]`` congruent to that slot
    mod ``window``) and ``valid`` marks slots holding a live token.
    When ``window`` covers the whole cache (``window >= lengths``) the
    map degenerates to the identity on ``[0, lengths)`` — the legacy
    dense layout.

    ``start``/``size`` (PR 10) select a contiguous slot range
    ``[start, start + size)`` of the ring instead of the whole window —
    the address map of one ring SHARD. ``start`` may be traced (a
    ``shard_map`` ``axis_index`` expression); ``size`` is static and
    defaults to ``window``.
    """
    lengths = jnp.asarray(lengths, jnp.int32)
    base = (lengths - window)[:, None]                     # (B, 1)
    slots = (jnp.asarray(start, jnp.int32)
             + jnp.arange(size if size is not None else window,
                          dtype=jnp.int32))[None, :]       # (1, W|size)
    ring_pos = base + ((slots - base) % window)            # in [base, base+W)
    valid = ring_pos >= 0                                  # ring_pos < len
    return ring_pos, valid


def ring_gather_mask(mask: jax.Array, ring_pos: jax.Array,
                     valid: jax.Array) -> jax.Array:
    """Pull a (B, Smax) absolute-coordinate boolean mask onto ring
    coordinates: (B, W) with dead slots False. The hot partial's
    participation operand."""
    smax = mask.shape[-1]
    idx = jnp.clip(ring_pos, 0, smax - 1)
    return valid & jnp.take_along_axis(mask, idx, axis=-1)


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, *,
                   scale: float, block_s: int, kv_len: int):
    isplit = pl.program_id(2)

    q = q_ref[0, 0].astype(jnp.float32)            # (rep, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (block_s, d)
    v = v_ref[0, 0].astype(jnp.float32)            # (block_s, d)
    msk = mask_ref[0]                              # (block_s,) bool/int8

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = isplit * block_s + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    live = (pos < kv_len) & (msk[None, :] != 0)
    s = jnp.where(live, s, NEG_INF)

    m = jnp.max(s, axis=-1)                        # (rep,)
    p = jnp.exp(s - m[:, None])
    p = jnp.where(live, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # Dead split (all masked): emit the merge identity (m=NEG_INF, l=o=0).
    o_ref[0, 0, :, 0, :] = o
    m_ref[0, 0, :, 0] = m
    l_ref[0, 0, :, 0] = l


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 mask: jax.Array | None = None, *,
                 kv_len: int | None = None,
                 kv_lens: jax.Array | None = None,
                 scale: float | None = None,
                 block_s: int = DEFAULT_BLOCK_S,
                 interpret: bool = False
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """PAMattention local stage. Returns stacked partials over splits.

    q: (B, H, d); k, v: (B, H_kv, S, d); mask: (B, S) participation.
    ``kv_len`` is a static whole-batch length bound; ``kv_lens`` an optional
    per-sequence (B,) dynamic length (ragged continuous batching) that is
    folded into the participation mask without re-tracing per length.
    Returns (o, m, l): o (B, H, nsplit, d) fp32 unnormalized, m/l
    (B, H, nsplit) fp32. Merge with ``repro.kernels.ops.merge_decode``.
    """
    B, H, d = q.shape
    _, H_kv, S, _ = k.shape
    rep = H // H_kv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    if kv_len is None:
        kv_len = S
    if mask is None:
        mask = jnp.ones((B, S), jnp.int8)
    else:
        mask = mask.astype(jnp.int8)
    if kv_lens is not None:
        live = jnp.arange(S)[None, :] < kv_lens[:, None]
        mask = mask * live.astype(jnp.int8)

    block_s = min(block_s, max(S, 8))
    pad = (block_s - S % block_s) % block_s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    S_p = S + pad
    nsplit = S_p // block_s

    qg = q.reshape(B, H_kv, rep, d)

    kernel = functools.partial(_decode_kernel, scale=scale, block_s=block_s,
                               kv_len=kv_len)

    o, m, l = pl.pallas_call(
        kernel,
        grid=(B, H_kv, nsplit),
        in_specs=[
            pl.BlockSpec((1, 1, rep, d), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, d), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_s, d), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, block_s), lambda b, h, s: (b, s)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rep, 1, d), lambda b, h, s: (b, h, 0, s, 0)),
            pl.BlockSpec((1, 1, rep, 1), lambda b, h, s: (b, h, 0, s)),
            pl.BlockSpec((1, 1, rep, 1), lambda b, h, s: (b, h, 0, s)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H_kv, rep, nsplit, d), jnp.float32),
            jax.ShapeDtypeStruct((B, H_kv, rep, nsplit), jnp.float32),
            jax.ShapeDtypeStruct((B, H_kv, rep, nsplit), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(qg, k, v, mask)

    return (o.reshape(B, H, nsplit, d), m.reshape(B, H, nsplit),
            l.reshape(B, H, nsplit))


# ------------------------------------------------------------- paged kernel
def _paged_decode_kernel(bt_ref, bl_ref, q_ref, k_ref, v_ref, mask_ref,
                         o_ref, m_ref, l_ref, *, scale: float):
    b = pl.program_id(0)
    i = pl.program_id(2)
    live_block = bl_ref[b, i] != 0

    @pl.when(live_block)
    def _attend():
        q = q_ref[0, 0].astype(jnp.float32)        # (rep, d)
        k = k_ref[0, :, 0].astype(jnp.float32)     # (block_size, d)
        v = v_ref[0, :, 0].astype(jnp.float32)
        msk = mask_ref[0]                          # (block_size,)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        live = msk[None, :] != 0
        s = jnp.where(live, s, NEG_INF)
        m = jnp.max(s, axis=-1)                    # (rep,)
        p = jnp.exp(s - m[:, None])
        p = jnp.where(live, p, 0.0)
        o_ref[0, 0, :, 0, :] = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0, 0, :, 0] = m
        l_ref[0, 0, :, 0] = jnp.sum(p, axis=-1)

    @pl.when(jnp.logical_not(live_block))
    def _skip():
        # Untouched page: emit the merge identity without reading KV.
        o_ref[0, 0, :, 0, :] = jnp.zeros_like(o_ref[0, 0, :, 0, :])
        m_ref[0, 0, :, 0] = jnp.full_like(m_ref[0, 0, :, 0], NEG_INF)
        l_ref[0, 0, :, 0] = jnp.zeros_like(l_ref[0, 0, :, 0])


def flash_decode_paged(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                       block_table: jax.Array, mask: jax.Array, *,
                       block_live: jax.Array | None = None,
                       block_offset: jax.Array | int | None = None,
                       scale: float | None = None,
                       interpret: bool = False
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """PAMattention local stage over a paged KV pool (block-table operand).

    q: (B, H, d); k_pool/v_pool: (NB+1, block_size, H_kv, d) single-layer
    pool slices, sentinel block last; block_table: (B, nb) int32 physical
    block per logical block (sentinel for unmapped); mask: (B, nb*bs)
    participation at *logical* positions with any per-sequence length
    bound already folded in.

    ``block_table`` and ``block_live`` ride the grid as scalar-prefetch
    operands: the k/v index maps dereference the table so each grid cell
    DMAs exactly its physical block, and cells with ``block_live == 0``
    emit the merge identity — untouched pages are skipped. Dead entries
    are remapped onto the sentinel so their prefetches alias one block.

    ``block_offset`` (PR 10) makes the read SHARD-LOCAL: ``k_pool`` /
    ``v_pool`` then hold only physical blocks ``[block_offset,
    block_offset + NB_local)`` of the global pool while ``block_table``
    keeps GLOBAL ids (block tables survive distribution unchanged — the
    PagedAttention property). Entries outside the local range are
    treated as dead: their cells emit the merge identity without a read,
    so the cross-shard Alg. 1 merge over per-shard partials is exact.
    May be traced (a ``shard_map`` ``axis_index`` expression).

    Returns stacked partials over logical blocks: (o (B, H, nb, d) fp32
    unnormalized, m/l (B, H, nb)). Merge with ``ops.merge_decode``.
    """
    B, H, d = q.shape
    NBp, bs, H_kv, _ = k_pool.shape
    nb = block_table.shape[1]
    rep = H // H_kv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    mask = mask.astype(jnp.int32)
    if block_live is None:
        block_live = mask.reshape(B, nb, bs).any(axis=-1)
    block_live = jnp.asarray(block_live).astype(jnp.int32)
    if block_offset is not None:
        # Localize: only table entries inside my block range stay live,
        # and surviving ids rebase onto local pool coordinates.
        inside = ((block_table >= block_offset)
                  & (block_table < block_offset + NBp))
        block_live = block_live * inside.astype(jnp.int32)
        block_table = jnp.where(inside, block_table - block_offset, 0)
    # Route dead logical blocks onto the sentinel: their (skipped) cells
    # all alias one physical page instead of touching live data.
    table = jnp.where(block_live != 0, block_table, NBp - 1)
    table = table.astype(jnp.int32)

    qg = q.reshape(B, H_kv, rep, d)
    kernel = functools.partial(_paged_decode_kernel, scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # block table + block_live
        grid=(B, H_kv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, rep, d), lambda b, h, i, bt, bl: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda b, h, i, bt, bl: (bt[b, i], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda b, h, i, bt, bl: (bt[b, i], 0, h, 0)),
            pl.BlockSpec((1, bs), lambda b, h, i, bt, bl: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rep, 1, d),
                         lambda b, h, i, bt, bl: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, rep, 1),
                         lambda b, h, i, bt, bl: (b, h, 0, i)),
            pl.BlockSpec((1, 1, rep, 1),
                         lambda b, h, i, bt, bl: (b, h, 0, i)),
        ],
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H_kv, rep, nb, d), jnp.float32),
            jax.ShapeDtypeStruct((B, H_kv, rep, nb), jnp.float32),
            jax.ShapeDtypeStruct((B, H_kv, rep, nb), jnp.float32),
        ],
        interpret=interpret,
    )(table, block_live, qg, k_pool, v_pool, mask)

    return (o.reshape(B, H, nb, d), m.reshape(B, H, nb),
            l.reshape(B, H, nb))
