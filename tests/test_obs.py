"""PR 9 observability layer: metrics registry semantics, trace-export
schema validation (balanced spans, monotone sim-clock timestamps),
metrics determinism under seeded chaos, and the engine fastpath
invariants (single dispatch per decode step, buffer donation) re-run
with collectors ENABLED — telemetry must never change dispatch
structure."""

import asyncio
import json

import jax
import numpy as np
import pytest

from conftest import build_model, make_pam, make_requests

from repro.cluster import (ClusterSpec, FaultEvent, FaultInjector,
                           RecoveryConfig)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.metrics import (BYTES_BUCKETS, Histogram, MetricsRegistry,
                               log_buckets)
from repro.obs.trace import TraceCollector, validate
from repro.perfmodel.devices import CXL_CLASS, HBM_CLASS
from repro.serving import EngineSpec, Request, ServingConfig

jax.config.update("jax_platform_name", "cpu")

_CFG, _PARAMS = build_model("qwen3-0.6b")


# ------------------------------------------------------- metrics registry
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "a counter")
    g = reg.gauge("g", "a gauge")
    h = reg.histogram("h_seconds", "a histogram")
    c.inc()
    c.inc(2.5)
    g.set(7)
    g.inc(-3)
    for v in (0.001, 0.002, 0.004):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["c_total"] == 3.5
    assert snap["gauges"]["g"] == 4.0
    assert snap["histograms"]["h_seconds"]["count"] == 3
    assert snap["histograms"]["h_seconds"]["sum"] == pytest.approx(0.007)
    with pytest.raises(ValueError):
        c.inc(-1)


def test_disabled_registry_mutators_are_noops():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("c_total")
    h = reg.histogram("h_seconds")
    c.inc(100)
    h.observe(1.0)
    snap = reg.snapshot()
    assert snap["counters"]["c_total"] == 0.0
    assert snap["histograms"]["h_seconds"]["count"] == 0


def test_registration_idempotent_and_type_checked():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")


def test_labeled_children_render_and_sort():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests", ("device",))
    c.labels(device="b").inc(2)
    c.labels(device="a").inc(1)
    snap = reg.snapshot()
    keys = list(snap["counters"])
    assert keys == ['reqs_total{device="a"}', 'reqs_total{device="b"}']
    with pytest.raises(ValueError):
        c.labels(node="a")
    text = reg.render()
    assert "# TYPE reqs_total counter" in text
    assert 'reqs_total{device="a"} 1' in text


def test_histogram_render_is_cumulative_prometheus():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", buckets=(0.0, 1.0, 10.0))
    for v in (0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    text = reg.render()
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="10"} 3' in text
    assert 'lat_seconds_bucket{le="+Inf"} 4' in text
    assert "lat_seconds_count 4" in text


def test_histogram_percentiles_clamp_to_observed():
    h = Histogram.standalone()
    for _ in range(100):
        h.observe(0.25)
    # every sample identical: all percentiles clamp to the exact value
    assert h.percentile(50) == 0.25
    assert h.percentile(99) == 0.25
    s = h.summary()
    assert s["n"] == 100 and s["max"] == 0.25


def test_histogram_empty_summary_has_n0_marker():
    s = Histogram.standalone().summary()
    assert s == {"p50": 0.0, "p95": 0.0, "p99": 0.0, "n": 0,
                 "mean": 0.0, "max": 0.0}


def test_log_buckets_shape_and_validation():
    b = log_buckets(1e-3, 1e0, 4)
    assert b[0] == 0.0 and b[1] == pytest.approx(1e-3)
    assert b[-1] == pytest.approx(1.0)
    assert list(b) == sorted(b)
    with pytest.raises(ValueError):
        log_buckets(0.0, 1.0, 4)
    assert BYTES_BUCKETS[0] == 0.0 and BYTES_BUCKETS[1] == 1.0


def test_install_use_scoping():
    base = obs_metrics.get_registry()
    with obs_metrics.use() as reg:
        assert obs_metrics.get_registry() is reg
        assert reg.enabled
    assert obs_metrics.get_registry() is base


# --------------------------------------------------------- trace collector
def test_spans_balanced_and_idempotent():
    tr = TraceCollector()
    tr.begin(1, "queued", 0.0)
    tr.begin(1, "queued", 0.5)          # idempotent re-begin: dropped
    tr.begin(1, "decode", 1.0)          # auto-closes "queued"
    tr.end(1, "prefill", 1.5)           # no matching open span: dropped
    tr.mark(1, "finish", 2.0)
    tr.end(1, "decode", 2.0)
    counts = validate(tr.export())
    assert counts["spans"] == 2 and counts["requests"] == 1
    assert counts["phases_per_request"]["1"] == ["decode", "finish",
                                                 "queued"]


def test_timestamps_clamped_monotone_per_track():
    tr = TraceCollector()
    tr.slice("dev0", "step", 1.0, 0.5)
    tr.slice("dev0", "step", 0.2, 0.1)      # clock resync: clamped fwd
    tr.begin(7, "decode", 3.0)
    tr.end(7, "decode", 1.0)                # end before begin: clamped
    validate(tr.export())                   # must not raise


def test_ring_bounded_with_dropped_count():
    tr = TraceCollector(capacity=8)
    for i in range(20):
        tr.instant("dev0", f"e{i}", i * 1e-3)
    assert len(tr.events) == 8 and tr.dropped == 12
    assert tr.export()["otherData"]["dropped_events"] == 12


def test_close_open_defaults_to_last_timestamp():
    tr = TraceCollector()
    tr.begin(3, "decode", 1.5)
    tr.slice("dev0", "step", 2.0, 0.25)
    tr.close_open()
    counts = validate(tr.export())
    assert counts["spans"] == 1
    assert tr.last_time() == pytest.approx(2.25)


def test_validate_rejects_schema_violations():
    with pytest.raises(ValueError):
        validate({})                         # no traceEvents
    unbalanced = {"traceEvents": [
        {"ph": "b", "cat": "request", "id": 1, "name": "decode",
         "pid": 1, "tid": 0, "ts": 0, "args": {}}]}
    with pytest.raises(ValueError, match="unclosed"):
        validate(unbalanced)
    time_travel = {"traceEvents": [
        {"ph": "X", "cat": "device", "name": "s", "pid": 10, "tid": 0,
         "ts": 100, "dur": 50, "args": {}},
        {"ph": "X", "cat": "device", "name": "s", "pid": 10, "tid": 0,
         "ts": 120, "dur": 10, "args": {}}]}
    with pytest.raises(ValueError, match="time travel"):
        validate(time_travel)
    bad_dur = {"traceEvents": [
        {"ph": "X", "cat": "device", "name": "s", "pid": 10, "tid": 0,
         "ts": 0, "dur": -1, "args": {}}]}
    with pytest.raises(ValueError, match="duration"):
        validate(bad_dur)


# --------------------------------------------- engine + cluster integration
def _engine(scfg=None, **scfg_kw):
    scfg = scfg or ServingConfig(max_batch=3, max_len=64, pam=make_pam(),
                                 **scfg_kw)
    return EngineSpec(model=_CFG, serving=scfg).build(_PARAMS)


def test_engine_metrics_account_for_tokens_and_finishes():
    with obs_metrics.use() as reg:
        eng = _engine()
        for r in make_requests(3, _CFG.vocab, plen=6, max_new=8):
            eng.submit(r)
        eng.run()
        total = sum(len(rs.outputs) for rs in eng.requests.values())
        assert reg.get('pam_engine_decode_tokens_total{device="dev0"}'
                       ) == total
        assert reg.get('pam_engine_finished_total{device="dev0"}') == 3
        snap = reg.snapshot()
        h = snap["histograms"]['pam_engine_step_seconds{device="dev0"}']
        assert h["count"] == eng.steps and h["sum"] > 0


def test_engine_trace_full_lifecycle_single_device():
    with obs_trace.use() as tr:
        eng = _engine()
        for r in make_requests(2, _CFG.vocab, plen=6, max_new=6):
            eng.submit(r)
        eng.run()
        counts = validate(tr.export())
        for phases in counts["phases_per_request"].values():
            assert {"queued", "decode", "finish"} <= set(phases)
        assert counts["slices"] == eng.steps


def test_fastpath_single_dispatch_with_collectors_enabled():
    """THE hard constraint: one fused jitted call per decode step with
    metrics + tracing both active."""
    with obs_metrics.use(), obs_trace.use():
        eng = _engine(scfg=ServingConfig(max_batch=2, max_len=64,
                                         pam=make_pam()))
        for r in make_requests(2, _CFG.vocab, plen=6, max_new=8):
            eng.submit(r)
        calls = {"decode": 0, "admit": 0}
        fused_real = eng._get_micro(1)
        eng._micro_jits[1] = (
            lambda *a, **k: (calls.__setitem__("decode",
                                               calls["decode"] + 1),
                             fused_real(*a, **k))[1])
        admit_real = eng._admit_jit
        eng._admit_jit = (
            lambda *a, **k: (calls.__setitem__("admit",
                                               calls["admit"] + 1),
                             admit_real(*a, **k))[1])
        eng.step()
        admit_calls = calls["admit"]
        assert calls["decode"] == 1
        for _ in range(4):
            eng.step()
        assert calls["decode"] == 5
        assert calls["admit"] == admit_calls
        assert eng.decode_dispatches == 5


def test_donation_holds_with_collectors_enabled():
    with obs_metrics.use(), obs_trace.use():
        eng = _engine(scfg=ServingConfig(max_batch=2, max_len=64,
                                         pam=make_pam()))
        for r in make_requests(2, _CFG.vocab, plen=6, max_new=8):
            eng.submit(r)
        eng.step()
        k_buf, imp_buf, tok_buf = (eng.cache.k, eng.pam_state.importance,
                                   eng.tokens_dev)
        eng.step()
        assert k_buf.is_deleted()
        assert imp_buf.is_deleted()
        assert tok_buf.is_deleted()


def test_fastpath_streams_unchanged_by_collectors():
    """Telemetry observes, never perturbs: greedy token streams are
    identical with collectors on and off (micro-loop fast path too)."""
    def run(micro):
        eng = EngineSpec(model=_CFG, serving=ServingConfig(
            max_batch=3, max_len=64, pam=make_pam(),
            micro_steps=micro)).build(_PARAMS)
        for r in make_requests(3, _CFG.vocab, plen=6, max_new=8):
            eng.submit(r)
        eng.run()
        return {rid: rs.outputs for rid, rs in eng.requests.items()}

    for micro in (1, 4):
        bare = run(micro)
        with obs_metrics.use(), obs_trace.use():
            traced = run(micro)
        assert bare == traced, micro


def _chaos_cluster(reg_seed=0):
    """Seeded stall+kill chaos run over a heterogeneous 2-device
    cluster; every construction happens under the caller's installed
    collectors."""
    scfg = ServingConfig(max_batch=4, max_len=64,
                         pam=make_pam(hot=4, warm=8, recency_window=2),
                         block_size=8)
    inj = FaultInjector([FaultEvent(tick=6, kind="kill", device="cxl0")],
                        seed=reg_seed)
    router = ClusterSpec.of(
        _CFG, [HBM_CLASS, CXL_CLASS], serving=scfg,
        recovery=RecoveryConfig(
            heartbeat_timeout_s=0.01)).build(_PARAMS, faults=inj)
    for i, r in enumerate(make_requests(6, _CFG.vocab, plen=16,
                                        max_new=12)):
        router.submit_to(r, ("hbm0", "cxl0")[i % 2])
    return router.run()


def test_chaos_trace_schema_and_migration_lifecycle():
    with obs_metrics.use(), obs_trace.use() as tr:
        s = _chaos_cluster()
        assert s["finished"] == 6
        counts = validate(tr.export())
        assert counts["requests"] == 6
        # at least one request's lifecycle crosses a migration or
        # replay seam and still closes balanced
        moved = [p for p in counts["phases_per_request"].values()
                 if "migrate_out" in p or "replay" in p]
        assert moved, counts["phases_per_request"]
        assert all("finish" in p for p in
                   counts["phases_per_request"].values())


def test_chaos_metrics_snapshot_deterministic():
    """Same seeded fault trace => byte-identical counter snapshot
    (metrics are fed only from sim-clock/modeled values)."""
    snaps = []
    for _ in range(2):
        with obs_metrics.use() as reg:
            _chaos_cluster()
            snaps.append(json.dumps(reg.snapshot(), sort_keys=True))
    assert snaps[0] == snaps[1]
    assert json.loads(snaps[0])["counters"][
        'pam_cluster_faults_total{kind="kill"}'] == 1.0


def test_recovery_stats_mirrored_into_registry():
    with obs_metrics.use() as reg:
        _chaos_cluster()
        snap = reg.snapshot()["counters"]
        assert snap['pam_cluster_recovery_events_total'
                    '{event="kills_detected"}'] == 1.0


# ------------------------------------------------------------ live export
def test_ndjson_metrics_op():
    async def go():
        from repro.frontend.server import AsyncServer
        srv = AsyncServer(_engine())
        server, port, pump = await srv.serve_endpoint()
        try:
            reader, writer = await asyncio.open_connection("127.0.0.1",
                                                           port)
            writer.write(b'{"op": "metrics"}\n')
            await writer.drain()
            line = await reader.readline()
            writer.close()
            return json.loads(line)
        finally:
            pump.cancel()
            server.close()
            await server.wait_closed()

    with obs_metrics.use():
        msg = asyncio.run(go())
    assert msg["op"] == "metrics" and msg["enabled"] is True
    assert set(msg["metrics"]) == {"counters", "gauges", "histograms"}
    assert "pam_frontend_requests_total" in msg["metrics"]["counters"]


def test_frontend_latency_histograms_populated():
    async def go(srv, reqs):
        for r in reqs:
            srv.submit(r.prompt, r.max_new_tokens, rid=r.id,
                       arrival=r.arrival)
        await srv.drain()

    with obs_metrics.use() as reg:
        from repro.frontend.server import AsyncServer
        srv = AsyncServer(_engine())
        reqs = make_requests(4, _CFG.vocab, plen=6, max_new=6,
                             arrivals=True)
        asyncio.run(go(srv, reqs))
        snap = reg.snapshot()
        assert snap["histograms"]["pam_frontend_ttft_seconds"][
            "count"] == 4
        streamed = sum(len(r.tokens) for r in srv.records.values())
        assert reg.get("pam_frontend_streamed_tokens_total") == streamed
        assert snap["histograms"]["pam_frontend_itl_seconds"][
            "count"] == streamed - 4
        s = srv.summary()
        assert s["finished"] == 4 and s["streamed_tokens"] == streamed


def test_summary_canonical_keys():
    """Satellite 1: the renamed canonical key set — engines expose
    ``step_time_s`` in load signals and ``migrations_in/out`` in
    summaries; routers expose ``balancer_migrations``."""
    eng = _engine()
    sig = eng.load_signal()
    assert "step_time_s" in sig and "last_step_time" not in sig
    s = eng.summary()
    assert {"migrations_in", "migrations_out", "prefill_dispatches",
            "admit_dispatches"} <= set(s)
    with obs_metrics.use():
        summary = _chaos_cluster()
    assert "balancer_migrations" in summary
    assert "migrations" not in summary
    assert {"migrations_in", "migrations_out"} <= set(summary)
