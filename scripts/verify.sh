#!/usr/bin/env bash
# Repo verification: the tier-1 test suite + a fast benchmark smoke.
# Usage: scripts/verify.sh [--fast]   (--fast skips the bench smoke)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
    echo "== bench smoke (engine section) =="
    python -m benchmarks.run --section engine --out /tmp/BENCH_smoke.json
    python - <<'EOF'
import json
d = json.load(open("/tmp/BENCH_smoke.json"))
assert d["dispatches_per_step"] == 1.0, d["dispatches_per_step"]
assert d["decode_tok_s"] > 0
assert d["paged_blocks_touched_per_step"] < d["paged_blocks_window_per_step"]
print(f"smoke OK: {d['decode_tok_s']:.0f} tok/s, "
      f"{d['dispatches_per_step']:.2f} dispatches/step, paged pages/step "
      f"{d['paged_blocks_touched_per_step']:.1f}"
      f"/{d['paged_blocks_window_per_step']:.1f}")
EOF

    echo "== cluster smoke (2 device classes, migration exactness) =="
    python scripts/cluster_smoke.py
fi
echo "verify OK"
