"""Jit'd public wrappers around the Pallas kernels.

``pam_decode_attention`` is the full Alg. 1 pipeline: per-tier local stage
(flash_decode kernel over that tier's pool) followed by the hierarchical
reduction — intra-device merge over splits, inter-tier merge over tiers.
Wrappers fall back to interpret mode automatically off-TPU so the same call
sites run in tests, examples, and on hardware.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import online_softmax as osm
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.flash_decode import flash_decode_paged as _flash_decode_paged
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def fused_attention(q, k, v, *, causal=True, scale=None, block_q=128,
                    block_k=128, interpret=None):
    """Prefill/train attention. q:(B,H,S,d), k/v:(B,H_kv,S,d) -> (B,H,S,d)."""
    if interpret is None:
        interpret = not _on_tpu()
    return _flash_attention(q, k, v, causal=causal, scale=scale,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)


def merge_decode(o: jax.Array, m: jax.Array, l: jax.Array,
                 out_dtype=None) -> jax.Array:
    """Reduction stage (Alg. 1 ``Reduction``): merge split partials.

    o: (B, H, nsplit, d); m/l: (B, H, nsplit). Returns (B, H, d).
    """
    part = osm.AttnPartial(o=jnp.moveaxis(o, 2, 0), m=jnp.moveaxis(m, 2, 0),
                           l=jnp.moveaxis(l, 2, 0))
    merged = osm.merge_many(part)
    return osm.finalize(merged, out_dtype=out_dtype)


@functools.partial(jax.jit, static_argnames=("kv_len", "scale", "block_s",
                                             "interpret"))
def decode_attention(q, k, v, mask=None, *, kv_len=None, kv_lens=None,
                     scale=None, block_s=512, interpret=None):
    """Single-pool decode attention (local stage + intra-device reduction).

    q: (B, H, d); k/v: (B, H_kv, S, d); mask: (B, S); kv_lens: optional
    per-sequence (B,) dynamic lengths. Returns (B, H, d).
    """
    if interpret is None:
        interpret = not _on_tpu()
    o, m, l = _flash_decode(q, k, v, mask, kv_len=kv_len, kv_lens=kv_lens,
                            scale=scale, block_s=block_s,
                            interpret=interpret)
    return merge_decode(o, m, l, out_dtype=q.dtype)


def decode_attention_partial(q, k, v, mask=None, *, kv_len=None,
                             kv_lens=None, scale=None, block_s=512,
                             interpret=None) -> osm.AttnPartial:
    """Local stage only — returns the merged per-pool partial (for the
    inter-tier / inter-device reduction). Shapes as ``decode_attention``;
    partial fields are (B, H, d) / (B, H)."""
    if interpret is None:
        interpret = not _on_tpu()
    o, m, l = _flash_decode(q, k, v, mask, kv_len=kv_len, kv_lens=kv_lens,
                            scale=scale, block_s=block_s,
                            interpret=interpret)
    part = osm.AttnPartial(o=jnp.moveaxis(o, 2, 0), m=jnp.moveaxis(m, 2, 0),
                           l=jnp.moveaxis(l, 2, 0))
    return osm.merge_many(part)


def masked_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                            participate: jax.Array | None,
                            kv_lens: jax.Array, *, scale=None,
                            use_kernel: bool | None = None,
                            block_s: int = 512
                            ) -> tuple[jax.Array, jax.Array]:
    """Repeat-free GQA decode attention + per-token attention mass.

    The single decode-attention entry point for the serving fast path:
    q: (B, H, d); k/v: (B, H_kv, S, d); participate: (B, S) bool or None
    (PAM sparsity/tier union); kv_lens: (B,). Returns (out (B, H, d),
    mass (B, S)) where ``mass`` is the head-mean, count-scaled softmax mass
    feeding the importance EMA (eq. 7).

    On TPU the local stage runs the Pallas ``flash_decode`` kernel (query
    heads grouped per kv head) and the mass is reconstructed from the merged
    (m, l) statistics with one grouped QK^T; elsewhere a single grouped
    einsum computes scores once and reuses them for both the output and the
    mass — no ``jnp.repeat`` KV expansion on either path.
    """
    if use_kernel is None:
        use_kernel = _on_tpu()
    B, H, d = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    live = jnp.arange(S)[None, :] < kv_lens[:, None]
    if participate is not None:
        live = live & participate
    if not use_kernel:
        from repro.models.attention import grouped_decode_attn
        return grouped_decode_attn(q, k, v, live, scale=scale)

    # kernel path: ragged lengths ride the kernel's kv_lens fold so the
    # participation mask alone is the PAM operand
    part = decode_attention_partial(q, k, v, participate, kv_lens=kv_lens,
                                    scale=scale, block_s=min(block_s, S))
    out = osm.finalize(part, out_dtype=q.dtype)
    # Per-token mass from the merged (m, l): one grouped QK^T, no repeat.
    rep = H // Hkv
    sc = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(B, Hkv, rep, d)
    s = jnp.einsum("bgrd,bgsd->bgrs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * sc
    s = jnp.where(live[:, None, None, :], s, -jnp.inf)
    m = part.m.reshape(B, Hkv, rep)
    l = part.l.reshape(B, Hkv, rep)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None]) / jnp.maximum(l, 1e-30)[..., None]
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    n_live = jnp.sum(live, axis=-1, keepdims=True).astype(jnp.float32)
    mass = jnp.mean(p, axis=(1, 2)) * n_live
    return out, mass


# ------------------------------------------------------------- paged tiers
def _grouped_partial_from_scores(s: jax.Array, v: jax.Array,
                                 live: jax.Array) -> osm.AttnPartial:
    """Partial (o, m, l) from precomputed grouped scores.

    s: (B, Hkv, rep, S) fp32; v: (B, Hkv, S, d); live: (B, S) bool.
    Returns AttnPartial with o (B, H, d), m/l (B, H).
    """
    B, Hkv, rep, S = s.shape
    d = v.shape[-1]
    s = jnp.where(live[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bgrs,bgsd->bgrd", p, v.astype(jnp.float32))
    return osm.AttnPartial(o=o.reshape(B, Hkv * rep, d),
                           m=m.reshape(B, Hkv * rep),
                           l=l.reshape(B, Hkv * rep))


def _grouped_scores(q: jax.Array, k: jax.Array, scale: float) -> jax.Array:
    """One repeat-free grouped QK^T: q (B, H, d), k (B, Hkv, S, d) ->
    (B, Hkv, rep, S) fp32."""
    B, H, d = q.shape
    Hkv = k.shape[1]
    qg = q.reshape(B, Hkv, H // Hkv, d)
    return jnp.einsum("bgrd,bgsd->bgrs", qg.astype(jnp.float32),
                      k.astype(jnp.float32)) * scale


def paged_decode_attention_partial(q: jax.Array, k_pool: jax.Array,
                                   v_pool: jax.Array,
                                   block_table: jax.Array,
                                   token_mask: jax.Array, *,
                                   block_live: jax.Array | None = None,
                                   block_offset=None,
                                   scale=None, use_kernel: bool | None = None,
                                   interpret: bool | None = None
                                   ) -> osm.AttnPartial:
    """Local stage over a paged pool: merged per-pool partial.

    q: (B, H, d); k_pool/v_pool: (NB+1, bs, Hkv, d) single-layer slices
    (sentinel last); block_table: (B, nb) physical ids; token_mask:
    (B, nb*bs) participation at logical positions (length bound folded
    in). On TPU the Pallas ``flash_decode_paged`` kernel walks the table
    in-grid and skips dead pages; elsewhere a jnp gather through the same
    table is the reference path. Partial fields are (B, H, d) / (B, H).

    ``block_offset`` (PR 10) makes the pool slices SHARD-LOCAL while the
    table keeps global ids: entries outside ``[block_offset,
    block_offset + NB_local)`` are masked out of the partial entirely,
    so per-shard partials merge exactly into the global result
    (Alg. 1 across shards — ``distributed.pam_shard``). May be traced.
    """
    if block_offset is not None:
        # Fold non-local tokens out of the mask so BOTH paths agree: a
        # token whose block lives on another shard contributes the
        # merge identity here and its real weight there.
        nb_local, bs = k_pool.shape[0], k_pool.shape[1]
        inside = ((block_table >= block_offset)
                  & (block_table < block_offset + nb_local))
        token_mask = token_mask & jnp.repeat(inside, bs, axis=1)
        live = inside if block_live is None else (block_live & inside)
        block_live = live
        block_table = jnp.where(inside, block_table - block_offset, 0)
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        if interpret is None:
            interpret = not _on_tpu()
        o, m, l = _flash_decode_paged(q, k_pool, v_pool, block_table,
                                      token_mask, block_live=block_live,
                                      scale=scale, interpret=interpret)
        part = osm.AttnPartial(o=jnp.moveaxis(o, 2, 0),
                               m=jnp.moveaxis(m, 2, 0),
                               l=jnp.moveaxis(l, 2, 0))
        return osm.merge_many(part)
    from repro.core.pam_interface import paged_gather_logical
    d = q.shape[-1]
    sc = scale if scale is not None else 1.0 / (d ** 0.5)
    gk = paged_gather_logical(k_pool, block_table)  # (B, Hkv, nb*bs, d)
    gv = paged_gather_logical(v_pool, block_table)
    s = _grouped_scores(q, gk, sc)
    return _grouped_partial_from_scores(s, gv, token_mask)


def paged_masked_decode_attention(q: jax.Array, k_cache: jax.Array,
                                  v_cache: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array, block_table: jax.Array,
                                  hot_mask: jax.Array, paged_mask: jax.Array,
                                  kv_lens: jax.Array, *,
                                  block_live: jax.Array | None = None,
                                  scale=None, use_kernel: bool | None = None
                                  ) -> tuple[jax.Array, jax.Array]:
    """Tiered decode attention: hot-ring partial ⊕ paged warm/cold partial.

    The paged serving fast path's decode-attention entry point. The hot
    tier reads the dense kernel-ready **ring buffer** (``k_cache``/
    ``v_cache``, (B, Hkv, W, dh) — absolute position p at ring slot
    ``p % W``; W == Smax degenerates to the legacy full-window layout):
    the hot participation mask, given in absolute coordinates
    ``(B, Smax)``, is pulled onto ring coordinates through the rotated
    position map (``flash_decode.ring_position_map``). The warm/cold
    tiers read the shared block pool *through the block table* —
    ``paged_mask`` selects their tokens at logical positions, and only
    blocks with a participating token are touched. The two partials are
    merged exactly (Alg. 1 reduction), so the result equals dense masked
    attention over the union mask whenever the pool mirrors the cache.

    Callers must keep ``hot_mask`` inside the ring window (positions
    ``>= kv_lens - W``); out-of-window hot tokens have no ring slot and
    are silently dropped from the hot partial (the serving engine's tier
    clamp guarantees they were re-tagged onto the paged side).

    Returns (out (B, H, d), mass (B, Smax)) where ``mass`` is the
    head-mean count-scaled softmax mass over the union working set in
    absolute coordinates, reconstructed from the merged (m, l)
    statistics: the hot contribution is scattered back through the ring
    index map, the paged contribution comes from the pool's logical
    gather — one grouped QK^T each, the kernel-path idiom of
    ``masked_decode_attention``.
    """
    from repro.core.pam_interface import paged_gather_logical
    from repro.kernels.flash_decode import (ring_gather_mask,
                                            ring_position_map)
    B, H, d = q.shape
    Hkv, W = k_cache.shape[1], k_cache.shape[2]
    Smax = hot_mask.shape[1]
    rep = H // Hkv
    sc = scale if scale is not None else 1.0 / (d ** 0.5)
    live_len = jnp.arange(Smax)[None, :] < kv_lens[:, None]
    hot = hot_mask & live_len
    pgd = paged_mask & live_len

    # Hot partial over the ring: scores on ring coordinates, participation
    # pulled through the rotated position map.
    ring_pos, ring_valid = ring_position_map(kv_lens, W)
    hot_ring = ring_gather_mask(hot, ring_pos, ring_valid)
    s_ring = _grouped_scores(q, k_cache, sc)           # (B, Hkv, rep, W)
    part = _grouped_partial_from_scores(s_ring, v_cache, hot_ring)

    # Paged partial + logical-order pool scores (the latter also feed the
    # union-mass reconstruction — the pool mirrors every token, so its
    # gathered scores are the absolute-coordinate truth).
    # NOTE: the union-mass reconstruction below needs absolute-coordinate
    # scores for the paged side, which this (reference) formulation takes
    # from a full logical pool gather — O(Smax) per step even when few
    # blocks participate. Folding the mass emission into the Pallas
    # kernel's block walk (so only live pages are scored) is the ROADMAP
    # kernel-fusion follow-on; the partial itself already skips dead
    # pages on the kernel path.
    if use_kernel is None:
        use_kernel = _on_tpu()
    gk = paged_gather_logical(k_pool, block_table)     # (B, Hkv, Smax, d)
    s_pool = _grouped_scores(q, gk, sc)                # (B, Hkv, rep, Smax)
    if use_kernel:
        part_paged = paged_decode_attention_partial(
            q, k_pool, v_pool, block_table, pgd, block_live=block_live,
            scale=sc, use_kernel=True)
    else:
        gv = paged_gather_logical(v_pool, block_table)
        part_paged = _grouped_partial_from_scores(s_pool, gv, pgd)
    merged = osm.merge_partials(part, part_paged)
    out = osm.finalize(merged, out_dtype=q.dtype)

    # Union mass in absolute coordinates from the merged (m, l).
    m = merged.m.reshape(B, Hkv, rep)
    l = merged.l.reshape(B, Hkv, rep)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    inv_l = 1.0 / jnp.maximum(l, 1e-30)[..., None]

    def probs(s, mask):
        s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
        p = jnp.exp(s - m_safe[..., None]) * inv_l
        return jnp.where(jnp.isfinite(s), p, 0.0)

    ph = jnp.mean(probs(s_ring, hot_ring), axis=(1, 2))      # (B, W)
    pp = jnp.mean(probs(s_pool, pgd), axis=(1, 2))           # (B, Smax)
    bidx = jnp.arange(B)[:, None]
    scatter_idx = jnp.clip(ring_pos, 0, Smax - 1)
    mass = pp.at[bidx, scatter_idx].add(jnp.where(hot_ring, ph, 0.0))
    hot_eff = jnp.zeros((B, Smax), jnp.int32).at[bidx, scatter_idx].max(
        hot_ring.astype(jnp.int32)).astype(bool)       # hot ∩ window, abs
    n_live = jnp.sum(hot_eff | pgd, axis=-1,
                     keepdims=True).astype(jnp.float32)
    return out, mass * n_live


def pam_decode_attention(q: jax.Array,
                         tier_kv: Sequence[tuple[jax.Array, jax.Array]],
                         tier_masks: Sequence[jax.Array | None], *,
                         scale=None, block_s=512,
                         interpret=None) -> jax.Array:
    """Full PAMattention decode over heterogeneous tier pools (Alg. 1).

    tier_kv: [(k_t, v_t)] per tier, each (B, H_kv, S_t, d) — S_t may differ
    per tier (HBM hot pool small & dense, SSD pool large). tier_masks:
    per-tier participation (B, S_t) or None. Exact merge across tiers.
    """
    parts = [
        decode_attention_partial(q, k_t, v_t, msk, scale=scale,
                                 block_s=min(block_s, k_t.shape[2]),
                                 interpret=interpret)
        for (k_t, v_t), msk in zip(tier_kv, tier_masks)
    ]
    acc = parts[0]
    for p in parts[1:]:
        acc = osm.merge_partials(acc, p)           # inter-tier reduction
    return osm.finalize(acc, out_dtype=q.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a, b, c, d_skip, *, chunk=128, interpret=None):
    """Mamba-2 SSD chunked scan. See ``ssd_scan`` for shapes."""
    if interpret is None:
        interpret = not _on_tpu()
    return _ssd_scan(x, dt, a, b, c, d_skip, chunk=chunk,
                     interpret=interpret)
