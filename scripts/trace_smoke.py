"""Telemetry smoke for scripts/verify.sh (PR 9): a chaos-cluster run
with the metrics registry and trace collector active must export a
schema-valid, Perfetto-loadable Chrome trace showing at least one
request's lifecycle crossing a replay or migration seam, and the
counter snapshot must agree with the router's summary.

    PYTHONPATH=src python scripts/trace_smoke.py [trace-out.json]

Writes the trace artifact to ``$TRACE_OUT`` (default
``/tmp/pam_trace_smoke.json``) — CI uploads it.
"""

import json
import os
import sys

import jax
import numpy as np

jax.config.update("jax_platform_name", "cpu")

from repro.cluster import (ClusterSpec, FaultEvent,                  # noqa: E402
                           FaultInjector, RecoveryConfig)
from repro.models import transformer as tf                           # noqa: E402
from repro.models.config import get_config, reduced                  # noqa: E402
from repro.obs import metrics as obs_metrics                         # noqa: E402
from repro.obs import trace as obs_trace                             # noqa: E402
from repro.perfmodel.devices import CXL_CLASS, HBM_CLASS             # noqa: E402
from repro.serving import (EngineSpec, PAMManagerConfig,             # noqa: E402
                           Request, ServingConfig)


def main():
    out_path = (sys.argv[1] if len(sys.argv) > 1
                else os.environ.get("TRACE_OUT",
                                    "/tmp/pam_trace_smoke.json"))
    cfg = reduced(get_config("qwen3-0.6b"))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    pam = PAMManagerConfig(max_tokens=64, hot_capacity=4, warm_capacity=8,
                           compression=4, recency_window=2,
                           schedule_interval=2)
    scfg = ServingConfig(max_batch=4, max_len=64, pam=pam, block_size=8)
    rng = np.random.default_rng(0)
    reqs = [Request(id=i, prompt=rng.integers(0, cfg.vocab, 16),
                    max_new_tokens=12) for i in range(6)]

    reg = obs_metrics.install()
    tr = obs_trace.install()
    try:
        inj = FaultInjector([FaultEvent(tick=6, kind="kill",
                                        device="cxl0")])
        router = ClusterSpec.of(
            cfg, [HBM_CLASS, CXL_CLASS], serving=scfg,
            recovery=RecoveryConfig(
                heartbeat_timeout_s=0.01)).build(params, faults=inj)
        for i, r in enumerate(reqs):
            router.submit_to(r, ("hbm0", "cxl0")[i % 2])
        summary = router.run()
    finally:
        obs_metrics.uninstall()
        obs_trace.uninstall()

    assert summary["finished"] == 6, summary
    assert summary["fault_tolerance"]["kills_detected"] == 1, summary

    # exactness: telemetry observed a chaos run whose streams still
    # match a bare, untraced twin
    twin = EngineSpec(model=cfg, serving=scfg).build(params)
    for r in reqs:
        twin.submit(Request(id=r.id, prompt=r.prompt,
                            max_new_tokens=r.max_new_tokens))
    twin.run()
    for rid, rs in router.finished.items():
        assert rs.outputs == twin.requests[rid].outputs, rid

    # schema contract: balanced spans, monotone per-track timestamps
    tr.close_open()
    trace = tr.export()
    counts = obs_trace.validate(trace)
    assert counts["requests"] == 6, counts
    seam = [rid for rid, p in counts["phases_per_request"].items()
            if "replay" in p or "migrate_out" in p]
    assert seam, counts["phases_per_request"]
    assert all("finish" in p
               for p in counts["phases_per_request"].values()), counts

    # metrics agree with the summary they instrument
    snap = reg.snapshot()
    fleet_finished = sum(
        v for k, v in snap["counters"].items()
        if k.startswith("pam_engine_finished_total"))
    assert fleet_finished == summary["finished"], snap["counters"]
    assert snap["counters"][
        'pam_cluster_faults_total{kind="kill"}'] == 1.0

    with open(out_path, "w") as f:
        json.dump(trace, f)
    print(f"trace smoke OK: {counts['spans']} spans / "
          f"{counts['slices']} slices / {counts['counters']} counter "
          f"samples over {counts['requests']} requests on "
          f"{counts['devices']} devices, {len(seam)} lifecycle(s) "
          f"across a replay/migration seam, streams exact -> {out_path}")


if __name__ == "__main__":
    main()
