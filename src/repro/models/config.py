"""Architecture configuration schema + registry.

One ``ModelConfig`` describes any of the assigned families:
dense / moe / ssm / hybrid / audio-encoder / vlm. ``reduced()`` derives the
CPU-smoke-test variant of the same family (few layers, tiny dims).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_expert: int = 0               # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: int = 0            # 0 = no q compression


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64              # P
    expand: int = 2
    n_groups: int = 1
    conv_kernel: int = 4
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: groups of mamba layers with a shared attention block."""
    n_groups: int = 13
    mamba_per_group: int = 5
    tail_mamba: int = 3             # trailing pure-mamba layers


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                 # 0 -> d_model // n_heads
    qk_norm: bool = False
    causal: bool = True             # audio encoder: False
    rope_theta: float = 1e6
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # modality frontends (stub: precomputed embeddings, see input_specs)
    num_patches: int = 0            # vlm: image patch tokens per sample
    frontend_dim: int = 0           # vlm/audio: stub embedding dim

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Supports O(1)-state long-context decode (long_500k eligible)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        """Autoregressive — encoder-only archs have no decode step."""
        return self.family != "audio"

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        dh, H, Hkv = self.head_dim, self.n_heads, self.n_kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            assert self.ssm is not None
            di = self.ssm.d_inner(d)
            nh = self.ssm.n_heads(d)
            per = (d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state + nh)
                   + di * self.ssm.conv_kernel + di * d + 2 * d)
            return emb + L * per
        attn = d * (H * dh) + 2 * d * (Hkv * dh) + (H * dh) * d
        if self.mla is not None:
            m = self.mla
            dq = H * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            attn = (d * dq + d * m.kv_lora_rank + d * m.qk_rope_head_dim
                    + m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                    + H * m.v_head_dim * d)
        if self.moe is not None:
            e = self.moe
            ffn = ((e.num_experts + e.num_shared) * 3 * d * e.d_expert
                   + d * e.num_experts)
        else:
            ffn = 3 * d * self.d_ff
        if self.family == "hybrid":
            assert self.hybrid is not None and self.ssm is not None
            hb = self.hybrid
            n_mamba = hb.n_groups * hb.mamba_per_group + hb.tail_mamba
            di = self.ssm.d_inner(d)
            nh = self.ssm.n_heads(d)
            mamba_per = (d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state
                              + nh) + di * self.ssm.conv_kernel + di * d + 2 * d)
            shared = attn + 3 * d * self.d_ff + 2 * d
            return emb + n_mamba * mamba_per + shared
        return emb + L * (attn + ffn + 2 * d)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        total = self.param_count()
        all_experts = e.num_experts * 3 * self.d_model * e.d_expert
        active_experts = e.top_k * 3 * self.d_model * e.d_expert
        return total - self.n_layers * (all_experts - active_experts)


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # trigger config module imports
        import repro.configs  # noqa: F401
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    import repro.configs  # noqa: F401
    return dict(_REGISTRY)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=128,
        vocab=256,
        d_head=16,
        dtype="float32",
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4, top_k=2,
                                        num_shared=min(cfg.moe.num_shared, 1),
                                        d_expert=32)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                              qk_rope_head_dim=8, v_head_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, head_dim=8, expand=2,
                              n_groups=1, conv_kernel=4, chunk=16)
    if cfg.hybrid is not None:
        kw["hybrid"] = HybridConfig(n_groups=2, mamba_per_group=1,
                                    tail_mamba=1)
        kw["n_layers"] = 5
    if cfg.family == "vlm":
        kw["num_patches"] = 4
        kw["frontend_dim"] = 32
    if cfg.family == "audio":
        kw["frontend_dim"] = 32
    return dataclasses.replace(cfg, **kw)
