PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test verify bench quickstart lint format

test:            ## tier-1 test suite
	python -m pytest -x -q

lint:            ## ruff correctness gate (blocking in CI)
	ruff check .

format:          ## apply ruff formatting (check runs non-blocking in CI)
	ruff format .

verify:          ## tier-1 tests + fast bench smoke (scripts/verify.sh)
	bash scripts/verify.sh

bench:           ## full benchmark harness -> BENCH.json
	python -m benchmarks.run --out BENCH.json

quickstart:      ## run the examples/quickstart.py walkthrough
	python examples/quickstart.py
